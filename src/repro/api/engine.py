"""SimilarityEngine: the one way to run a similarity campaign.

The engine owns everything between a ``SimilarityRequest`` and a
``SimilarityResult``: metric resolution via the registry, request
validation against the device pool, comet-mesh construction (cached per
decomposition so repeated requests reuse compiled programs), input
materialization, padding (inside the core engines), plan selection and
2-way/3-way dispatch including the staged 3-way sweep.

    from repro.api import SimilarityEngine, SimilarityRequest

    engine = SimilarityEngine()
    result = engine.run(SimilarityRequest(metric="czekanowski", way=2), V)
    for tile in result.tiles():
        ...
"""
from __future__ import annotations

import time

import numpy as np

from repro.api.registry import get_metric
from repro.api.request import SimilarityRequest
from repro.api.result import SimilarityResult
from repro.core.threeway import threeway_distributed
from repro.core.twoway import twoway_distributed
from repro.obs import trace as obs
from repro.parallel.mesh import COMET_AXES, make_comet_mesh

__all__ = ["SimilarityEngine"]


def _campaign_comparisons(result) -> int:
    """Achieved element-comparison count — the paper's comparisons/s
    numerator: result entries x vector length, summed over a batch's
    campaigns.  Delta campaigns count only the border entries actually
    computed (that is the work the engine did)."""
    if hasattr(result, "campaigns"):  # BatchedSimilarityResult
        return sum(
            int(r.num_results()) * int(r.n_f)
            for _m, _s, r in result.campaigns
        )
    d = result.meta.get("delta")
    if d is not None:
        return int(d["computed_entries"]) * int(result.n_f)
    return int(result.num_results()) * int(result.n_f)


def _obs_block(comparisons, seconds, tracer, i0) -> dict:
    """The normalized ``meta["obs"]`` block every campaign result carries.

    Always: achieved ``comparisons``, wall ``seconds``,
    ``comparisons_per_s``.  When tracing was enabled for the run, also the
    per-phase breakdown (from the span events recorded since index ``i0``)
    and — when the core engines recorded roofline events — the summed
    ``bound_seconds``, the binding ``bottleneck`` term, and
    ``utilization`` = bound / measured device-phase seconds (1.0 means
    running AT the cost-model bound)."""
    block = {
        "comparisons": int(comparisons),
        "seconds": float(seconds),
        "comparisons_per_s": float(comparisons) / max(float(seconds), 1e-12),
    }
    if tracer is None:
        return block
    events = tracer.events(i0)
    phases = obs.aggregate_phases(events)
    block["phases"] = {
        n: {"count": int(p["count"]), "seconds": float(p["seconds"])}
        for n, p in sorted(phases.items()) if n != "roofline"
    }
    bound, bottleneck = 0.0, None
    for ph, name, _ts, _tid, args in events:
        if ph == "E" and name == "roofline" and args:
            bound += float(args.get("bound_seconds", 0.0))
            bottleneck = args.get("bottleneck", bottleneck)
    if bound > 0.0:
        block["bound_seconds"] = bound
        block["bottleneck"] = bottleneck
        measured = sum(
            p["seconds"] for n, p in phases.items()
            if n in ("ring-step", "delta-border")
        )
        if measured > 0.0:
            block["utilization"] = bound / measured
    return block


def _subset_positions(request, n_v: int, *, restrict: bool):
    """Validate subset indices against ``n_v`` and compute each subset's
    positions within the traversal payload.

    ``restrict=True`` (in-memory): the payload is the sorted union of all
    subset indices; each subset's positions index into the union, in
    subset order.  ``restrict=False`` (streamed): the payload keeps the
    full vector axis, so positions are the subset indices themselves.
    Returns ``(subs, union, pos)``; union/pos are None/{} for full-set
    requests."""
    subs = request.campaign_subsets()
    if not request.subsets:
        return subs, None, {}
    for name, idx in subs:
        bad = [i for i in idx if i >= n_v]
        if bad:
            raise ValueError(
                f"subset {name!r} indices {bad} out of range for n_v={n_v}"
            )
    if restrict:
        union = np.unique(np.concatenate(
            [np.asarray(idx, np.int64) for _, idx in subs]
        ))
        pos = {
            name: np.searchsorted(union, np.asarray(idx, np.int64))
            for name, idx in subs
        }
        return subs, union, pos
    pos = {name: np.asarray(idx, np.int64) for name, idx in subs}
    return subs, None, pos


class SimilarityEngine:
    """Metric-agnostic front-end over the distributed similarity engines."""

    def __init__(self, mesh=None, devices=None):
        """``mesh``: use an existing ("pf","pv","pv") comet mesh instead of
        constructing one (must match each request's decomposition).
        ``devices``: restrict mesh construction to an explicit device list.
        """
        self._mesh = mesh
        self._devices = devices
        self._mesh_cache = {}

    # -- internals ---------------------------------------------------------

    def _device_count(self) -> int:
        if self._mesh is not None:
            return int(self._mesh.devices.size)
        if self._devices is not None:
            return len(self._devices)
        import jax

        return len(jax.devices())

    def _mesh_for(self, request: SimilarityRequest):
        key = (request.n_pf, request.n_pv, request.n_pr)
        if self._mesh is not None:
            shape = tuple(self._mesh.devices.shape)
            if self._mesh.axis_names != COMET_AXES or shape != key:
                raise ValueError(
                    f"engine mesh {self._mesh.axis_names}{shape} does not "
                    f"match request decomposition {key}"
                )
            return self._mesh
        if key not in self._mesh_cache:
            self._mesh_cache[key] = make_comet_mesh(
                *key, devices=self._devices
            )
        return self._mesh_cache[key]

    # -- public API --------------------------------------------------------

    def run(self, request: SimilarityRequest, V=None) -> SimilarityResult:
        """Execute a campaign; ``V`` overrides the request's input spec.

        ``V`` (or the materialized input) may be a value matrix, a
        pre-encoded ``PackedPlanes`` payload, or a lazy ``ShardedPlanes``
        handle — with a ``source="planes"`` input the campaign streams
        packed planes from the dataset store straight into the engines (no
        host-side encode) and the result's manifest records the dataset
        provenance (path + checksum).  When the resolved ``streaming``
        knob is "on" (multi-shard or budgeted datasets under "auto"), the
        campaign runs the out-of-core ``repro.stream`` pipeline: the
        payload never materializes in host memory beyond the double
        buffers, and ``meta["stream"]`` records the chunk accounting.

        Every result's ``meta["obs"]`` records achieved comparisons/s;
        under an enabled ``repro.obs`` tracer it adds the per-phase
        breakdown and roofline utilization (docs/OBSERVABILITY.md)."""
        if request.delta_from:
            # load() verifies the prior's checksum before we merge into it
            prior = SimilarityResult.load(request.delta_from)
            return self.run_delta(request, prior, V)
        tracer = obs.get_tracer()
        i0 = tracer.event_count() if tracer is not None else 0
        t0 = time.perf_counter()
        with obs.span("campaign"):
            result = self._run(request, V)
        result.meta["obs"] = _obs_block(
            _campaign_comparisons(result), time.perf_counter() - t0,
            tracer, i0,
        )
        return result

    def _run(self, request: SimilarityRequest, V=None) -> SimilarityResult:
        from repro.kernels.mgemm_levels.planes import PackedPlanes
        from repro.store.reader import ShardedPlanes

        spec = get_metric(request.metric)
        with obs.span("validate"):
            request.validate(n_devices=self._device_count(), metric_spec=spec)
        meta = {}
        if V is None:
            if request.input is None:
                raise ValueError("no input: pass V or set request.input")
            if (request.input.source == "planes"
                    and request.streaming != "off"):
                # lazy handle: streaming eligibility resolves before any
                # payload byte is read; non-streamed runs materialize below
                from repro.store import DatasetReader

                if not request.input.path:
                    raise ValueError(
                        "InputSpec(source='planes') needs a dataset path"
                    )
                V = DatasetReader(request.input.path).sharded()
            else:
                V = request.input.materialize()
            if request.input.source == "bed":
                meta["dataset"] = {
                    "path": request.input.path,
                    "kind": "bed",
                    "missing": request.input.missing,
                }
        if isinstance(V, ShardedPlanes):
            from repro.core.twoway import resolve_config

            if resolve_config(request.to_comet_config(), V, spec).streaming \
                    == "on":
                if request.is_batched:
                    return self._run_streamed_batched(request, V, meta)
                return self._run_streamed(request, V, spec, meta)
            V = V.materialize()  # in-memory PackedPlanes path below
        if isinstance(V, PackedPlanes):
            # provenance travels on the handle (DatasetReader.packed() fills
            # it from the manifest it already parsed), so it is recorded no
            # matter which entry point materialized the planes — engine or
            # the serving layer's pre-materialized submit()
            if V.origin:
                meta["dataset"] = V.origin
            n_f, n_v = V.n_f, V.n_v
        else:
            V = np.asarray(V)
            if V.ndim != 2:
                raise ValueError(f"V must be (n_f, n_v), got shape {V.shape}")
            n_f, n_v = V.shape
        mesh = self._mesh_for(request)
        cfg = request.to_comet_config()
        stages = request.resolved_stages()
        if request.is_batched:
            return self._run_batched(request, V, meta, n_f, n_v, mesh, cfg)

        t0 = time.perf_counter()
        if request.way == 2:
            outputs = [twoway_distributed(V, mesh, cfg, metric=spec)]
            if request.packed:
                outputs = [o.pack() for o in outputs]
        else:
            outputs = [
                threeway_distributed(V, mesh, cfg, stage=s, metric=spec)
                for s in stages
            ]
        seconds = time.perf_counter() - t0

        return SimilarityResult(
            way=request.way,
            metric=request.metric,
            n_v=n_v,
            n_f=n_f,
            outputs=outputs,
            decomposition=(request.n_pf, request.n_pv, request.n_pr),
            n_st=request.n_st,
            stages=stages,
            out_dtype=request.out_dtype,
            seconds=seconds,
            meta=meta,
        )

    # -- delta campaigns ----------------------------------------------------

    def run_delta(self, request: SimilarityRequest, prior: SimilarityResult,
                  V=None) -> SimilarityResult:
        """Border-block delta campaign: given ``prior`` covering the input's
        first ``prior.n_v`` vectors, compute ONLY the new-vs-all rectangle
        and new-vs-new triangle (``repro.core.delta``) and merge into packed
        upper-triangular storage — checksum bit-identical to a full
        recompute, compute proportional to the border (``meta["delta"]``).

        Lineage: when the input is an appended dataset store, its
        manifest's ``parent.checksum`` must match the dataset checksum the
        prior recorded (if it recorded one) — a delta against the wrong
        ancestor raises instead of silently merging unrelated results.
        The merged result round-trips ``save()/load()`` as a single-rank
        packed result and is itself a valid prior for the next append
        (deltas chain)."""
        tracer = obs.get_tracer()
        i0 = tracer.event_count() if tracer is not None else 0
        t0 = time.perf_counter()
        with obs.span("campaign"):
            result = self._run_delta(request, prior, V)
        result.meta["obs"] = _obs_block(
            _campaign_comparisons(result), time.perf_counter() - t0,
            tracer, i0,
        )
        return result

    def _run_delta(self, request, prior, V=None) -> SimilarityResult:
        from repro.core.delta import merge_delta, twoway_delta
        from repro.kernels.mgemm_levels.planes import PackedPlanes
        from repro.store.reader import ShardedPlanes

        spec = get_metric(request.metric)
        with obs.span("validate"):
            request.validate(n_devices=self._device_count(), metric_spec=spec)
        if request.way != 2 or request.is_batched:
            raise ValueError("delta campaigns are 2-way, non-batched only")
        if prior.way != 2:
            raise ValueError(f"prior result is {prior.way}-way, need 2-way")
        if prior.metric != request.metric:
            raise ValueError(
                f"prior result is metric {prior.metric!r}, request says "
                f"{request.metric!r}"
            )
        if prior.out_dtype != request.out_dtype:
            raise ValueError(
                f"prior out_dtype {prior.out_dtype!r} != request "
                f"{request.out_dtype!r} (merged storage is one array)"
            )
        meta = {}
        if V is None:
            if request.input is None:
                raise ValueError("no input: pass V or set request.input")
            if (request.input.source == "planes"
                    and request.streaming != "off"):
                from repro.store import DatasetReader

                V = DatasetReader(request.input.path).sharded()
            else:
                V = request.input.materialize()
        if isinstance(V, (PackedPlanes, ShardedPlanes)):
            n_f, n_v = V.n_f, V.n_v
            origin = dict(V.origin) if V.origin else {}
        else:
            V = np.asarray(V)
            if V.ndim != 2:
                raise ValueError(f"V must be (n_f, n_v), got shape {V.shape}")
            n_f, n_v = V.shape
            origin = {}
        n_old = prior.n_v
        m = n_v - n_old
        if m < 1:
            raise ValueError(
                f"input has n_v={n_v} vectors, prior already covers "
                f"{n_old} — nothing appended"
            )
        if prior.n_f != n_f:
            raise ValueError(
                f"prior covers n_f={prior.n_f} fields, input has {n_f} — "
                "not the same cohort"
            )
        if origin:
            meta["dataset"] = origin
            prior_ck = prior.meta.get("dataset", {}).get("checksum")
            parent = origin.get("parent")
            if prior_ck and parent and parent["checksum"] != prior_ck:
                raise ValueError(
                    f"dataset lineage mismatch: manifest parent checksum "
                    f"{parent['checksum']} != prior result's dataset "
                    f"{prior_ck}"
                )
        mesh = self._mesh_for(request)
        cfg = request.to_comet_config()

        t0 = time.perf_counter()
        dinfo = None
        if isinstance(V, ShardedPlanes):
            from repro.core.twoway import resolve_config

            if resolve_config(cfg, V, spec).streaming == "on":
                from repro.stream import stream_twoway_delta

                rect, tri, rcfg, dinfo, sinfo = stream_twoway_delta(
                    V, n_old, mesh, cfg, spec
                )
                meta["stream"] = sinfo
            else:
                V = V.materialize()
        if dinfo is None:
            rect, tri, rcfg, dinfo = twoway_delta(V, n_old, mesh, cfg, spec)
        out = merge_delta(
            prior.outputs[0], rect, tri, n_old, m, rcfg.out_dtype
        )
        seconds = time.perf_counter() - t0
        dinfo["prior"] = {"n_v": n_old, "checksum": hex(prior.checksum())}
        meta["delta"] = dinfo

        # single-rank packed decomposition so save()/load() round-trips the
        # merged storage; the border's requested decomposition is recorded
        # in meta["delta"]["decomposition"]
        return SimilarityResult(
            way=2,
            metric=request.metric,
            n_v=n_v,
            n_f=n_f,
            outputs=[out],
            decomposition=(1, 1, 1),
            n_st=1,
            stages=(0,),
            out_dtype=request.out_dtype,
            seconds=seconds,
            meta=meta,
        )

    # -- batched campaigns --------------------------------------------------

    def _batch_specs(self, request):
        """Resolve every campaign metric and gate each against the way."""
        names = request.campaign_metrics()
        specs = [get_metric(n) for n in names]
        for name, s in zip(names, specs):
            if request.way not in s.ways:
                raise ValueError(
                    f"metric {name!r} supports ways {s.ways}, "
                    f"requested {request.way}"
                )
        return names, specs

    def _run_batched(self, request, V, meta, n_f, n_v, mesh, cfg):
        """In-memory batched dispatch: one ring traversal, many campaigns.

        Named subsets restrict the payload to the sorted UNION of all
        subset indices before the traversal — a vector-axis view for value
        matrices, a byte-column view (``take_planes_vectors``) for packed
        planes, so pre-encoded payloads are never re-encoded — then each
        subset's result is carved out of the union output host-side."""
        from repro.core.threeway import threeway_batched
        from repro.core.twoway import twoway_batched
        from repro.kernels.mgemm_levels.planes import (
            PackedPlanes,
            take_planes_vectors,
        )

        names, specs = self._batch_specs(request)
        subs, union, pos = _subset_positions(request, n_v, restrict=True)
        Vu = V
        if union is not None:
            if isinstance(V, PackedPlanes):
                Vu = PackedPlanes(
                    np.ascontiguousarray(take_planes_vectors(V.planes, union)),
                    n_f=V.n_f, origin=V.origin,
                )
            else:
                Vu = np.ascontiguousarray(V[:, union])
        stages = request.resolved_stages()

        t0 = time.perf_counter()
        if request.way == 2:
            outs, binfo = twoway_batched(Vu, mesh, cfg, specs)
            per_metric = [[o] for o in outs]
        else:
            per_metric = [[] for _ in specs]
            for s in stages:
                outs, binfo = threeway_batched(Vu, mesh, cfg, specs, stage=s)
                for lst, o in zip(per_metric, outs):
                    lst.append(o)
        seconds = time.perf_counter() - t0
        return self._assemble_batched(
            request, names, subs, pos, per_metric, n_f, n_v, meta, binfo,
            seconds, stages,
        )

    def _run_streamed_batched(self, request, sh, meta):
        """Out-of-core batched dispatch over a lazy ShardedPlanes handle.

        The streamed ring carries the FULL vector axis (the payload lives
        in disk shards — there is no cheap union view), so named subsets
        are extracted from the full-set outputs; ring accounting reflects
        the full payload."""
        from repro.stream import stream_threeway_batched, stream_twoway_batched

        names, specs = self._batch_specs(request)
        subs, _, pos = _subset_positions(request, sh.n_v, restrict=False)
        mesh = self._mesh_for(request)
        cfg = request.to_comet_config()
        stages = request.resolved_stages()
        if sh.origin:
            meta["dataset"] = sh.origin

        t0 = time.perf_counter()
        if request.way == 2:
            outs, binfo, sinfo = stream_twoway_batched(sh, mesh, cfg, specs)
            per_metric = [[o] for o in outs]
        else:
            per_metric = [[] for _ in specs]
            for s in stages:
                outs, binfo, sinfo = stream_threeway_batched(
                    sh, mesh, cfg, specs, stage=s
                )
                for lst, o in zip(per_metric, outs):
                    lst.append(o)
        seconds = time.perf_counter() - t0
        meta["stream"] = sinfo
        return self._assemble_batched(
            request, names, subs, pos, per_metric, sh.n_f, sh.n_v, meta,
            binfo, seconds, stages,
        )

    def _assemble_batched(self, request, names, subs, pos, per_metric,
                          n_f, n_v, meta, binfo, seconds, stages):
        """Wrap per-metric union outputs into one BatchedSimilarityResult.

        Full-set campaigns reuse the distributed outputs directly (same
        layout as a sequential run); named-subset campaigns are extracted
        into single-rank plans.  Every campaign result carries the shared
        ``meta["batch"]`` accounting."""
        from repro.api.batch import (
            BatchedSimilarityResult,
            extract_threeway,
            extract_twoway,
        )

        batch_meta = dict(binfo)
        batch_meta.update(
            campaigns=len(names) * len(subs),
            subsets=[n for n, _ in subs if n],
            encodes=1,
            traversals=1 if request.way == 2 else len(stages),
        )
        cmeta = {**meta, "batch": batch_meta}
        campaigns = []
        for mi, mname in enumerate(names):
            outs_m = per_metric[mi]
            for sname, idx in subs:
                if idx is None:  # full-set campaign
                    outputs = outs_m
                    if request.way == 2 and request.packed:
                        outputs = [o.pack() for o in outputs]
                    res = SimilarityResult(
                        way=request.way, metric=mname, n_v=n_v, n_f=n_f,
                        outputs=outputs,
                        decomposition=(request.n_pf, request.n_pv,
                                       request.n_pr),
                        n_st=request.n_st, stages=stages,
                        out_dtype=request.out_dtype, seconds=seconds,
                        meta=cmeta,
                    )
                else:
                    p = pos[sname]
                    if request.way == 2:
                        out = extract_twoway(outs_m[0], p)
                        outputs = [out.pack() if request.packed else out]
                    else:
                        outputs = [extract_threeway(outs_m, p)]
                    res = SimilarityResult(
                        way=request.way, metric=mname, n_v=len(idx), n_f=n_f,
                        outputs=outputs, decomposition=(1, 1, 1),
                        n_st=1, stages=(0,),
                        out_dtype=request.out_dtype, seconds=seconds,
                        meta=cmeta,
                    )
                campaigns.append((mname, sname, res))
        return BatchedSimilarityResult(
            campaigns=campaigns, meta=cmeta, seconds=seconds
        )

    def _run_streamed(self, request, sh, spec, meta) -> SimilarityResult:
        """Out-of-core campaign over a lazy ``ShardedPlanes`` handle.

        Dispatches to ``repro.stream``: chunked deferred-flush programs +
        the cross-shard merge epilogue.  Results are bit-identical to the
        in-memory engines; ``meta["stream"]`` records chunk/peak-host-bytes
        accounting."""
        from repro.stream import stream_threeway, stream_twoway

        mesh = self._mesh_for(request)
        cfg = request.to_comet_config()
        stages = request.resolved_stages()
        if sh.origin:
            meta["dataset"] = sh.origin

        t0 = time.perf_counter()
        outputs, sinfo = [], None
        if request.way == 2:
            out, sinfo = stream_twoway(sh, mesh, cfg, metric=spec)
            outputs = [out.pack() if request.packed else out]
        else:
            for s in stages:
                out, sinfo = stream_threeway(sh, mesh, cfg, stage=s,
                                             metric=spec)
                outputs.append(out)
        seconds = time.perf_counter() - t0
        meta["stream"] = sinfo

        return SimilarityResult(
            way=request.way,
            metric=request.metric,
            n_v=sh.n_v,
            n_f=sh.n_f,
            outputs=outputs,
            decomposition=(request.n_pf, request.n_pv, request.n_pr),
            n_st=request.n_st,
            stages=stages,
            out_dtype=request.out_dtype,
            seconds=seconds,
            meta=meta,
        )
