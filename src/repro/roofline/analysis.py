"""Three-term roofline from a compiled dry-run artifact.

Targets TPU v5e (assignment constants):
    197 TFLOP/s bf16 MXU per chip | 819 GB/s HBM | ~50 GB/s/link ICI.
The VPU estimate (~1 TOP/s, 8x128 lanes x ~940 MHz x 2 ops) prices the
faithful min-plus kernel, which cannot use the MXU (DESIGN.md §2).

cost_analysis() on the compiled module is PER-DEVICE (the SPMD-partitioned
module — verified empirically), so terms are flops_dev/peak etc. with no
chip division.  Collective bytes come from HLO parsing (repro.roofline.hlo);
the collective term uses modeled wire traffic / one ICI link (conservative:
a 2D torus ring uses one link per direction per axis).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.roofline.hlo import analyze_hlo  # noqa: F401 (re-exported)


@dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float  # bf16 FLOP/s per chip (MXU)
    vpu_ops: float  # elementwise op/s per chip (VPU estimate)
    hbm_bw: float  # bytes/s per chip
    link_bw: float  # bytes/s per ICI link


HW_V5E = Hardware(
    name="tpu_v5e", peak_flops=197e12, vpu_ops=1.0e12, hbm_bw=819e9, link_bw=50e9
)


def analyze_compiled(compiled, n_devices: int, hw: Hardware = HW_V5E,
                     vpu_fraction: float = 0.0) -> dict:
    """Roofline terms (seconds per step, per chip) from a compiled artifact.

    vpu_fraction: fraction of the FLOPs that are min-plus (VPU-priced) —
    1.0 for the faithful comet kernels, 0.0 for matmul workloads.
    """
    from repro.roofline.hlo import analyze_hlo

    from repro.parallel.compat import cost_analysis_dict

    ca = cost_analysis_dict(compiled)
    text = compiled.as_text()
    hc = analyze_hlo(text, n_devices)
    # loop-aware HLO cost model (while bodies x trip count); XLA's own
    # cost_analysis counts loop bodies once and is kept for reference
    flops = float(hc.flops)
    bytes_accessed = float(hc.bytes)
    try:
        ma = compiled.memory_analysis()
        memory = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes_est": int(
                ma.argument_size_in_bytes
                + ma.output_size_in_bytes
                + ma.temp_size_in_bytes
                - ma.alias_size_in_bytes
            ),
        }
    except Exception:  # pragma: no cover - backend without memory_analysis
        memory = {}

    mxu_flops = flops * (1 - vpu_fraction)
    vpu_flops = flops * vpu_fraction
    t_compute = mxu_flops / hw.peak_flops + vpu_flops / hw.vpu_ops
    t_memory = bytes_accessed / hw.hbm_bw
    t_collective = hc.total_wire_bytes / hw.link_bw
    t_collective_operand = hc.total_operand_bytes / hw.link_bw

    terms = {
        "hw": hw.name,
        "n_devices": n_devices,
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "bytes_upper_per_device": float(hc.bytes_upper),
        "xla_flops_once": float(ca.get("flops", 0.0)),
        "xla_bytes_once": float(ca.get("bytes accessed", 0.0)),
        "vpu_fraction": vpu_fraction,
        "t_compute": t_compute,
        "t_memory": t_memory,
        "t_collective": t_collective,
        "t_collective_operand_spec": t_collective_operand,
        "collectives": hc.collectives_dict(),
        "memory": memory,
    }
    terms["bottleneck"] = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_collective),
        key=lambda kv: kv[1],
    )[0]
    t_bound = max(t_compute, t_memory, t_collective)
    terms["roofline_fraction"] = (t_compute / t_bound) if t_bound > 0 else 0.0
    return terms


def model_flops(arch_params: int, tokens: int, kind: str,
                active_fraction: float = 1.0) -> float:
    """MODEL_FLOPS: 6*N*D train (N_active for MoE), 2*N*D forward-only."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * arch_params * active_fraction * tokens
