"""Popcount bit-GEMM (binary fast path) + sorenson metric tests.

Covers the ISSUE-7 contract: the popgemm kernels agree bit-for-bit with
the byte-table oracle and the min-plus formulation on binary data; pad
bits are inert under AND+popcount exactly as BITPLANE_FORMAT.md promises
for the dot formulation (hypothesis property over non-multiple-of-8 field
counts); the shared POPCOUNT table is the single definition; and the
``sorenson`` metric is bit-identical to its independent boolean AND-dot
oracle on every path (xla / fused-vpu / fused-levels / fused-popcount /
levels_xla).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import SORENSON, SimilarityEngine, SimilarityRequest, get_metric
from repro.core.metric_spec import CZEKANOWSKI, czek_assemble_tile
from repro.core.synthetic import random_integer_vectors
from repro.core.tile_executor import TileExecutor
from repro.core.twoway import CometConfig, resolve_config
from repro.kernels.mgemm import unpack_tri_tiles
from repro.kernels.mgemm_levels import POPCOUNT, encode_bitplanes_np
from repro.kernels.popgemm import (
    metric2_pop,
    metric2_pop_tri,
    pop_planes,
    pop_planes_ref,
    threeway_batch_pop,
    threeway_pop_ref,
)

try:  # property tests run under hypothesis when present (CI installs it);
    # a deterministic case sweep below keeps coverage without it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


def _binary(k, n, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 2, (k, n)).astype(np.float32)


# -- shared POPCOUNT table (satellite: dedup) --------------------------------


def test_popcount_table_is_shared():
    """writer, reader validate(), and the popgemm oracle index the SAME
    table object, owned by the format module (planes.py)."""
    from repro.kernels.mgemm_levels import planes
    from repro.store import writer

    assert writer.POPCOUNT is planes.POPCOUNT
    assert POPCOUNT is planes.POPCOUNT
    assert [int(POPCOUNT[b]) for b in (0, 1, 0b1011, 0xFF)] == [0, 1, 3, 8]
    assert int(POPCOUNT.sum()) == 1024  # sum over all bytes = 256 * 4


# -- kernel parity vs oracle and vs min-plus ---------------------------------


def _check_pop_kernels(m, k, n, seed):
    A, B = _binary(k, m, seed), _binary(k, n, seed + 1)
    Pa = encode_bitplanes_np(A, 1)
    Pb = encode_bitplanes_np(B, 1)
    ref = pop_planes_ref(Pa, Pb)
    # oracle == min-plus numerator == boolean AND-dot
    assert (ref == np.minimum(A[:, :, None], B[:, None, :]).sum(0)).all()
    got = np.asarray(pop_planes(jnp.asarray(Pa), jnp.asarray(Pb),
                                bm=8, bn=8, bkb=8))
    assert (got == ref).all()  # exact integers, no tolerance
    # fused epilogue form: same fp32 assembly ops as the unfused path
    sa = A.sum(axis=0).astype(np.float32)
    sb = B.sum(axis=0).astype(np.float32)
    fused = np.asarray(metric2_pop(
        jnp.asarray(Pa), jnp.asarray(Pb), jnp.asarray(sa), jnp.asarray(sb),
        epilogue=czek_assemble_tile, bm=8, bn=8, bkb=8))
    want = np.asarray(czek_assemble_tile(
        jnp.asarray(ref, jnp.float32), jnp.asarray(sa)[:, None],
        jnp.asarray(sb)[None, :]))
    assert (fused == want).all()  # bit-identical fp32


@pytest.mark.parametrize(
    "m,k,n,seed",
    [(1, 1, 1, 0), (5, 7, 3, 1), (12, 40, 9, 2), (19, 65, 23, 3)],
)
def test_pop_kernels_cases(m, k, n, seed):
    _check_pop_kernels(m, k, n, seed)


def test_pop_tri_matches_rectangular():
    """Triangular-schedule diagonal kernel == strict upper triangle of the
    rectangular kernel on the same block."""
    A = _binary(37, 19, 7)
    P = encode_bitplanes_np(A, 1)
    s = A.sum(axis=0).astype(np.float32)
    packed = metric2_pop_tri(jnp.asarray(P), jnp.asarray(s),
                             epilogue=czek_assemble_tile, bt=8, bkb=8)
    tri = np.asarray(unpack_tri_tiles(packed, 19, 8))
    full = np.asarray(metric2_pop(
        jnp.asarray(P), jnp.asarray(P), jnp.asarray(s), jnp.asarray(s),
        epilogue=czek_assemble_tile, bm=8, bn=8, bkb=8))
    assert (tri == np.triu(full, 1)).all()
    assert (np.tril(tri) == 0).all()


def test_threeway_pop_matches_oracle():
    """3-way slice kernel: X_j stays a packed AND, result == byte-table
    oracle == min-plus triple numerator."""
    A = _binary(37, 11, 4)
    X = _binary(37, 5, 5)
    B = _binary(37, 9, 6)
    Pa, Px, Pb = (encode_bitplanes_np(M, 1) for M in (A, X, B))
    got = np.asarray(threeway_batch_pop(
        jnp.asarray(Pa), jnp.asarray(Px), jnp.asarray(Pb),
        bm=8, bn=8, bkb=8))
    ref = threeway_pop_ref(Pa, Px, Pb)
    assert (got == ref).all()
    # triple min summed over fields — the min-plus formulation
    want = np.minimum(
        np.minimum(A[:, None, :, None], X[:, :, None, None]),
        B[:, None, None, :],
    ).sum(axis=0)
    assert (ref == want).all()


# -- padding inertness under popcount (satellite: hypothesis) ----------------


def _check_padding_inert(k, m, n, seed):
    """Non-multiple-of-8 field counts: the encoder's pad bits are ZERO, so
    they are inert in AND+popcount — the numerator equals the boolean
    AND-dot of the UNPADDED values, and extra zero-byte padding (the store
    shard / pf-align rule) never changes it."""
    A, B = _binary(k, m, seed), _binary(k, n, seed + 1)
    Pa = encode_bitplanes_np(A, 1)
    Pb = encode_bitplanes_np(B, 1)
    if k % 8:  # remainder bits of the last byte are zero
        last = Pa[0, -1, :]
        mask = 0xFF << (k % 8) & 0xFF
        assert (last & mask).sum() == 0
    want = (A.T.astype(np.float64) @ B.astype(np.float64))  # AND-dot, k rows
    assert (pop_planes_ref(Pa, Pb) == want).all()
    # whole-byte padding (pad_planes / field_align) is inert too
    Pa8 = encode_bitplanes_np(A, 1, field_align=4)
    Pb8 = encode_bitplanes_np(B, 1, field_align=4)
    assert (pop_planes_ref(Pa8, Pb8) == want).all()
    got = np.asarray(pop_planes(jnp.asarray(Pa8), jnp.asarray(Pb8),
                                bm=8, bn=8, bkb=8))
    assert (got == want).all()


@pytest.mark.parametrize("k,m,n,seed",
                         [(1, 2, 2, 0), (7, 3, 4, 1), (9, 5, 2, 2),
                          (13, 4, 6, 3), (31, 6, 3, 4)])
def test_padding_inert_cases(k, m, n, seed):
    _check_padding_inert(k, m, n, seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(
        k=st.integers(1, 41),   # non-multiple-of-8 field counts included
        m=st.integers(1, 9),
        n=st.integers(1, 9),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_padding_inert_property(k, m, n, seed):
        _check_padding_inert(k, m, n, seed)


# -- executor routing + cross-path parity ------------------------------------


def test_executor_popcount_block_matches_other_paths():
    """pair_block on the popcount path == fused-levels == unfused xla,
    bit-identical, for both rectangular and diagonal blocks."""
    V = random_integer_vectors(24, 16, max_value=1, seed=9)
    sa = np.asarray(V.sum(axis=0), np.float32)
    blocks = {}
    for impl, levels in [("levels", 1), ("levels", 2), ("xla", 1)]:
        cfg = resolve_config(CometConfig(impl=impl, levels=levels),
                             V, CZEKANOWSKI)
        ex = TileExecutor(cfg=cfg, metric=CZEKANOWSKI, axis=None)
        Va = jnp.asarray(V, jnp.float32)
        rect = np.asarray(ex.pair_block(Va, jnp.asarray(sa), Va,
                                        jnp.asarray(sa)))
        diag = np.asarray(ex.pair_block(Va, jnp.asarray(sa), Va,
                                        jnp.asarray(sa), diagonal=True))
        blocks[(impl, levels)] = (rect, diag)
    assert TileExecutor(
        cfg=resolve_config(CometConfig(impl="levels", levels=1), V,
                           CZEKANOWSKI),
        metric=CZEKANOWSKI, axis=None).path == "fused-popcount"
    ref = blocks[("xla", 1)]
    for key, (rect, diag) in blocks.items():
        assert (rect == ref[0]).all(), key
        assert (diag == ref[1]).all(), key


# -- sorenson metric (satellite) ---------------------------------------------


def test_sorenson_registered():
    spec = get_metric("sorenson")
    assert spec is SORENSON
    assert spec.ways == (2, 3)
    assert spec.combine is jnp.minimum
    # shared assembly callables => shared fp ops => bit-identical paths
    assert spec.assemble2 is CZEKANOWSKI.assemble2
    assert spec.assemble_tile is CZEKANOWSKI.assemble_tile


@pytest.mark.parametrize("impl,levels", [
    ("xla", 1),        # unfused reference
    ("pallas", 1),     # fused-vpu
    ("levels", 2),     # fused-levels (bf16 plane dots)
    ("levels", 1),     # fused-popcount (binary fast path)
    ("levels_xla", 1),  # unfused plane contraction
])
def test_sorenson_parity_2way(impl, levels):
    V = random_integer_vectors(24, 20, max_value=1, seed=11)
    eng = SimilarityEngine()
    res = eng.run(SimilarityRequest(metric="sorenson", way=2, impl=impl,
                                    levels=levels), V)
    oracle = np.triu(SORENSON.oracle2(V), 1)
    got = np.triu(np.asarray(res.dense(), np.float64), 1)
    np.testing.assert_allclose(got, oracle, rtol=0, atol=1e-6)
    # bit-identical checksum across every impl (exact integer numerators)
    ref = eng.run(SimilarityRequest(metric="sorenson", way=2), V)
    assert res.checksum() == ref.checksum()


def test_sorenson_parity_3way():
    V = random_integer_vectors(24, 15, max_value=1, seed=12)
    eng = SimilarityEngine()
    res = eng.run(SimilarityRequest(metric="sorenson", way=3, impl="levels",
                                    levels=1), V)
    ref = eng.run(SimilarityRequest(metric="sorenson", way=3, impl="xla",
                                    levels=1), V)
    assert res.checksum() == ref.checksum()
    o3 = SORENSON.oracle3(V)
    d3 = np.asarray(res.dense(), np.float64)
    for (i, j, k), v in np.ndenumerate(d3):
        if i < j < k:
            assert abs(v - o3[i, j, k]) < 1e-6
