"""Distributed 2-way Proportional Similarity engine — paper §4.1, Algorithm 1.

SPMD mapping (shard_map over a ("pf", "pv", "pr") mesh):

* V (n_f, n_v) is sharded over "pf" (vector elements) and "pv" (vector
  number), replicated over "pr".
* Ring: at step d, every rank holds block (p_v + d) mod n_pv via
  ``jax.lax.ppermute`` (the paper's pipelined send/recv; XLA's async
  collective-permute scheduler overlaps it with the mGEMM, replacing the
  paper's hand-rolled double buffering).
* Block-circulant schedule: rank row p_v computes block (p_v, p_v + d);
  the final step of an even ring is computed by the lower half only.
* "pr" round-robin: step d executes on ranks with d % n_pr == p_r under
  ``lax.cond`` (compute genuinely skipped, not masked).
* "pf" reduction: numerator partials are ``psum`` over "pf"; row-sum
  denominators are psummed once and ring-carried alongside V.

Per-block compute is owned by the ``TileExecutor`` (kernel dispatch, fused
metric epilogues, triangular diagonal-block schedule) — see
``repro.core.tile_executor``.

Bit-exactness contract (paper §5): with integer-valued inputs every
numerator is an exact fp integer regardless of summation order, so any
(n_pf, n_pv, n_pr) decomposition — and any executor path — produces
bit-identical metric values, verified by checksum in
tests/distributed_harness.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.compat import shard_map

from repro.core import checksum as ck
from repro.obs import trace as obs
from repro.core.metric_spec import (
    CZEKANOWSKI,
    MetricSpec,
    batch_lead,
    group_families,
)
from repro.core.mgemm import get_impl
from repro.core.plan2 import TwoWayPlan, global_pairs_of_block
from repro.core.tile_executor import TileExecutor

__all__ = [
    "CometConfig",
    "TwoWayOutput",
    "twoway_distributed",
    "twoway_batched",
    "czek2_distributed",
    "pad_vectors",
    "resolve_config",
]


@dataclass(frozen=True)
class CometConfig:
    """Decomposition + implementation knobs (paper's n_pf / n_pv / n_pr / n_st)."""

    n_pf: int = 1
    n_pv: int = 1
    n_pr: int = 1
    n_st: int = 1  # 3-way staging
    impl: str = "xla"  # mgemm implementation registry key
    levels: int = 2  # for impl='levels*'
    out_dtype: str = "float32"
    # ring payload dtype (beyond-paper §Perf): int8 quarters the ICI wire
    # traffic of the V ring — EXACT for integer data with values <= 127
    # (SNP {0,1,2} codes); metric math still accumulates in fp32.
    # "auto" (default) selects int8 whenever the input is integer-valued
    # with |values| <= 127, instead of silently ring-carrying fp32; pass
    # ring_dtype="float32" to opt out explicitly.
    ring_dtype: str = "auto"
    # contraction-axis chunk of the XLA mgemm (memory/speed trade-off)
    chunk: int = 128
    # bit-plane pre-encoding for the levels path: "auto" encodes V once
    # into packed uint8 planes (8 plane-bits/byte, docs/BITPLANE_FORMAT.md)
    # and ring-carries THOSE — in BOTH engines, 2-way ring and 3-way
    # doubly-nested ring alike — whenever impl='levels*', the metric
    # combine is min, and the data is integer-valued in [0, levels];
    # "bitplane" forces it (ValueError if ineligible); "none" keeps the
    # value ring with per-step/per-slice (V >= t) construction.
    encoding: str = "auto"
    # out-of-core streaming (repro.stream): "auto" streams store-backed
    # multi-shard datasets (or whenever max_host_bytes is set), "on"
    # forces it (ValueError without a store-backed input), "off" keeps
    # the in-memory single-pass campaign.
    streaming: str = "auto"
    # peak HOST bytes the streamed staging buffers may occupy (0 =
    # unbounded: one disk shard per chunk).  Bounds the double-buffered
    # chunk pipeline, NOT the dataset size — see repro.stream.StreamPlan.
    max_host_bytes: int = 0

    @property
    def n_ranks(self) -> int:
        return self.n_pf * self.n_pv * self.n_pr

    def impl_fn(self):
        fn = get_impl(self.impl)
        if self.impl.startswith("levels"):
            return partial(fn, levels=self.levels)
        if self.impl == "xla":
            return partial(fn, chunk=self.chunk)
        return fn


def pad_vectors(
    V: np.ndarray, cfg: CometConfig, *, field_align: int = 1
) -> np.ndarray:
    """Pad fields to n_pf multiple and vectors to n_pv multiple with zeros.

    Zero padding is inert: pad vectors produce zero numerators and are
    excluded by index bookkeeping on the host side.  ``field_align`` further
    aligns the field count (8*n_pf for the packed bit-plane payload, whose
    byte axis must split evenly over "pf")."""
    n_f, n_v = V.shape
    fp = (-n_f) % (cfg.n_pf * field_align)
    vp = (-n_v) % cfg.n_pv
    if fp or vp:
        V = np.pad(V, ((0, fp), (0, vp)))
    return V


def _values_int8_safe(V: np.ndarray) -> bool:
    """True when ring-carrying V as int8 is value-exact."""
    if V.size == 0:
        return False
    if not np.issubdtype(V.dtype, np.integer):
        if not np.isfinite(V).all() or not (V == np.floor(V)).all():
            return False
    return bool(V.min() >= -128 and V.max() <= 127)


def _values_leveled(V: np.ndarray, levels: int) -> bool:
    """True when V is integer-valued in [0, levels] — the exactness domain
    of the level decomposition AND of the bit-plane encoding."""
    if V.size == 0:
        return False
    if not np.issubdtype(V.dtype, np.integer):
        if not np.isfinite(V).all() or not (V == np.floor(V)).all():
            return False
    return bool(V.min() >= 0 and V.max() <= levels)


def _plane_eligible(cfg: CometConfig, metric: MetricSpec) -> bool:
    """The ONE plane-path eligibility predicate (impl + metric), shared by
    the value and pre-encoded branches of ``resolve_config``."""
    return (
        cfg.impl in ("levels", "levels_xla")
        and metric.combine is jnp.minimum
    )


def _plane_ineligible_msg(prefix: str, cfg: CometConfig, metric: MetricSpec) -> str:
    return (
        f"{prefix} needs impl='levels'/'levels_xla' and a min-combine metric "
        f"(got impl={cfg.impl!r}, metric={metric.name!r})"
    )


def resolve_config(
    cfg: CometConfig, V, metric: MetricSpec
) -> CometConfig:
    """Resolve the 'auto' knobs (ring_dtype, encoding) against actual data.

    The distributed entry points call this once per campaign, so the device
    programs and the TileExecutor only ever see concrete settings.

    ``V`` may be a value matrix, a pre-encoded ``PackedPlanes`` payload
    (``repro.store`` campaign loading), or a LAZY ``ShardedPlanes`` handle
    (``DatasetReader.sharded()`` — the streamed campaign's input, which
    shares every plane-path eligibility rule without materializing a
    byte).  Pre-encoded input HAS no value form on the host, so it must
    resolve to the plane path: eligibility failures (impl / metric /
    levels mismatch, explicit ``encoding="none"``) raise instead of
    falling back.

    The ``streaming`` knob resolves here too (this is the one place
    eligibility rules live): "auto" -> "on" for a lazy store handle with
    multiple shards or an explicit ``max_host_bytes`` budget, "off"
    otherwise; "on" without store-backed input raises — a value matrix is
    already resident, there is nothing to stream."""
    from dataclasses import replace

    from repro.kernels.mgemm_levels.planes import PackedPlanes
    from repro.store.reader import ShardedPlanes

    if cfg.streaming not in ("auto", "on", "off"):
        raise ValueError(
            f"streaming must be 'auto', 'on' or 'off', got {cfg.streaming!r}"
        )
    if isinstance(V, ShardedPlanes):
        streaming = cfg.streaming
        if streaming == "auto":
            streaming = "on" if (V.n_shards > 1 or cfg.max_host_bytes > 0) \
                else "off"
        cfg = replace(cfg, streaming=streaming)
    elif cfg.streaming == "on":
        raise ValueError(
            "streaming='on' needs a store-backed dataset input "
            "(InputSpec(source='planes') / DatasetReader.sharded()); "
            "value matrices and materialized PackedPlanes are already "
            "resident in host memory"
        )
    else:
        cfg = replace(cfg, streaming="off")

    if isinstance(V, (PackedPlanes, ShardedPlanes)):
        if cfg.encoding == "none":
            raise ValueError(
                "pre-encoded plane input cannot run with encoding='none' "
                "(there are no host-side values to ring-carry) — load the "
                "matrix instead, or drop encoding='none'"
            )
        if not _plane_eligible(cfg, metric):
            raise ValueError(
                _plane_ineligible_msg("pre-encoded plane input", cfg, metric)
            )
        if V.levels != cfg.levels:
            raise ValueError(
                f"dataset is encoded with levels={V.levels}, request says "
                f"levels={cfg.levels}"
            )
        ring = cfg.ring_dtype
        if ring == "auto":  # plane payloads are uint8; value ring unused
            ring = "int8" if cfg.levels <= 127 else "float32"
        return replace(cfg, ring_dtype=ring, encoding="bitplane")

    V = np.asarray(V)
    ring = cfg.ring_dtype
    if ring == "auto":
        ring = "int8" if _values_int8_safe(V) else "float32"
    enc = cfg.encoding
    if enc not in ("auto", "bitplane", "none"):
        raise ValueError(f"unknown encoding {enc!r}")
    if enc != "none":
        eligible = _plane_eligible(cfg, metric)
        leveled = _values_leveled(V, cfg.levels)
        if enc == "bitplane":
            if not eligible:
                raise ValueError(
                    _plane_ineligible_msg("encoding='bitplane'", cfg, metric)
                )
            if not leveled:
                raise ValueError(
                    "encoding='bitplane' needs integer data in "
                    f"[0, levels={cfg.levels}]"
                )
        else:
            enc = "bitplane" if (eligible and leveled) else "none"
    return replace(cfg, ring_dtype=ring, encoding=enc)


@dataclass
class TwoWayOutput:
    """Per-rank metric blocks + the metadata to read them.

    Two storage modes:

    * ``dense`` — ``blocks`` is (n_pv, n_pr, slots, m, m), one full square
      per computed ring step (what the device program emits).
    * ``packed`` — ``blocks`` is (n_pv, n_pr, packed_len): each rank's
      computed steps concatenated, the diagonal block (step 0, where only
      the strict upper triangle carries results) stored as its m(m-1)/2
      packed triangle values and off-diagonal blocks as flat m*m squares.
      The layout is derived from the plan, so nothing beyond the flat array
      needs persisting.  Packing is a HOST-side storage transform (the
      device program still emits dense slots; ``pack()`` converts after the
      transfer), so the saving applies to the retained / persisted result
      buffer — roughly half for diagonal-dominated small-``n_pv`` runs (one
      slot, one diagonal block) — not to peak device memory.
    """

    blocks: np.ndarray
    plan: TwoWayPlan
    n_v: int  # true (unpadded) vector count
    n_vp: int  # padded block size
    storage: str = "dense"  # "dense" | "packed"

    # -- packed layout (deterministic from the plan) -----------------------

    def _packed_layout(self, p_r: int):
        """[(d, offset, size)] for one round-robin rank's packed buffer."""
        m = self.n_vp
        tri = m * (m - 1) // 2
        out, off = [], 0
        for d in self.plan.steps_of_pr(p_r):
            size = tri if d == 0 else m * m
            out.append((d, off, size))
            off += size
        return out

    def _block_values(self, p_v: int, p_r: int, d: int) -> np.ndarray:
        """(m, m) values of the block rank (p_v, p_r) computed at step d."""
        m = self.n_vp
        if self.storage == "dense":
            return self.blocks[p_v, p_r, d // self.plan.n_pr]
        off, size = next(
            (o, s) for dd, o, s in self._packed_layout(p_r) if dd == d
        )
        flat = self.blocks[p_v, p_r, off:off + size]
        if d == 0:
            out = np.zeros((m, m), flat.dtype)
            out[np.triu_indices(m, 1)] = flat
            return out
        return flat.reshape(m, m)

    def pack(self) -> "TwoWayOutput":
        """Convert to packed upper-triangular storage (values unchanged —
        identical entries and checksum, verified in tests)."""
        if self.storage == "packed":
            return self
        m = self.n_vp
        iu = np.triu_indices(m, 1)
        layouts = [self._packed_layout(p_r) for p_r in range(self.plan.n_pr)]
        length = max((lay[-1][1] + lay[-1][2]) if lay else 0 for lay in layouts)
        packed = np.zeros(
            (self.plan.n_pv, self.plan.n_pr, length), self.blocks.dtype
        )
        for p_v in range(self.plan.n_pv):
            for p_r in range(self.plan.n_pr):
                for d, off, size in layouts[p_r]:
                    blk = self.blocks[p_v, p_r, d // self.plan.n_pr]
                    packed[p_v, p_r, off:off + size] = (
                        blk[iu] if d == 0 else blk.ravel()
                    )
        return TwoWayOutput(
            blocks=packed, plan=self.plan, n_v=self.n_v, n_vp=self.n_vp,
            storage="packed",
        )

    @property
    def nbytes(self) -> int:
        return self.blocks.nbytes

    # -- reads --------------------------------------------------------------

    def entries(self):
        """Yield (i, j, value) for every unique computed pair (i < j)."""
        n_pv, n_pr = self.plan.n_pv, self.plan.n_pr
        for p_v in range(n_pv):
            for p_r in range(n_pr):
                for d in self.plan.steps_of_pr(p_r):
                    if not self.plan.rank_computes(p_v, p_r, d):
                        continue
                    row, col = self.plan.block_of(p_v, d)
                    I, J, mask = global_pairs_of_block(row, col, self.n_vp)
                    mask = mask & (I < self.n_v) & (J < self.n_v)
                    vals = self._block_values(p_v, p_r, d)
                    yield I[mask], J[mask], vals[mask]

    def dense(self) -> np.ndarray:
        """(n_v, n_v) symmetric metric matrix (tests / small problems)."""
        out = np.zeros((self.n_v, self.n_v), self.blocks.dtype)
        for I, J, V in self.entries():
            lo, hi = np.minimum(I, J), np.maximum(I, J)
            out[lo, hi] = V
            out[hi, lo] = V
        return out

    def checksum(self) -> int:
        return ck.combine([ck.raw_pairs(I, J, V) for I, J, V in self.entries()])

    def num_pairs(self) -> int:
        return sum(len(I) for I, _, _ in self.entries())


#: Compiled-program cache for the 2-way shard_map programs.  ``jax.jit``
#: memoizes per function object, and the entry points used to build a fresh
#: ``partial`` (hence a fresh jit cache) per campaign — every repeated
#: request paid trace+compile again.  Keying the jitted callable on
#: (mesh, cfg, plan geometry, metric name, flags) lets a hot serving
#: process — and ``SimilarityService.warmup`` — reuse the compiled
#: executable across requests; jit still retraces on a shape change.
_PROGRAM_CACHE: "OrderedDict" = None


def _cached_jit(key, build):
    """Return (building if absent) the jitted program for ``key``."""
    global _PROGRAM_CACHE
    if _PROGRAM_CACHE is None:
        from collections import OrderedDict

        _PROGRAM_CACHE = OrderedDict()
    fn = _PROGRAM_CACHE.get(key)
    if fn is None:
        fn = _PROGRAM_CACHE[key] = jax.jit(build())
        while len(_PROGRAM_CACHE) > 128:
            _PROGRAM_CACHE.popitem(last=False)
    else:
        _PROGRAM_CACHE.move_to_end(key)
    return fn


def _twoway_program(
    Vl, *, cfg: CometConfig, plan: TwoWayPlan, out_dtype,
    metric: MetricSpec = None, planes: bool = False,
):
    """Per-device program (inside shard_map). Vl: (n_f/n_pf, n_vp) values,
    or — on the bit-plane campaign path (``planes=True``) — the rank's
    packed plane shard (levels, n_fb/n_pf, n_vp) uint8.

    All block compute goes through the TileExecutor: on the fused Pallas
    paths the metric epilogue runs in-kernel (no dense numerator block in
    HBM) and the step-0 diagonal block runs the triangular tile schedule
    (only ``tj >= ti`` tiles enumerated, per paper §5).  With planes, the
    ring carries the packed representation — L/32 of the fp32 wire volume —
    and ``(V >= t)`` never runs inside the ring loop."""
    metric = metric or CZEKANOWSKI
    executor = TileExecutor(cfg=cfg, metric=metric, out_dtype=out_dtype,
                            axis="pf")
    n_pv, n_pr = cfg.n_pv, cfg.n_pr
    m = Vl.shape[-1]
    if planes:
        # stats from the exact value reconstruction V = sum_t plane_t
        from repro.kernels.mgemm_levels import values_from_planes

        s_own = jax.lax.psum(metric.stat(values_from_planes(Vl)), "pf")
    else:
        s_own = jax.lax.psum(metric.stat(Vl), "pf")  # (m,)
    pv = jax.lax.axis_index("pv")
    pr = jax.lax.axis_index("pr")
    # receive from upward neighbour: src (i+1) -> dst i
    perm = [((i + 1) % n_pv, i) for i in range(n_pv)]

    Vr, sr = Vl, s_own
    out = jnp.zeros((plan.slots_per_rank, m, m), out_dtype)
    for d in range(plan.n_steps):
        if d > 0:
            Vr = jax.lax.ppermute(Vr, "pv", perm)
            sr = jax.lax.ppermute(sr, "pv", perm)
        execute = (d % n_pr) == pr
        if plan.is_half_step(d):
            execute = jnp.logical_and(execute, pv < n_pv // 2)

        def compute(o, Vr=Vr, sr=sr, d=d):
            vals = executor.pair_block(Vl, s_own, Vr, sr, diagonal=(d == 0))
            return o.at[d // n_pr].set(vals)

        out = jax.lax.cond(execute, compute, lambda o: o, out)
    return out[None, None]  # leading (pv=1, pr=1) device dims


def _twoway_deferred_program(
    Pl, *, cfg: CometConfig, plan: TwoWayPlan, metric: MetricSpec = None,
):
    """Deferred-flush chunk program (``repro.stream``): one byte-axis chunk
    of the campaign payload runs the SAME block-circulant ring as
    ``_twoway_program``, but every block emits its raw fp32 numerator
    partial (psummed over "pf") instead of assembled metric values, and
    the per-vector stat partial rides along.  ``Pl`` is the rank's packed
    plane shard of ONE chunk — (levels, chunk_kb/n_pf, n_vp) uint8.

    The stats ring is gone entirely: raw numerators need no stats, so the
    chunk ring carries only the plane payload (the merge epilogue reads
    chunk-summed global stats instead).  Returns ``(partials, s_own)`` —
    (slots, m, m) fp32 and (m,) fp32.
    """
    from repro.kernels.mgemm_levels import values_from_planes

    metric = metric or CZEKANOWSKI
    executor = TileExecutor(cfg=cfg, metric=metric, out_dtype=jnp.float32,
                            axis="pf", deferred=True)
    n_pv, n_pr = cfg.n_pv, cfg.n_pr
    m = Pl.shape[-1]
    s_own = jax.lax.psum(metric.stat(values_from_planes(Pl)), "pf")
    pv = jax.lax.axis_index("pv")
    pr = jax.lax.axis_index("pr")
    perm = [((i + 1) % n_pv, i) for i in range(n_pv)]

    Pr = Pl
    out = jnp.zeros((plan.slots_per_rank, m, m), jnp.float32)
    for d in range(plan.n_steps):
        if d > 0:
            Pr = jax.lax.ppermute(Pr, "pv", perm)
        execute = (d % n_pr) == pr
        if plan.is_half_step(d):
            execute = jnp.logical_and(execute, pv < n_pv // 2)

        def compute(o, Pr=Pr, d=d):
            return o.at[d // n_pr].set(executor.pair_partial(Pl, Pr))

        out = jax.lax.cond(execute, compute, lambda o: o, out)
    return out[None, None], s_own[None]


def _prep_payload(V, cfg: CometConfig, metric: MetricSpec):
    """Resolve the config against V and build the sharded ring payload.

    The one payload-preparation path shared by the sequential and batched
    2-way entry points (so a batched campaign's payload is byte-identical
    to the sequential campaign's).  Returns
    ``(cfg, arg, in_specs, planes, n_vp, n_v)``.
    """
    from repro.kernels.mgemm_levels.planes import PackedPlanes, pad_planes

    if isinstance(V, PackedPlanes):
        n_v = V.n_v
        cfg = resolve_config(cfg, V, metric)  # always "bitplane" (or raises)
        Pp = pad_planes(
            V.planes, byte_align=cfg.n_pf,
            n_v=n_v + (-n_v) % cfg.n_pv,
        )
        return cfg, jnp.asarray(Pp), P(None, "pf", "pv"), True, \
            Pp.shape[2] // cfg.n_pv, n_v
    n_v = V.shape[1]
    V = np.asarray(V)
    cfg = resolve_config(cfg, V, metric)
    planes = cfg.encoding == "bitplane"
    if planes:
        # encode ONCE before shard_map; the byte axis shards over "pf"
        from repro.kernels.mgemm_levels import encode_bitplanes_np

        Vp = pad_vectors(V, cfg, field_align=8)
        with obs.span("encode") as sp:
            arg = jnp.asarray(encode_bitplanes_np(Vp, cfg.levels))
            sp.add(bytes=int(arg.nbytes), levels=int(cfg.levels))
        in_specs = P(None, "pf", "pv")
    else:
        Vp = pad_vectors(V, cfg)
        arg = jnp.asarray(Vp, dtype=jnp.dtype(cfg.ring_dtype))
        in_specs = P("pf", "pv")
    return cfg, arg, in_specs, planes, Vp.shape[1] // cfg.n_pv, n_v


def twoway_distributed(
    V, mesh: Mesh, cfg: CometConfig, metric: MetricSpec = None
) -> TwoWayOutput:
    """Compute all unique 2-way metrics of V's columns on the mesh.

    ``V``: (n_f, n_v) value matrix, or a pre-encoded ``PackedPlanes``
    payload (``repro.store`` zero-encode loading) — the packed planes are
    re-padded with inert zero bytes/columns to the campaign geometry and
    ring-carried directly; the host encoder never runs."""
    metric = metric or CZEKANOWSKI
    cfg, arg, in_specs, planes, n_vp, n_v = _prep_payload(V, cfg, metric)
    plan = TwoWayPlan(cfg.n_pv, cfg.n_pr)
    out_dtype = jnp.dtype(cfg.out_dtype)

    fn = _cached_jit(
        ("twoway", mesh, cfg, plan, metric.name, str(out_dtype), planes),
        lambda: shard_map(
            partial(_twoway_program, cfg=cfg, plan=plan, out_dtype=out_dtype,
                    metric=metric, planes=planes),
            mesh=mesh,
            in_specs=in_specs,
            out_specs=P("pv", "pr", None, None, None),
            check=False,
        ),
    )
    with obs.span("ring-step") as sp:
        blocks = obs.fence(fn(arg))
        sp.add(steps=int(plan.n_steps), payload_bytes=int(arg.nbytes))
    obs.roofline_event(fn, (arg,), int(mesh.devices.size))
    blocks = np.asarray(blocks).reshape(
        cfg.n_pv, cfg.n_pr, plan.slots_per_rank, n_vp, n_vp
    )
    return TwoWayOutput(blocks=blocks, plan=plan, n_v=n_v, n_vp=n_vp)


def _twoway_batched_program(
    Vl, *, cfg: CometConfig, plan: TwoWayPlan, out_dtype,
    groups, planes: bool = False,
):
    """Batched-campaign per-device program: ONE ring traversal, M results.

    ``groups`` is the ``group_families`` partition of the requested
    metrics: each family shares a numerator contraction per ring step
    (``TileExecutor.pair_raw``) and fans it out through every member's
    ``merge_pair`` epilogue — extra metrics in a family cost one extra
    elementwise assembly, never another contraction or ring step.  The
    payload ring (``Vr``) is metric-agnostic and moves EXACTLY the bytes
    of the sequential single-metric program; only the small per-family
    (m,) stat vectors scale with family count.

    Emits (M, slots, m, m) metric values, M = total metrics in flattened
    family order (the entry point restores request order).
    """
    from repro.kernels.mgemm_levels import values_from_planes

    n_pv, n_pr = cfg.n_pv, cfg.n_pr
    m = Vl.shape[-1]
    execs = [
        [TileExecutor(cfg=cfg, metric=s, out_dtype=out_dtype, axis="pf")
         for s in grp]
        for grp in groups
    ]
    W = values_from_planes(Vl) if planes else Vl
    # one psummed stat per family (members share the stat by definition
    # of family_key) — bitwise the sequential program's s_own
    stats = tuple(
        jax.lax.psum(grp[0].stat(W), "pf") for grp in groups
    )
    n_metrics = sum(len(grp) for grp in groups)
    pv = jax.lax.axis_index("pv")
    pr = jax.lax.axis_index("pr")
    perm = [((i + 1) % n_pv, i) for i in range(n_pv)]

    Vr, srs = Vl, stats
    out = jnp.zeros((n_metrics, plan.slots_per_rank, m, m), out_dtype)
    for d in range(plan.n_steps):
        if d > 0:
            Vr = jax.lax.ppermute(Vr, "pv", perm)
            srs = tuple(jax.lax.ppermute(s, "pv", perm) for s in srs)
        execute = (d % n_pr) == pr
        if plan.is_half_step(d):
            execute = jnp.logical_and(execute, pv < n_pv // 2)

        def compute(o, Vr=Vr, srs=srs, d=d):
            vals = []
            for g, ex_grp in enumerate(execs):
                raw = ex_grp[0].pair_raw(
                    Vl, stats[g], Vr, srs[g], diagonal=(d == 0)
                )
                vals.extend(
                    ex.merge_pair(raw, stats[g], srs[g], diagonal=(d == 0))
                    for ex in ex_grp
                )
            return o.at[:, d // n_pr].set(jnp.stack(vals))

        out = jax.lax.cond(execute, compute, lambda o: o, out)
    return out[None, None]  # leading (pv=1, pr=1) device dims


def _twoway_deferred_batched_program(
    Pl, *, cfg: CometConfig, plan: TwoWayPlan, groups,
):
    """Deferred-flush batched chunk program (streamed batched campaigns):
    one byte-axis chunk, one ring, one raw fp32 numerator partial per
    metric FAMILY (members share it) plus per-family stat partials.
    Returns ``(partials (G, slots, m, m) fp32, stats (G, m) fp32)`` — the
    host accumulates both across chunks and fans the merge epilogue out
    per metric after the last chunk."""
    from repro.kernels.mgemm_levels import values_from_planes

    n_pv, n_pr = cfg.n_pv, cfg.n_pr
    m = Pl.shape[-1]
    execs = [
        TileExecutor(cfg=cfg, metric=grp[0], out_dtype=jnp.float32,
                     axis="pf", deferred=True)
        for grp in groups
    ]
    W = values_from_planes(Pl)
    stats = jnp.stack([jax.lax.psum(grp[0].stat(W), "pf") for grp in groups])
    pv = jax.lax.axis_index("pv")
    pr = jax.lax.axis_index("pr")
    perm = [((i + 1) % n_pv, i) for i in range(n_pv)]

    Pr = Pl
    out = jnp.zeros((len(groups), plan.slots_per_rank, m, m), jnp.float32)
    for d in range(plan.n_steps):
        if d > 0:
            Pr = jax.lax.ppermute(Pr, "pv", perm)
        execute = (d % n_pr) == pr
        if plan.is_half_step(d):
            execute = jnp.logical_and(execute, pv < n_pv // 2)

        def compute(o, Pr=Pr, d=d):
            parts = jnp.stack(
                [ex.pair_raw(Pl, None, Pr, None) for ex in execs]
            )
            return o.at[:, d // n_pr].set(parts)

        out = jax.lax.cond(execute, compute, lambda o: o, out)
    return out[None, None], stats[None]


def twoway_batched(
    V, mesh: Mesh, cfg: CometConfig, specs,
) -> tuple:
    """Batched 2-way campaigns: one ring traversal, one result per metric.

    ``specs`` is a sequence of MetricSpecs sharing the SAME payload; the
    config's 'auto' knobs resolve against ``batch_lead(specs)`` (the
    plane-native member constrains encoding the most).  Returns
    ``(outputs, binfo)``: per-spec ``TwoWayOutput`` in request order —
    each bit-identical to its sequential ``twoway_distributed`` run — and
    the ring-traffic accounting dict behind ``meta["batch"]``
    (``ring_payload_bytes`` is a function of payload shape and plan ONLY,
    independent of how many metrics ride the traversal).
    """
    specs = list(specs)
    cfg, arg, in_specs, planes, n_vp, n_v = _prep_payload(
        V, cfg, batch_lead(specs)
    )
    groups = group_families(specs)
    flat = [s for grp in groups for s in grp]
    plan = TwoWayPlan(cfg.n_pv, cfg.n_pr)
    out_dtype = jnp.dtype(cfg.out_dtype)

    fn = shard_map(
        partial(_twoway_batched_program, cfg=cfg, plan=plan,
                out_dtype=out_dtype, groups=groups, planes=planes),
        mesh=mesh,
        in_specs=in_specs,
        out_specs=P("pv", "pr", None, None, None, None),
        check=False,
    )
    jfn = jax.jit(fn)
    with obs.span("ring-step") as sp:
        blocks = obs.fence(jfn(arg))
        sp.add(steps=int(plan.n_steps), payload_bytes=int(arg.nbytes),
               metrics=len(flat))
    obs.roofline_event(jfn, (arg,), int(mesh.devices.size))
    blocks = np.asarray(blocks).reshape(
        cfg.n_pv, cfg.n_pr, len(flat), plan.slots_per_rank, n_vp, n_vp
    )
    by_name = {
        s.name: TwoWayOutput(
            blocks=np.ascontiguousarray(blocks[:, :, i]), plan=plan,
            n_v=n_v, n_vp=n_vp,
        )
        for i, s in enumerate(flat)
    }
    binfo = batch_accounting(
        int(arg.nbytes), cfg, plan, groups, n_vp, planes=planes, way=2
    )
    return [by_name[s.name] for s in specs], binfo


def batch_accounting(
    payload_nbytes: int, cfg: CometConfig, plan, groups,
    n_vp: int, *, planes: bool, way: int,
) -> dict:
    """Ring-traffic accounting for one batched traversal (either way).

    ``ring_payload_bytes`` counts the V/plane payload actually ppermuted:
    per-rank shard bytes x the plan's ``ring_steps`` x ranks —
    deliberately independent of metric count (that is the whole point of
    batching).  The per-family (m,) fp32 stat vectors are the only traffic
    that scales with the batch; they are reported separately and are
    negligible next to the payload (m floats vs m payload columns)."""
    shard = payload_nbytes // (cfg.n_pf * cfg.n_pv)
    return {
        "way": way,
        "families": len(groups),
        "metrics": [s.name for grp in groups for s in grp],
        "planes": planes,
        "payload_bytes_per_rank": shard,
        "ring_steps": plan.ring_steps,
        "n_ranks": cfg.n_ranks,
        "ring_payload_bytes": shard * plan.ring_steps * cfg.n_ranks,
        "stat_ring_bytes": (
            len(groups) * n_vp * 4 * plan.ring_steps * cfg.n_ranks
        ),
    }


def czek2_distributed(V: np.ndarray, mesh: Mesh, cfg: CometConfig) -> TwoWayOutput:
    """Proportional Similarity 2-way campaign (pre-registry entry point)."""
    return twoway_distributed(V, mesh, cfg, metric=CZEKANOWSKI)
