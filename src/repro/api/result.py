"""SimilarityResult: one streaming interface over 2-way and 3-way outputs.

The engines produce per-rank metric *blocks* (``TwoWayOutput`` /
``ThreeWayOutput``); a result unifies them — across ways and across 3-way
stages — behind one reading API:

* ``tiles()``    — stream of ``Tile``s, one per computed block slice: global
                   index arrays + values.  This is the production path: a
                   campaign's output never has to exist densely in memory
                   (the paper's 3-way runs write ~1e12 results).
* ``entries()``  — flat scalar stream ``(i, j[, k], value)`` for small jobs.
* ``dense()``    — materialized symmetric matrix / tensor (tests, demos).
* ``checksum()`` — the paper §5 exact multiset checksum over all tiles.
* ``save()/load()`` — manifest + per-stage block arrays, round-tripping to
                   an identical checksum.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field, replace

import numpy as np

from repro.core import checksum as ck
from repro.core.plan2 import TwoWayPlan
from repro.core.plan3 import ThreeWayPlan
from repro.core.threeway import ThreeWayOutput
from repro.core.twoway import TwoWayOutput

__all__ = ["Tile", "SimilarityResult"]

MANIFEST = "manifest.json"
FORMAT_VERSION = 1


@dataclass(frozen=True)
class Tile:
    """One computed block slice: parallel global-index arrays + values."""

    way: int
    index: tuple  # (I, J) or (I, J, K) int arrays, same length as values
    values: np.ndarray
    stage: int = 0

    def __len__(self) -> int:
        return len(self.values)

    def raw_checksum(self) -> tuple:
        if self.way == 2:
            return ck.raw_pairs(*self.index, self.values)
        return ck.raw_triples(*self.index, self.values)


@dataclass
class SimilarityResult:
    """Unified, streaming view of a similarity campaign's output."""

    way: int
    metric: str
    n_v: int
    n_f: int
    outputs: list  # one TwoWayOutput, or one ThreeWayOutput per stage
    decomposition: tuple = (1, 1, 1)
    n_st: int = 1
    stages: tuple = (0,)
    out_dtype: str = "float32"
    seconds: float = 0.0
    meta: dict = field(default_factory=dict)
    # memoized aggregates (blocks are write-once; full tile scans are the
    # dominant host-side cost of large campaigns)
    _checksum: int = field(default=None, init=False, repr=False, compare=False)
    _num_results: int = field(default=None, init=False, repr=False, compare=False)

    # -- streaming reads ---------------------------------------------------

    def tiles(self):
        """Yield every computed block slice as a Tile (constant memory)."""
        for out in self.outputs:
            stage = getattr(out, "stage", 0)
            for tup in out.entries():
                *index, values = tup
                yield Tile(way=self.way, index=tuple(index), values=values,
                           stage=stage)

    def entries(self):
        """Flat scalar stream: (i, j, value) / (i, j, k, value)."""
        for tile in self.tiles():
            for row in zip(*tile.index, tile.values):
                yield row

    def dense(self) -> np.ndarray:
        """Materialized (n_v, n_v) symmetric matrix, or (n_v, n_v, n_v)
        tensor holding each triple at its canonical sorted index i < j < k
        (the other 5 permutation slots stay zero)."""
        out = np.zeros((self.n_v,) * self.way, np.dtype(self.out_dtype))
        for tile in self.tiles():
            idx = np.sort(np.stack(tile.index), axis=0)
            if self.way == 2:
                out[idx[0], idx[1]] = tile.values
                out[idx[1], idx[0]] = tile.values
            else:
                out[idx[0], idx[1], idx[2]] = tile.values
        return out

    def checksum(self) -> int:
        """Paper §5 exact campaign checksum (all stages combined)."""
        if self._checksum is None:
            parts = []
            count = 0
            for t in self.tiles():
                parts.append(t.raw_checksum())
                count += len(t)
            self._checksum = ck.combine(parts)
            self._num_results = count
        return self._checksum

    def num_results(self) -> int:
        if self._num_results is None:
            self._num_results = sum(len(t) for t in self.tiles())
        return self._num_results

    # -- storage modes -----------------------------------------------------

    @property
    def storage(self) -> str:
        """"dense" | "packed" (2-way only; 3-way outputs are always dense)."""
        if self.way == 2 and self.outputs:
            return self.outputs[0].storage
        return "dense"

    def pack(self) -> "SimilarityResult":
        """Return a result with 2-way outputs in packed upper-triangular
        block storage (``self`` is left untouched, like
        ``TwoWayOutput.pack()``).

        Values, entries and checksum are unchanged (the packed form drops
        only the never-computed lower triangle of diagonal blocks); the
        retained result memory for the slot buffer roughly halves on
        diagonal-dominated decompositions."""
        if self.way != 2 or self.storage == "packed":
            return self
        return replace(self, outputs=[o.pack() for o in self.outputs])

    # -- persistence -------------------------------------------------------

    def save(self, path: str) -> dict:
        """Write per-stage blocks + a manifest; returns the manifest dict."""
        os.makedirs(path, exist_ok=True)
        for out, stage in zip(self.outputs, self.stages):
            np.save(os.path.join(path, f"blocks_s{stage}.npy"), out.blocks)
        manifest = {
            "format_version": FORMAT_VERSION,
            "metric": self.metric,
            "way": self.way,
            "n_f": int(self.n_f),
            "n_v": int(self.n_v),
            "n_vp": int(self.outputs[0].n_vp),
            "decomposition": list(self.decomposition),
            "n_st": self.n_st,
            "stages": list(self.stages),
            "storage": self.storage,
            "out_dtype": self.out_dtype,
            "results": int(self.num_results()),
            "seconds": self.seconds,
            "checksum": hex(self.checksum()),
            **self.meta,
        }
        with open(os.path.join(path, MANIFEST), "w") as f:
            json.dump(manifest, f, indent=2)
        return manifest

    #: manifest keys owned by the result itself; anything else in a saved
    #: manifest came from ``meta`` (e.g. the dataset-store provenance block
    #: the engine records for ``source="planes"`` campaigns) and is
    #: restored into ``meta`` on load
    _MANIFEST_KEYS = frozenset({
        "format_version", "metric", "way", "n_f", "n_v", "n_vp",
        "decomposition", "n_st", "stages", "storage", "out_dtype",
        "results", "seconds", "checksum",
    })

    @classmethod
    def load(cls, path: str) -> "SimilarityResult":
        """Rebuild a result from ``save()`` output (verifies the checksum)."""
        with open(os.path.join(path, MANIFEST)) as f:
            m = json.load(f)
        n_pf, n_pv, n_pr = m["decomposition"]
        outputs = []
        for stage in m["stages"]:
            blocks = np.load(os.path.join(path, f"blocks_s{stage}.npy"))
            if m["way"] == 2:
                outputs.append(TwoWayOutput(
                    blocks=blocks, plan=TwoWayPlan(n_pv, n_pr),
                    n_v=m["n_v"], n_vp=m["n_vp"],
                    storage=m.get("storage", "dense"),
                ))
            else:
                outputs.append(ThreeWayOutput(
                    blocks=blocks, plan=ThreeWayPlan(n_pv, n_pr, m["n_st"]),
                    n_v=m["n_v"], n_vp=m["n_vp"], stage=stage,
                ))
        result = cls(
            way=m["way"], metric=m["metric"], n_v=m["n_v"], n_f=m["n_f"],
            outputs=outputs, decomposition=tuple(m["decomposition"]),
            n_st=m["n_st"], stages=tuple(m["stages"]),
            out_dtype=m["out_dtype"], seconds=m.get("seconds", 0.0),
            meta={k: v for k, v in m.items() if k not in cls._MANIFEST_KEYS},
        )
        got = hex(result.checksum())
        if got != m["checksum"]:
            raise ValueError(
                f"checksum mismatch loading {path}: manifest {m['checksum']}, "
                f"recomputed {got}"
            )
        return result
