"""Serving launcher: batched generation with a smoke-scale model.

    python -m repro.launch.serve --arch llama3-8b --smoke --batch 4 --tokens 16
"""
import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-json", default="", metavar="OUT.json",
                    help="write the engine's metrics-registry snapshot "
                         "(request/token counters, prefill and decode-step "
                         "latency histograms) to OUT.json after the run")
    args = ap.parse_args(argv)

    import jax
    import numpy as np

    from repro.configs.registry import get_config, get_smoke_config
    from repro.models import api
    from repro.serve.engine import ServeConfig, ServeEngine

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = api.init_model(cfg, jax.random.PRNGKey(args.seed))
    eng = ServeEngine(
        cfg, params,
        ServeConfig(max_new_tokens=args.tokens, temperature=args.temperature),
    )
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(3, cfg.vocab_size, (args.batch, args.prompt_len)).astype(
        np.int32
    )
    t0 = time.time()
    out = eng.generate(prompts)
    dt = time.time() - t0
    total = out.size
    print(f"arch={cfg.name} batch={args.batch} new_tokens={args.tokens}")
    print(f"generated {total} tokens in {dt:.2f}s = {total / dt:.1f} tok/s")
    for row in out[: min(4, len(out))]:
        print("  ", row.tolist())
    if args.metrics_json:
        import json

        with open(args.metrics_json, "w") as f:
            json.dump(eng.registry.snapshot(), f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"metrics={args.metrics_json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
