"""command-r-plus-104b [dense] — hf:CohereForAI/c4ai-command-r-v01 (unverified).

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000, no bias.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    head_dim=128,
    rope_theta=75e3,
)

SMOKE = CONFIG.replace(
    name="command-r-plus-104b-smoke",
    n_layers=2,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    head_dim=16,
)
