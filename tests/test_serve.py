"""SimilarityService: the async serving front-end's concurrency battery.

Pins the serving contract (docs/ARCHITECTURE.md serving layer):

* ``submit_async`` returns futures; N threads firing mixed duplicate /
  unique requests get exactly ONE compute per fingerprint (duplicates —
  cached or still in flight — share the result object) and every future
  resolves;
* an engine exception propagates through the future, the worker thread
  survives it, and the failed fingerprint is retryable;
* ``shutdown`` drains queued campaigns, then joins every worker — no
  leaked threads, later submits are refused; the context manager form
  shuts down on exit;
* store-backed requests are fingerprinted by dataset checksum +
  ``campaign_key()`` — NEVER by payload bytes: submitting a ~1 GiB-scale
  sparse mmap'd dataset completes without reading a payload byte
  (``_payload_hash`` stubbed to raise, shard files unreadable);
* delta awareness: an appended dataset whose parent's result is cached
  schedules only the border blocks (``delta_hits``), bit-identical to
  the cold full recompute;
* ``warmup`` compiles on a zeros payload from manifest dims alone without
  touching the cache or hit/miss counters.
"""
import json
import os
import threading

import numpy as np
import pytest

import repro.serve.engine as serve_engine
from repro.api import InputSpec, SimilarityRequest
from repro.core.synthetic import random_integer_vectors
from repro.serve.engine import SimilarityService
from repro.store import append_dataset, write_dataset
from repro.store.format import shard_name


def _matrix(n_f=24, n_v=10, seed=0):
    return random_integer_vectors(n_f, n_v, max_value=2, seed=seed)


# -- futures + exactly-one-compute -------------------------------------------


def test_duplicate_submits_share_one_compute():
    V = _matrix()
    req = SimilarityRequest(way=2, metric="czekanowski")
    with SimilarityService(workers=2) as svc:
        futs = [svc.submit_async(req, V) for _ in range(10)]
        results = [f.result(timeout=60) for f in futs]
        assert all(r is results[0] for r in results)
        assert svc.misses == 1 and svc.hits == 9
        assert svc.stats()["cached_results"] == 1


def test_threaded_mixed_requests_all_resolve():
    """N client threads, mixed duplicate/unique requests: every future
    resolves, each unique fingerprint computes exactly once — and every
    concurrent ``stats()``/``metrics()`` snapshot is internally
    consistent (hits + misses + in_flight == submitted)."""
    V = _matrix()
    uniques = [
        SimilarityRequest(way=2, metric="czekanowski", chunk=c)
        for c in (32, 64, 96, 128)
    ]
    with SimilarityService(workers=3) as svc:
        futures, lock = [], threading.Lock()
        stop, bad_snaps = threading.Event(), []

        def sampler():
            # hammer snapshots while submissions and completions race
            while not stop.is_set():
                for snap in (svc.stats(), svc.metrics()):
                    total = snap["hits"] + snap["misses"] + snap["in_flight"]
                    if total != snap["submitted"]:
                        bad_snaps.append(snap)

        def client(i):
            req = uniques[i % len(uniques)]
            f = svc.submit_async(req, V)
            with lock:
                futures.append((i % len(uniques), f))

        sampling = threading.Thread(target=sampler)
        sampling.start()
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(16)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        by_req = {}
        for k, f in futures:
            by_req.setdefault(k, set()).add(id(f.result(timeout=60)))
        stop.set()
        sampling.join()
        assert not bad_snaps, bad_snaps[:3]
        # each unique request resolved, to exactly one result object
        assert len(by_req) == len(uniques)
        assert all(len(ids) == 1 for ids in by_req.values())
        assert svc.misses == len(uniques)
        assert svc.hits == 16 - len(uniques)
        # the latency split saw every computed campaign
        m = svc.metrics()
        assert m["queue_wait_seconds"]["count"] == len(uniques)
        assert m["compute_seconds"]["count"] == len(uniques)
        assert m["queue_depth"] == 0 and m["in_flight"] == 0
        # chunking is a perf knob: all four computed the same answer
        cks = {f.result().checksum() for _, f in futures}
        assert len(cks) == 1


def test_sync_submit_compat():
    """The blocking façade: second submit returns the SAME object and the
    stats dict keeps its exact (registry-backed) shape."""
    V = _matrix()
    svc = SimilarityService()
    try:
        req = SimilarityRequest(way=2, metric="czekanowski")
        r1 = svc.submit(req, V)
        r2 = svc.submit(req, V)
        assert r2 is r1
        assert svc.stats() == {
            "hits": 1, "misses": 1, "cached_results": 1, "delta_hits": 0,
            "in_flight": 0, "submitted": 2, "warmups": 0, "errors": 0,
        }
    finally:
        svc.shutdown()


# -- error propagation + lifecycle -------------------------------------------


def test_engine_error_propagates_and_worker_survives():
    V = _matrix()
    with SimilarityService() as svc:
        bad = SimilarityRequest(way=2, metric="czekanowski", n_pv=1024)
        f = svc.submit_async(bad, V)
        with pytest.raises(ValueError, match="devices"):
            f.result(timeout=60)
        # the failed fingerprint did not get cached or stuck in flight
        assert svc.stats()["cached_results"] == 0
        f2 = svc.submit_async(bad, V)
        with pytest.raises(ValueError, match="devices"):
            f2.result(timeout=60)
        # worker is alive and computes fresh requests
        good = svc.submit(SimilarityRequest(way=2, metric="czekanowski"), V)
        assert good.n_v == V.shape[1]


def test_shutdown_joins_workers_and_refuses_submits():
    V = _matrix()
    svc = SimilarityService(workers=2)
    req = SimilarityRequest(way=2, metric="czekanowski")
    fut = svc.submit_async(req, V)
    svc.shutdown()
    # queued campaign drained before the workers exited
    assert fut.result(timeout=5).n_v == V.shape[1]
    assert not any(t.is_alive() for t in svc._threads)
    with pytest.raises(RuntimeError, match="shut down"):
        svc.submit_async(req, V)
    svc.shutdown()  # idempotent


def test_no_leaked_threads_after_exception():
    V = _matrix()
    svc = SimilarityService(workers=2)
    for _ in range(3):
        f = svc.submit_async(
            SimilarityRequest(way=2, metric="czekanowski", n_pv=1024), V
        )
        with pytest.raises(ValueError):
            f.result(timeout=60)
    svc.shutdown()
    assert not any(t.is_alive() for t in svc._threads)


# -- store-backed fingerprinting: no payload read ----------------------------


def test_store_fingerprint_never_hashes_payload(tmp_path, monkeypatch):
    """Regression for the whole-payload-hashing fingerprint: store-backed
    submissions must key on the manifest checksum.  ``_payload_hash`` is
    stubbed to raise, so ANY payload hashing fails the test."""
    path = os.path.join(str(tmp_path), "ds")
    write_dataset(path, _matrix(seed=3), levels=2, n_shards=2)
    monkeypatch.setattr(
        serve_engine, "_payload_hash",
        lambda V: (_ for _ in ()).throw(AssertionError("payload was hashed")),
    )
    req = SimilarityRequest(way=2, metric="czekanowski", impl="levels",
                            levels=2, input=InputSpec(source="planes",
                                                      path=path))
    with SimilarityService() as svc:
        r1 = svc.submit(req)
        r2 = svc.submit(req)
        assert r2 is r1
        assert svc.hits == 1 and svc.misses == 1
        assert r1.meta["dataset"]["checksum"].startswith("sha256:")


def test_giant_mmap_dataset_fingerprint_reads_no_payload(tmp_path):
    """Fingerprinting a ~1 GiB-scale dataset submit must complete from the
    manifest alone: the shard file is a crafted sparse npy made unreadable
    after writing — any payload open would raise."""
    path = os.path.join(str(tmp_path), "big")
    os.makedirs(path)
    levels, kb, n_v = 2, 4096, 131072  # 2 * 4096 * 131072 = 1 GiB payload
    shard = os.path.join(path, shard_name(0))
    big = np.lib.format.open_memmap(
        shard, mode="w+", dtype=np.uint8, shape=(levels, kb, n_v)
    )
    del big  # sparse file: headers + holes, no data blocks written
    np.save(os.path.join(path, "stats.npy"),
            np.zeros((levels, n_v), np.int64))
    manifest = {
        "format": "repro-bitplane-dataset", "format_version": 1,
        "levels": levels, "n_f": 8 * kb, "n_v": n_v, "kb": kb,
        "n_shards": 1, "shard_files": [shard_name(0)],
        "stats_file": "stats.npy", "checksum": "sha256:" + "0" * 64,
        "dataset_version": 1,
    }
    json.dump(manifest, open(os.path.join(path, "dataset.json"), "w"))
    os.chmod(shard, 0)  # any payload read now raises PermissionError
    try:
        req = SimilarityRequest(way=2, metric="czekanowski", impl="levels",
                                levels=2, input=InputSpec(source="planes",
                                                          path=path))
        with SimilarityService() as svc:
            key, V = svc._fingerprint(req, None)
            assert V is None  # nothing materialized
            assert key[1] == ("dataset", manifest["checksum"])
    finally:
        os.chmod(shard, 0o600)


# -- delta-aware serving + warmup --------------------------------------------


def test_delta_aware_serving_matches_cold_recompute(tmp_path):
    V0, Vn = _matrix(n_v=12, seed=4), _matrix(n_v=5, seed=5)
    path = os.path.join(str(tmp_path), "ds")
    write_dataset(path, V0, levels=2, n_shards=2)
    base = dict(way=2, metric="czekanowski", impl="levels", levels=2)
    with SimilarityService() as svc:
        parent = svc.submit(SimilarityRequest(
            **base, input=InputSpec(source="planes", path=path)))
        append_dataset(path, Vn)
        child = svc.submit(SimilarityRequest(
            **base, input=InputSpec(source="planes", path=path)))
        assert svc.delta_hits == 1
        d = child.meta["delta"]
        assert d["n_old"] == 12 and d["n_new"] == 5
        assert d["computed_entries"] < d["full_entries"]
        assert d["prior"]["checksum"] == hex(parent.checksum())
    with SimilarityService() as cold:
        full = cold.submit(SimilarityRequest(
            **base, input=InputSpec(source="planes", path=path)))
        assert cold.delta_hits == 0 and "delta" not in full.meta
    assert child.checksum() == full.checksum()


def test_warmup_compiles_without_caching(tmp_path):
    path = os.path.join(str(tmp_path), "ds")
    write_dataset(path, _matrix(seed=6), levels=2, n_shards=1)
    req = SimilarityRequest(way=2, metric="czekanowski", impl="levels",
                            levels=2, input=InputSpec(source="planes",
                                                      path=path))
    with SimilarityService() as svc:
        dt = svc.warmup(req)
        assert dt >= 0 and svc.warmups == 1
        assert svc.stats() == {
            "hits": 0, "misses": 0, "cached_results": 0, "delta_hits": 0,
            "in_flight": 0, "submitted": 0, "warmups": 1, "errors": 0,
        }
        # the real submission still computes the real answer
        r = svc.submit(req)
        assert svc.stats() == {
            "hits": 0, "misses": 1, "cached_results": 1, "delta_hits": 0,
            "in_flight": 0, "submitted": 1, "warmups": 1, "errors": 0,
        }
        assert r.n_v == 10
