"""repro.stream — out-of-core streaming campaign pipeline.

Double-buffered disk -> host -> device streaming over a ``repro.store``
dataset's field shards: ``StreamPlan`` chunks the packed byte axis,
``ShardPrefetcher`` stages the next chunk from the shard mmaps while the
engines contract the current one, and the cross-shard merge epilogue in
``pipeline`` folds per-chunk fp32 numerator/stat partials into outputs
bit-identical to an in-memory campaign.  Peak host payload memory is the
two staging buffers — bounded by ``CometConfig.max_host_bytes`` — never
the dataset size.
"""
from repro.stream.pipeline import (  # noqa: F401
    stream_threeway,
    stream_threeway_batched,
    stream_twoway,
    stream_twoway_batched,
    stream_twoway_delta,
)
from repro.stream.plan import StreamChunk, StreamPlan, fill_chunk  # noqa: F401
from repro.stream.prefetch import ShardPrefetcher  # noqa: F401

__all__ = [
    "StreamPlan",
    "StreamChunk",
    "fill_chunk",
    "ShardPrefetcher",
    "stream_twoway",
    "stream_threeway",
    "stream_twoway_batched",
    "stream_threeway_batched",
    "stream_twoway_delta",
]
