from repro.data.tokens import SyntheticTokens, PrefetchIterator  # noqa: F401
