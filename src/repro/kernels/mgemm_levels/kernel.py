"""Pallas TPU kernel: level-decomposition mGEMM on the MXU (beyond-paper).

For inputs quantized to integer levels {0, 1, ..., L}:

    min(a, b) = sum_{t=1}^{L} 1[a >= t] * 1[b >= t]

so the min-plus contraction equals a sum of L *ordinary* GEMMs of 0/1
indicator matrices — which the 128x128 MXU executes at bf16 peak
(197 TFLOP/s on v5e) instead of the ~1 TOP/s VPU rate of the faithful
kernel.  Exact for integer data with values <= L (SNP allele counts are
{0,1,2}; the paper's companion CCC work uses 2-3 bit codes).  This is the
TPU-native generalization of the paper's §2.3 observation that the binary
(Sorenson) case maps to fast bit arithmetic.

Indicator construction happens in VMEM per tile (on the VPU, overlapped by
the MXU matmuls), so HBM traffic is identical to a plain GEMM of the raw
operands.

Cost: L * 2*M*N*K MXU FLOPs; for L <= 4 a ~25-50x win over the VPU kernel on
the compute roofline term (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 512


def _levels_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k_steps: int, levels: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    acc = jnp.zeros_like(acc_ref)
    for t in range(1, levels + 1):  # static unroll: L MXU matmuls per tile
        at = (a >= t).astype(jnp.bfloat16)
        bt = (b >= t).astype(jnp.bfloat16)
        acc += jnp.dot(at, bt, preferred_element_type=jnp.float32)
    acc_ref[...] += acc

    @pl.when(pl.program_id(2) == n_k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("levels", "bm", "bn", "bk", "interpret", "out_dtype")
)
def mgemm_levels_pallas(
    A,
    B,
    *,
    levels: int,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
    out_dtype=jnp.float32,
):
    """Exact min-plus GEMM for integer-valued A, B in [0, levels]."""
    m, k = A.shape
    k2, n = B.shape
    assert k == k2
    mp, np_, kp = (-m) % bm, (-n) % bn, (-k) % bk
    if mp or kp:
        A = jnp.pad(A, ((0, mp), (0, kp)))  # pad 0 -> indicator 0 -> no contribution
    if np_ or kp:
        B = jnp.pad(B, ((0, kp), (0, np_)))
    M, K = A.shape
    N = B.shape[1]
    n_k_steps = K // bk
    grid = (M // bm, N // bn, n_k_steps)
    out = pl.pallas_call(
        functools.partial(_levels_kernel, n_k_steps=n_k_steps, levels=levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, t: (i, t)),
            pl.BlockSpec((bk, bn), lambda i, j, t: (t, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(A, B)
    return out[:m, :n]
