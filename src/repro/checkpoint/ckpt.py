"""Sharded, async, atomic checkpointing with elastic restore.

Layout per step:  <dir>/step_<N>/
    manifest.json   — tree structure, leaf shapes/dtypes, step, config hash
    leaf_<i>.npy    — one array per pytree leaf (gathered to host)

Guarantees
----------
* **atomic**: written to ``step_<N>.tmp`` then os.rename'd — a crash mid-save
  never corrupts the latest checkpoint.
* **async**: ``save()`` snapshots device arrays to host, hands off to a
  writer thread, and returns; ``wait()`` joins (the trainer overlaps the
  write with the next steps — the paper's §6.8 compute/output overlap point).
* **elastic**: leaves are stored unsharded; ``restore()`` re-device_puts them
  under *any* mesh/sharding, so a job can restart on a different topology
  (node-failure recovery: continue on fewer/more pods).
* **bit-exact resume**: tested — train N steps == train k, restart, train
  N-k steps, identical parameters.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- save --

    def save(self, step: int, tree: Any, blocking: bool = False):
        """Snapshot -> async write. tree: any pytree of arrays."""
        self.wait()  # one in-flight write at a time
        leaves, treedef = jax.tree.flatten(tree)
        host_leaves = [np.asarray(x) for x in leaves]  # device -> host snapshot
        self._thread = threading.Thread(
            target=self._write, args=(step, host_leaves, str(treedef)), daemon=True
        )
        self._thread.start()
        if blocking:
            self.wait()

    def _write(self, step: int, leaves, treedef_str: str):
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        for i, leaf in enumerate(leaves):
            np.save(os.path.join(tmp, f"leaf_{i}.npy"), leaf)
        manifest = {
            "step": step,
            "n_leaves": len(leaves),
            "treedef": treedef_str,
            "shapes": [list(x.shape) for x in leaves],
            "dtypes": [str(x.dtype) for x in leaves],
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.available_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # ---------------------------------------------------------- restore --

    def available_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.available_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: int | None = None, shardings=None):
        """Restore into the structure of `template`.

        shardings: optional matching pytree of Sharding — enables elastic
        restore onto a different mesh than the one that saved."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        leaves, treedef = jax.tree.flatten(template)
        assert manifest["n_leaves"] == len(leaves), "tree structure changed"
        loaded = [
            np.load(os.path.join(path, f"leaf_{i}.npy")) for i in range(len(leaves))
        ]
        for got, want in zip(loaded, leaves):
            assert tuple(got.shape) == tuple(want.shape), (got.shape, want.shape)
        if shardings is not None:
            flat_sh = treedef.flatten_up_to(shardings)
            arrs = [jax.device_put(x, s) for x, s in zip(loaded, flat_sh)]
        else:
            arrs = [jax.numpy.asarray(x) for x in loaded]
        return treedef.unflatten(arrs), step
