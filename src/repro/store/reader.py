"""Dataset reader: memory-mapped plane views + zero-encode campaign loading.

``DatasetReader`` serves the on-disk payloads three ways:

* ``shard(r)``  — one field shard ``(levels, kbs, n_v)``, an ``np.memmap``
  byte-range view by default (no copy, no decode): disk shard ``r`` IS the
  ``shard_planes_fields(planes, r, n_shards)`` range.
* ``planes()``  — the full ``(levels, kb, n_v)`` payload; zero-copy mmap
  for single-shard datasets, a byte-axis concatenation otherwise.
* ``packed()``  — a ``PackedPlanes`` handle the distributed engines accept
  directly: the campaign goes mmap -> ring with NO host-side encode
  (asserted via an encoder-call counter in tests/test_store.py).

``validate()`` recomputes the sha256 payload checksum, the stats sidecar
and every shape against the manifest.
"""
from __future__ import annotations

import os

import numpy as np

from repro.kernels.mgemm_levels import PackedPlanes
from repro.store.format import payload_checksum, read_manifest
from repro.store.writer import POPCOUNT

__all__ = ["DatasetReader"]


class DatasetReader:
    """Read-side handle on one dataset directory (manifest parsed eagerly,
    payloads mapped lazily)."""

    def __init__(self, path: str):
        self.path = path
        self.manifest = read_manifest(path)

    # -- manifest accessors -------------------------------------------------

    @property
    def levels(self) -> int:
        return self.manifest["levels"]

    @property
    def n_f(self) -> int:
        return self.manifest["n_f"]

    @property
    def n_v(self) -> int:
        return self.manifest["n_v"]

    @property
    def kb(self) -> int:
        return self.manifest["kb"]

    @property
    def n_shards(self) -> int:
        return self.manifest["n_shards"]

    # -- payload views ------------------------------------------------------

    def shard(self, rank: int, *, mmap: bool = True) -> np.ndarray:
        """(levels, kb/n_shards, n_v) uint8 — field shard ``rank``."""
        if not 0 <= rank < self.n_shards:
            raise ValueError(f"shard {rank} out of range [0, {self.n_shards})")
        target = os.path.join(self.path, self.manifest["shard_files"][rank])
        arr = np.load(target, mmap_mode="r" if mmap else None)
        want = (self.levels, self.kb // self.n_shards, self.n_v)
        if arr.shape != want or arr.dtype != np.uint8:
            raise ValueError(
                f"{target}: payload is {arr.dtype}{arr.shape}, manifest says "
                f"uint8{want}"
            )
        return arr

    def planes(self, *, mmap: bool = True) -> np.ndarray:
        """Full (levels, kb, n_v) payload (mmap view when single-shard)."""
        shards = [self.shard(r, mmap=mmap) for r in range(self.n_shards)]
        if len(shards) == 1:
            return shards[0]
        return np.concatenate(shards, axis=1)

    def packed(self, *, mmap: bool = True) -> PackedPlanes:
        """The engine-facing handle: planes + true field count + origin.

        The origin block carries the manifest's path/checksum/provenance
        with the payload, so result manifests can record the exact dataset
        bytes a campaign ran on without re-reading ``dataset.json``."""
        return PackedPlanes(
            planes=self.planes(mmap=mmap),
            n_f=self.n_f,
            origin={
                "path": self.path,
                "checksum": self.manifest["checksum"],
                "levels": self.levels,
                "source": self.manifest.get("source", {}),
            },
        )

    def stats(self) -> np.ndarray:
        """(levels, n_v) int64 per-plane popcounts (exact-stats sidecar).

        ``stats().sum(axis=0)`` is the per-vector column sum of the encoded
        matrix — the Czekanowski denominator stat.
        """
        target = os.path.join(self.path, self.manifest["stats_file"])
        arr = np.load(target)
        want = (self.levels, self.n_v)
        if arr.shape != want:
            raise ValueError(
                f"{target}: stats shape {arr.shape}, manifest says {want}"
            )
        return arr

    # -- integrity ----------------------------------------------------------

    def validate(self) -> dict:
        """Recompute checksum + stats from the payloads; raise on mismatch.

        One pass over the shards feeds both the sha256 and the popcount
        accumulator (mirroring the writer), so validation reads each shard
        from disk once.  Returns the manifest on success.
        """
        stats = np.zeros((self.levels, self.n_v), np.int64)

        def scan():
            for r in range(self.n_shards):
                shard = self.shard(r)
                np.add(stats, POPCOUNT[shard].sum(axis=1, dtype=np.int64),
                       out=stats)
                yield shard

        got = payload_checksum(scan())
        want = self.manifest["checksum"]
        if got != want:
            raise ValueError(
                f"{self.path}: payload checksum {got} != manifest {want}"
            )
        if not np.array_equal(stats, self.stats()):
            raise ValueError(f"{self.path}: stats sidecar does not match payload")
        return self.manifest
