"""llama3-8b [dense] — arXiv:2407.21783 (unverified tier).

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256, 128k vocab GQA.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    head_dim=128,
    rope_theta=5e5,
)

SMOKE = CONFIG.replace(
    name="llama3-8b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=160,
    vocab_size=256,
    head_dim=16,
)
