"""Serving engines.

Two request families share this module:

* ``ServeEngine`` — batched LM generation: prefill + greedy/temperature
  decode with KV (or SSM-state) caches and per-sequence stopping.  The
  decode loop is a single jit'd step over the full batch (static shapes);
  finished sequences keep decoding into a scratch slot but their outputs
  are frozen — the standard static-batch serving pattern.

* ``SimilarityService`` — similarity campaigns as a service: frozen
  ``SimilarityRequest``s go through the SAME ``repro.api.SimilarityEngine``
  the CLI and benchmarks use (one code path to validate), with engine reuse
  across requests sharing a device pool and an LRU result cache keyed by
  (request, input fingerprint) so repeated campaigns are free.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.models.common import ModelConfig
from repro.parallel.sharding import use_mesh


@dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 -> greedy
    eos_id: int = 2
    seed: int = 0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig | None = None,
                 mesh=None):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg or ServeConfig()
        self.mesh = mesh
        self._decode = jax.jit(
            lambda p, c, t, i: api.decode_step(cfg, p, c, t, i)
        )

    def _prefill(self, tokens):
        """Feed the prompt one block at a time through decode steps.

        For attention archs this fills the KV cache; a production prefill
        would batch the whole prompt (see launch/dryrun.py's prefill_step —
        the serving engine here favors simplicity on CPU)."""
        B, P = tokens.shape
        cache = api.init_cache(
            self.cfg, self.params, B, P + self.scfg.max_new_tokens
        )
        logits = None
        for i in range(P):
            logits, cache = self._decode(
                self.params, cache, tokens[:, i : i + 1], i
            )
        return logits, cache, P

    def generate(self, prompts: np.ndarray) -> np.ndarray:
        """prompts (B, P) int32 -> (B, max_new_tokens) int32."""
        scfg = self.scfg
        with use_mesh(self.mesh):
            logits, cache, pos = self._prefill(jnp.asarray(prompts))
            B = prompts.shape[0]
            out = np.zeros((B, scfg.max_new_tokens), np.int32)
            done = np.zeros((B,), bool)
            key = jax.random.PRNGKey(scfg.seed)
            tok = self._sample(logits, key)
            for t in range(scfg.max_new_tokens):
                out[:, t] = np.where(done, 0, np.asarray(tok[:, 0]))
                done |= np.asarray(tok[:, 0]) == scfg.eos_id
                if done.all():
                    break
                logits, cache = self._decode(self.params, cache, tok, pos + t)
                key, sub = jax.random.split(key)
                tok = self._sample(logits, sub)
        return out

    def _sample(self, logits, key):
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        scaled = logits[:, -1, :] / self.scfg.temperature
        return jax.random.categorical(key, scaled)[:, None].astype(jnp.int32)


class SimilarityService:
    """Similarity campaigns behind a serving front-end.

    Every request is executed by ``repro.api.SimilarityEngine`` — the exact
    code path of the CLI and benchmarks — so serving never drifts from the
    validated engines.  Results are LRU-cached by (request, input
    fingerprint); the engine itself caches meshes per decomposition, so a
    hot service reuses compiled programs across requests.
    """

    def __init__(self, max_cached_results: int = 16, devices=None):
        from repro.api import SimilarityEngine

        self.engine = SimilarityEngine(devices=devices)
        self.max_cached_results = max_cached_results
        self._results = OrderedDict()
        self.hits = 0
        self.misses = 0

    @staticmethod
    def _fingerprint(request, V) -> tuple:
        """(normalized request, campaign identity, payload hash).

        The campaign key — metric name(s) + subset (name, indices) pairs —
        is part of the cache identity: two requests over the same payload
        and decomposition that differ only in which campaigns they batch
        are DIFFERENT answers.  Normalizing the ``subsets`` field first
        (list indices, numpy ints) keeps equivalent requests hashable and
        cache-equal regardless of how the caller spelled the indices."""
        if request.subsets:
            from dataclasses import replace

            request = replace(request, subsets=request.campaign_subsets())
        ckey = request.campaign_key()
        if V is None:
            return (request, ckey, None)
        from repro.kernels.mgemm_levels.planes import PackedPlanes

        h = hashlib.sha256()
        if isinstance(V, PackedPlanes):
            # pre-encoded store input: key on the payload bytes + true n_f
            # (np.ascontiguousarray on the dataclass would hash object
            # pointers — unstable across materializations)
            h.update(f"planes:{V.n_f}".encode())
            V = V.planes
        a = np.ascontiguousarray(V)
        h.update(str(a.shape).encode())
        h.update(str(a.dtype).encode())
        h.update(a.tobytes())
        return (request, ckey, h.hexdigest())

    def submit(self, request, V=None):
        """Run (or serve from cache) one campaign — a ``SimilarityResult``,
        or a ``BatchedSimilarityResult`` for batched requests."""
        if V is None and request.input is not None:
            # materialize BEFORE fingerprinting: a request-only key would go
            # stale if the backing file (or generator defaults) changed
            V = request.input.materialize()
        key = self._fingerprint(request, V)
        if key in self._results:
            self.hits += 1
            self._results.move_to_end(key)
            return self._results[key]
        self.misses += 1
        result = self.engine.run(request, V)
        self._results[key] = result
        while len(self._results) > self.max_cached_results:
            self._results.popitem(last=False)
        return result

    def stats(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "cached_results": len(self._results),
        }
