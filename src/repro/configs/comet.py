"""The paper's own workload configs — selectable archs like the LM ones.

``comet_2way`` / ``comet_3way`` reproduce the paper's Titan weak-scaling
per-node shapes (§6.6/§6.7) on v5e pods.  The (n_pf, n_pv, n_pr)
decomposition follows the paper's tuning rules for the fixed chip counts
(256 single-pod / 512 multi-pod):

* 2-way (§6.6): n_pr = ceil((n_pv/2 + 1) / l) — we pick n_pr=4 so the ring
  has ~2x more steps than replicas (load l ~ 8-9 blocks/rank).
* 3-way (§6.7): n_pr soaks up (n_pv+1)(n_pv+2) slices; it GROWS with scale
  (the paper ran n_pr ~ 500 at 14880 nodes), keeping l ~ 10-20.
* metric outputs are bf16 on-device (the paper writes 1-byte metrics in
  production, §6.8); staging (n_st) bounds the per-stage output exactly as
  in the paper.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class CometArchConfig:
    name: str
    way: int  # 2 or 3
    n_f: int  # fields per vector
    n_vp: int  # vectors per pv-rank (weak scaling: fixed per rank)
    n_pf: int = 1
    n_pr_single: int = 4  # 256-chip decomposition: n_pv = 256/(n_pf*n_pr)
    n_pr_multi: int = 4  # 512-chip decomposition
    n_st: int = 1  # 3-way stages
    impl: str = "xla"
    levels: int | None = None  # set -> MXU level-decomposition path
    out_dtype: str = "bfloat16"
    ring_dtype: str = "float32"  # int8 -> 4x less ICI wire (exact for ints)

    @property
    def family(self) -> str:
        return "comet"

    def decomposition(self, chips: int, multi_pod: bool) -> tuple[int, int, int]:
        n_pr = self.n_pr_multi if multi_pod else self.n_pr_single
        n_pv = chips // (self.n_pf * n_pr)
        return self.n_pf, n_pv, n_pr


# Paper §6.6 single-precision case: n_f=10,000, n_vp=12,288 per rank.
CONFIG_2WAY = CometArchConfig(
    name="comet_2way", way=2, n_f=10000, n_vp=12288,
    n_pr_single=4, n_pr_multi=4,
)

# Paper §6.7: n_f=20,000, n_vp=2,880 per rank, staged.
# n_st=48 divides n_vp/6=480 (paper rule); pipeline depth 10 per stage.
CONFIG_3WAY = CometArchConfig(
    name="comet_3way", way=3, n_f=20000, n_vp=2880,
    n_pr_single=16, n_pr_multi=32, n_st=48,
)

# Beyond-paper MXU variants: SNP-style {0,1,2} data via level decomposition.
# (the 3-way inner GEMM also qualifies: X_j = min(V, v_j) keeps integer
# levels <= L, so B_j = X_j^T ∘min V decomposes identically.)
CONFIG_2WAY_MXU = CometArchConfig(
    name="comet_2way_mxu", way=2, n_f=10000, n_vp=12288,
    n_pr_single=4, n_pr_multi=4, impl="levels_xla", levels=2,
)  # int8 ring measured separately as the §Perf A3 variant (--override)
CONFIG_3WAY_MXU = CometArchConfig(
    name="comet_3way_mxu", way=3, n_f=20000, n_vp=2880,
    n_pr_single=16, n_pr_multi=32, n_st=48, impl="levels_xla", levels=2,
    ring_dtype="int8",
)

SMOKE_2WAY = CometArchConfig(name="comet_2way-smoke", way=2, n_f=64, n_vp=24,
                             n_pr_single=1, n_pr_multi=1, out_dtype="float32")
SMOKE_3WAY = CometArchConfig(name="comet_3way-smoke", way=3, n_f=32, n_vp=12,
                             n_pr_single=1, n_pr_multi=1, out_dtype="float32")
