"""jit'd wrappers for the fused 3-way step kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import (
    threeway_batch_levels_pallas,
    threeway_batch_pallas,
    threeway_step_pallas,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def threeway_step(own, x, right, *, combine, **kw):
    """Metric-generic fused 3-way pipeline step (X_j never touches HBM)."""
    kw.setdefault("interpret", not _on_tpu())
    return threeway_step_pallas(own, x, right, combine=combine, **kw)


def threeway_batch(own, X, right, *, combine, **kw):
    """All L pipeline columns of one slice in a single fused launch."""
    kw.setdefault("interpret", not _on_tpu())
    return threeway_batch_pallas(own, X, right, combine=combine, **kw)


def threeway_batch_levels(Pown, PX, Pright, **kw):
    """Level-decomposed batched slice on packed bit-planes (min combine):
    the X_j plane is a packed AND in VMEM, the contraction runs on the MXU."""
    kw.setdefault("interpret", not _on_tpu())
    return threeway_batch_levels_pallas(Pown, PX, Pright, **kw)


def czek3_step(own, x, right, **kw):
    kw.setdefault("combine", jnp.minimum)
    return threeway_step(own, x, right, **kw)
