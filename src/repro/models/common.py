"""Model configuration + shared utilities for the LM stack.

One ``ModelConfig`` covers every assigned architecture family:
dense / GQA decoder-only, MoE, SSM (Mamba2), hybrid (Zamba2), enc-dec
(Seamless), and the VLM/audio variants (stub frontends — ``input_specs``
provides precomputed patch/frame embeddings per the assignment).

Parameters are plain pytrees (nested dicts of jnp arrays).  Per-layer
parameters are **stacked along a leading layer axis** and consumed with
``jax.lax.scan`` — this keeps compiled HLO size O(1) in depth, which is what
makes the 512-device dry-run of 64-95 layer models compile in reasonable
time.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = Any  # nested dict pytree


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim (granite: 512)
    capacity_factor: float = 1.25
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 64
    # --- hybrid (Zamba2): one shared attention block every k SSM layers ---
    hybrid_attn_every: int = 0
    # --- encoder-decoder ---
    n_enc_layers: int = 0
    # --- misc ---
    qkv_bias: bool = False
    rope_theta: float = 1e4
    mrope_sections: tuple[int, ...] = ()  # M-RoPE (qwen2-vl): t/h/w dims
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    compute_dtype: str = "float32"  # bf16 for dry-run/production
    param_dtype: str = "float32"
    remat: str = "none"  # none | full | dots
    max_seq: int = 131072
    # --- perf knobs (§Perf hillclimbs; defaults = paper-faithful baseline) ---
    seq_parallel: bool = False  # Megatron-SP: residual sharded over "model"
    flash_p_bf16: bool = False  # bf16 attention probabilities in flash
    moe_dispatch_chunks: int = 0  # >0: chunk-local MoE sort/dispatch
    dp_only: bool = False  # ZeRO-3 axis remap: no TP, batch over all axes

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_full_attention(self) -> bool:
        """True if any layer attends over the full sequence quadratically."""
        return self.family != "ssm"

    @property
    def cdt(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def pdt(self):
        return jnp.dtype(self.param_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # --- SSM derived dims ---
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim


def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal fan-in init (matches common LM practice)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (scale * jax.random.truncated_normal(key, -2, 2, shape)).astype(dtype)


def stack_layer_params(layer_init_fn, n_layers: int, key):
    """Initialize n_layers layers and stack leaves along axis 0 (scan form)."""
    keys = jax.random.split(key, n_layers)
    return jax.vmap(layer_init_fn)(keys)


def param_count(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


def cast_tree(params, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), params)
