"""JAX version-compat shims.

The engines target the modern JAX surface (``jax.shard_map`` with
``check_vma``, ``jax.set_mesh``, ``jax.make_mesh(axis_types=...)``,
``jax.sharding.AxisType``), but must also run on the 0.4.x series where those
live under ``jax.experimental`` or do not exist.  Every version-sensitive
call site goes through this module so the rest of the codebase stays on one
spelling.
"""
from __future__ import annotations

from contextlib import contextmanager

import jax

__all__ = [
    "shard_map", "make_mesh", "set_mesh", "cost_analysis_dict", "AXIS_TYPE_AUTO"
]

# jax >= 0.6: AxisType enum exists and make_mesh accepts axis_types.
AXIS_TYPE_AUTO = getattr(getattr(jax, "sharding"), "AxisType", None)
if AXIS_TYPE_AUTO is not None:
    AXIS_TYPE_AUTO = AXIS_TYPE_AUTO.Auto


def shard_map(f, *, mesh, in_specs, out_specs, check=False):
    """``jax.shard_map`` (check_vma) with fallback to the experimental API
    (check_rep).  ``check`` maps onto whichever knob the version has."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check
        )
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check)


def make_mesh(axis_shapes, axis_names, *, devices=None, auto_axes=True):
    """``jax.make_mesh`` that requests Auto axis types when supported."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if auto_axes and AXIS_TYPE_AUTO is not None:
        kwargs["axis_types"] = (AXIS_TYPE_AUTO,) * len(tuple(axis_names))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict (0.4.x returns [dict])."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


@contextmanager
def set_mesh(mesh):
    """``jax.set_mesh`` when present, else the Mesh context manager."""
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield
    else:
        with mesh:
            yield
