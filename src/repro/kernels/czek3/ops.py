"""jit'd wrappers for the fused 3-way step kernel."""
from __future__ import annotations

import jax

from .kernel import czek3_step_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def czek3_step(own, x, right, **kw):
    kw.setdefault("interpret", not _on_tpu())
    return czek3_step_pallas(own, x, right, **kw)
