"""Gradient compression for cross-pod data parallelism: int8 quantization
with error feedback (EF-SGD style).

On a multi-pod run the "pod" axis all-reduce crosses the slow inter-pod
links; quantizing gradients to int8 with a per-tensor scale cuts that
traffic 4x (fp32) / 2x (bf16), and the residual (quantization error) is fed
back into the next step so the compression is unbiased in the long run.

Used by the trainer's manual-DP mode (shard_map over "pod"): gradients are
quantized, psummed over "pod" in int32, and dequantized.  Inside a pod the
full-precision GSPMD all-reduce is kept (ICI is fast).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize(x, *, bits: int = 8):
    """Symmetric per-tensor int quantization. Returns (q int8/int16, scale)."""
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    dt = jnp.int8 if bits <= 8 else jnp.int16
    return q.astype(dt), scale.astype(jnp.float32)


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_tree(grads, error):
    """Quantize (grads + error). Returns (q_tree, scales, new_error)."""
    def one(g, e):
        t = g.astype(jnp.float32) + e
        q, s = quantize(t)
        deq = dequantize(q, s)
        return q, s, t - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        treedef.unflatten([o[0] for o in out]),
        treedef.unflatten([o[1] for o in out]),
        treedef.unflatten([o[2] for o in out]),
    )


def allreduce_compressed(grads, error, axis: str):
    """psum int8 grads over `axis` (as int32 to avoid overflow), mean, dequant.

    Scales are psum-maxed so every pod dequantizes identically."""
    q, scales, new_error = compress_tree(grads, error)
    n = jax.lax.psum(1, axis)
    scale_max = jax.tree.map(lambda s: jax.lax.pmax(s, axis), scales)
    # requantize against the shared scale so the sum is consistent
    def resum(qi, s_local, s_shared):
        v = dequantize(qi, s_local)
        q2 = jnp.clip(jnp.round(v / s_shared), -127, 127).astype(jnp.int32)
        total = jax.lax.psum(q2, axis)
        return total.astype(jnp.float32) * s_shared / n

    mean = jax.tree.map(resum, q, scales, scale_max)
    return mean, new_error
