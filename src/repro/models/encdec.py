"""Encoder-decoder transformer (Seamless-M4T backbone).

Per the assignment the modality frontend is a STUB: ``input_specs`` provides
precomputed speech-frame embeddings to the encoder (``src_embeds``); the text
decoder is a standard causal transformer with cross-attention.  Both stacks
are scanned.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models.common import ModelConfig, dense_init, stack_layer_params
from repro.models.norms import rms_norm
from repro.models.rope import rope_angles
from repro.parallel.sharding import DATA_AXES, shard


def _init_enc_layer(cfg: ModelConfig, key):
    ka, kf = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,), cfg.pdt),
        "attn": attn_mod.init_attention(cfg, ka),
        "ln2": jnp.ones((cfg.d_model,), cfg.pdt),
        "mlp": mlp_mod.init_mlp(cfg, kf),
    }


def _init_dec_layer(cfg: ModelConfig, key):
    ka, kx, kf = jax.random.split(key, 3)
    return {
        "ln1": jnp.ones((cfg.d_model,), cfg.pdt),
        "attn": attn_mod.init_attention(cfg, ka),
        "lnx": jnp.ones((cfg.d_model,), cfg.pdt),
        "xattn": attn_mod.init_attention(cfg, kx, cross=True),
        "ln2": jnp.ones((cfg.d_model,), cfg.pdt),
        "mlp": mlp_mod.init_mlp(cfg, kf),
    }


def init_encdec(cfg: ModelConfig, key):
    ke, k1, k2, kh = jax.random.split(key, 4)
    return {
        "embed": dense_init(ke, (cfg.vocab_size, cfg.d_model), cfg.pdt, scale=0.02),
        "enc_layers": stack_layer_params(
            partial(_init_enc_layer, cfg), cfg.n_enc_layers, k1
        ),
        "enc_ln": jnp.ones((cfg.d_model,), cfg.pdt),
        "dec_layers": stack_layer_params(
            partial(_init_dec_layer, cfg), cfg.n_layers, k2
        ),
        "final_ln": jnp.ones((cfg.d_model,), cfg.pdt),
        "lm_head": dense_init(kh, (cfg.d_model, cfg.vocab_size), cfg.pdt),
    }


def encode(cfg: ModelConfig, params, src_embeds):
    """src_embeds (B, S_src, D) — stub frontend output.  Bidirectional."""
    x = shard(src_embeds.astype(cfg.cdt), DATA_AXES, None, None)
    B, S, _ = x.shape
    pos = jnp.arange(S)[None, :] * jnp.ones((B, 1), jnp.int32)
    cos_sin = rope_angles(pos, cfg.hd, cfg.rope_theta)

    def body(x, lp):
        h, _ = attn_mod.attention(
            cfg, lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps),
            cos_sin=cos_sin, causal=False,
        )
        x = x + h
        x = x + mlp_mod.mlp(cfg, lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
        return x, None

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rms_norm(x, params["enc_ln"], cfg.norm_eps)


def _dec_block(cfg, lp, x, enc, cos_sin, cache=None, cache_index=None):
    h, new_kv = attn_mod.attention(
        cfg, lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps),
        cos_sin=cos_sin, cache=cache, cache_index=cache_index,
    )
    x = x + h
    h, _ = attn_mod.attention(
        cfg, lp["xattn"], rms_norm(x, lp["lnx"], cfg.norm_eps),
        kv_src=enc, causal=False,
    )
    x = x + h
    x = x + mlp_mod.mlp(cfg, lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
    return x, new_kv


def encdec_forward(cfg: ModelConfig, params, src_embeds, tgt_tokens):
    """Returns (logits (B, S_tgt, V), aux)."""
    enc = encode(cfg, params, src_embeds)
    x = params["embed"][tgt_tokens].astype(cfg.cdt)
    x = shard(x, DATA_AXES, None, None)
    B, S, _ = x.shape
    pos = jnp.arange(S)[None, :] * jnp.ones((B, 1), jnp.int32)
    cos_sin = rope_angles(pos, cfg.hd, cfg.rope_theta)

    def body(x, lp):
        x, _ = _dec_block(cfg, lp, x, enc, cos_sin)
        return x, None

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = x @ params["lm_head"].astype(cfg.cdt)
    return shard(logits, DATA_AXES, None, "model"), jnp.zeros((), jnp.float32)


def encdec_loss(cfg: ModelConfig, params, batch):
    from repro.models.transformer import sharded_xent

    logits, _ = encdec_forward(cfg, params, batch["src_embeds"], batch["tokens"])
    return sharded_xent(logits, batch["labels"], batch.get("mask"))


def encdec_decode_step(cfg: ModelConfig, params, cache, tokens, cache_index):
    """One decoder step against a frozen encoder memory kept in the cache."""
    enc = cache["enc"]
    x = params["embed"][tokens].astype(cfg.cdt)
    B, S = tokens.shape
    pos = cache_index + jnp.arange(S)[None, :] + jnp.zeros((B, 1), jnp.int32)
    cos_sin = rope_angles(pos, cfg.hd, cfg.rope_theta)

    def body(x, inp):
        lp, kvc = inp
        x, new_kv = _dec_block(cfg, lp, x, enc, cos_sin,
                               cache=kvc, cache_index=cache_index)
        return x, new_kv

    x, new_kv = jax.lax.scan(body, x, (params["dec_layers"], cache["kv"]))
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = x @ params["lm_head"].astype(cfg.cdt)
    return logits, {"enc": enc, "kv": new_kv}


def init_encdec_cache(cfg: ModelConfig, params, src_embeds, batch: int, max_len: int):
    enc = encode(cfg, params, src_embeds)
    kv = attn_mod.init_kv_cache(cfg, batch, max_len, cfg.n_layers, cfg.cdt)
    return {"enc": enc, "kv": kv}
