"""Paper Table 6: comparisons/sec normalized against hardware peak.

The paper normalizes absolute comparison rate by the hardware's peak flop
rate to compare across systems (CoMet 2-way SP: 0.169, 3-way SP: 0.213).
We compute the same normalized ratio for (a) this container's CPU run and
(b) the modeled v5e numbers from the dry-run artifacts, and reprint the
paper's table rows for context.
"""
from __future__ import annotations

import glob
import json
import os

import jax.numpy as jnp

from benchmarks.util import row, time_fn
from repro.core.mgemm import mgemm_xla
from repro.core.synthetic import random_integer_vectors
from repro.roofline.analysis import HW_V5E

HERE = os.path.dirname(os.path.abspath(__file__))
DRYRUN = os.path.join(HERE, "..", "results", "dryrun")

PAPER_TABLE6 = [
    ("haque2011_cpu_1bit", 222e9, 42.56e9, 5.216),
    ("gwisfi_gtx470", 767e9, 1088.6e9, 0.705),
    ("comet_2way_sp_17472xK20X", 4.29e15, 25.3e15, 0.169),
    ("comet_3way_sp_18424xK20X", 5.70e15, 26.7e15, 0.213),
]

CPU_PEAK_EST = 5e10  # single-core fp32 est (AVX2-ish) for normalization


def main():
    rows = []
    for name, cmp_s, peak, norm in PAPER_TABLE6:
        rows.append(row(f"table6/paper/{name}", 0.0, f"norm_perf={norm:.3f}"))

    V = jnp.asarray(random_integer_vectors(1024, 768, seed=0))
    t = time_fn(lambda v: mgemm_xla(v.T, v), V)
    rate = 1024 * 768 * 768 / t
    rows.append(row("table6/this_cpu_core", t,
                    f"norm_perf={rate / CPU_PEAK_EST:.3f}"))

    for path in sorted(glob.glob(os.path.join(DRYRUN, "comet_*.json"))):
        with open(path) as f:
            r = json.load(f)
        terms = r["roofline"]
        t_bound = max(terms["t_compute"], terms["t_memory"], terms["t_collective"])
        comps = r.get("elementwise_comparisons", 0)
        if not comps or t_bound <= 0:
            continue
        chips = terms["n_devices"]
        rate = comps / t_bound
        norm = rate / (chips * HW_V5E.peak_flops)
        tag = os.path.basename(path).replace(".json", "")
        rows.append(row(f"table6/v5e_model/{tag}", t_bound,
                        f"norm_perf={norm:.3f}"))
    return rows


if __name__ == "__main__":
    from benchmarks.util import print_rows

    print_rows(main())
