"""End-to-end PheWAS-style similarity campaign (paper §6.8 workflow).

Synthetic SNP association profiles (values {0,1,2} like allele counts) ->
distributed 2-way Czekanowski metrics on the MXU-exact level-decomposition
path -> thresholded output written per-rank with a manifest + exact
checksum -> staged 3-way pass over the strongest cluster.

    PYTHONPATH=src python examples/genomics_phewas.py [--n-v 600] [--n-f 385]
"""
import argparse
import json
import os

import numpy as np

from repro.core import checksum as ck
from repro.core.synthetic import random_integer_vectors
from repro.core.threeway import czek3_distributed
from repro.core.twoway import CometConfig, czek2_distributed
from repro.parallel.mesh import make_comet_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-v", type=int, default=600)
    ap.add_argument("--n-f", type=int, default=385)  # the paper's real n_f
    ap.add_argument("--threshold", type=float, default=0.8)
    ap.add_argument("--out", default="/tmp/phewas_campaign")
    args = ap.parse_args()

    # {0,1,2} allele-count-like profiles: exact on the levels (MXU) path
    V = random_integer_vectors(args.n_f, args.n_v, max_value=2, seed=11)
    mesh = make_comet_mesh(1, 1, 1)
    cfg = CometConfig(impl="levels_xla", levels=2, out_dtype="float32")

    out = czek2_distributed(V, mesh, cfg)
    os.makedirs(args.out, exist_ok=True)
    n_hits = 0
    parts = []
    hits = []
    for I, J, W in out.entries():
        parts.append(ck.raw_pairs(I, J, W))
        sel = W >= args.threshold
        n_hits += int(sel.sum())
        hits.extend(zip(I[sel].tolist(), J[sel].tolist(), W[sel].tolist()))
        # paper §6.8: metrics written as single bytes (~2.5 sig figs)
    u8 = {(i, j): int(w * 255 + 0.5) for i, j, w in hits}
    with open(os.path.join(args.out, "hits_u8.json"), "w") as f:
        json.dump({f"{i},{j}": v for (i, j), v in u8.items()}, f)
    checksum = ck.combine(parts)
    manifest = {
        "n_f": args.n_f, "n_v": args.n_v,
        "pairs": out.num_pairs(), "hits": n_hits,
        "threshold": args.threshold, "checksum": hex(checksum),
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(json.dumps(manifest, indent=2))

    # 3-way follow-up on the densest hub vectors (staged like the paper)
    deg = np.zeros(args.n_v, int)
    for i, j, _ in hits:
        deg[i] += 1
        deg[j] += 1
    hub = np.argsort(-deg)[:36]
    cfg3 = CometConfig(n_st=2, out_dtype="float32")
    total = 0
    for stage in range(2):
        out3 = czek3_distributed(V[:, hub], mesh, cfg3, stage=stage)
        total += out3.num_triples()
        print(f"stage {stage}: {out3.num_triples()} triples")
    print(f"3-way follow-up on {len(hub)} hub vectors: {total} unique triples")


if __name__ == "__main__":
    main()
