"""granite-moe-3b-a800m [moe] — hf:ibm-granite (hf-verified).

32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155, MoE 40 experts top-8.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    head_dim=64,
    n_experts=40,
    experts_per_token=8,
    moe_d_ff=512,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="granite-moe-3b-a800m-smoke",
    n_layers=2,
    d_model=48,
    n_heads=4,
    n_kv_heads=2,
    d_ff=64,
    vocab_size=256,
    head_dim=12,
    n_experts=5,
    experts_per_token=2,
    moe_d_ff=64,
)
