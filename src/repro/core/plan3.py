"""3-way tetrahedral schedule — paper §4.2, Figures 3-5, Algorithms 2-3.

The result cube (n_v^3, symmetric under all 6 permutations) is decomposed
into slabs by the vector-number axis: slab i = blocks (i, *, *).  Within a
slab, three block types are computed (paper Figure 5):

* DIAG  — block (i, i, i): the strict tetrahedron a < b < c, computed as six
          pipeline slices along the j axis.
* FACE  — blocks (i, J, J), J != i: triples (1 in own block, 2 in J) with the
          prism region {b < c}, computed as six pipeline slices along J.
          (This is the paper's "fold of the three diagonal planes into a
          single plane with full-height prisms".)
* VOL   — blocks (i, J, K), i, J, K distinct: exactly one 1/6-thickness slice
          whose *orientation* (which axis is sliced) and *placement* (which
          sixth) depend on the block's location — paper Figure 5(c).

Our concrete VOL rule (verified exhaustively in tests/test_plan3.py):
  let (A < B < C) = sorted block ids of {i, J, K}; slice the axis that holds
  the *middle* id B, at position perm_rank(i, J, K) in {0..5} (the index of
  the ordering pattern among the 6 permutations).  For a triple
  (x in A, y in B, z in C) the middle-id axis always carries y, and the six
  permutation-image blocks test y against six disjoint sixths, so every
  unique triple is computed exactly once.

Work accounting per slab: 6 + 6(n_pv-1) + (n_pv-1)(n_pv-2)
= (n_pv+1)(n_pv+2) slices — the paper's slice count — distributed round-robin
over the n_pr axis in Algorithm-2 order.

Staging (paper §4.2): each slice's pipeline axis range (a sixth of the block,
length n_vp/6) is subdivided into n_st stages; a run computes one stage,
pipeline length n_vp/(6*n_st) — exactly Algorithm 3's
j_min = floor((s_t + n_st*s) * n_vp / (6*n_st)).
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from enum import IntEnum

import numpy as np

__all__ = ["ItemKind", "ThreeWayItem", "ThreeWayPlan", "vol_slice_rule", "PERMS"]

PERMS = list(itertools.permutations((0, 1, 2)))


class ItemKind(IntEnum):
    DIAG = 0
    FACE = 1
    VOL = 2


def vol_slice_rule(own: int, bj: int, bk: int) -> tuple[int, int]:
    """(slice_axis, slice_idx) for a volume block (own; bj, bk).

    slice_axis: 0 = own/i axis, 1 = j axis, 2 = k axis — the axis holding the
    middle sorted block id.  slice_idx in 0..5 — the permutation rank.
    """
    ids = (own, bj, bk)
    order = tuple(sorted(ids).index(x) for x in ids)  # rank of each id
    slice_axis = order.index(1)  # position of the middle id
    slice_idx = PERMS.index(order)
    return slice_axis, slice_idx


@dataclass(frozen=True)
class ThreeWayItem:
    kind: ItemKind
    dj: int  # ring offset of block J (0 for DIAG)
    dk: int  # ring offset of block K (0 for DIAG, == dj for FACE)
    slice_axis: int  # which axis the sixth applies to (pipeline axis)
    slice_idx: int  # which sixth (0..5)
    sb: int  # Algorithm-2 global slice counter (round-robin key)

    def blocks(self, p_v: int, n_pv: int) -> tuple[int, int, int]:
        return (p_v, (p_v + self.dj) % n_pv, (p_v + self.dk) % n_pv)


@dataclass(frozen=True)
class ThreeWayPlan:
    n_pv: int
    n_pr: int
    n_st: int = 1  # stages; engine computes one stage per run

    @property
    def items_per_slab(self) -> int:
        return (self.n_pv + 1) * (self.n_pv + 2)

    @property
    def slots_per_rank(self) -> int:
        return math.ceil(self.items_per_slab / self.n_pr)

    @property
    def ring_steps(self) -> int:
        """Payload ppermutes per rank across one stage of the doubly-nested
        traversal: the face phase advances the J payload ``n_pv`` times
        (n_pv - 1 hops plus the realign hop back to dj = 1) and the volume
        phase's inner loop advances K ``(n_pv - 1)(n_pv + 1)`` times
        (n_pv + 1 inner hops — including the per-row realign — for each of
        the n_pv - 1 outer rows).  ``n_pv == 1`` has no off-rank blocks and
        never ppermutes.  Batched-campaign accounting only; independent of
        metric count by construction."""
        if self.n_pv == 1:
            return 0
        return self.n_pv + (self.n_pv - 1) * (self.n_pv + 1)

    def slab_items(self) -> list[ThreeWayItem]:
        """All items of one slab in Algorithm-2 order (same for every slab
        modulo the ring offsets, which is what makes the schedule SPMD)."""
        items: list[ThreeWayItem] = []
        sb = 0
        # 1) diagonal-edge block, six slices along the pipeline (j) axis
        for s in range(6):
            items.append(ThreeWayItem(ItemKind.DIAG, 0, 0, 1, s, sb))
            sb += 1
        # 2) face blocks (own; J, J), six slices each
        for s in range(6):
            for dj in range(1, self.n_pv):
                items.append(ThreeWayItem(ItemKind.FACE, dj, dj, 1, s, sb))
                sb += 1
        # 3) volume blocks, one oriented slice each
        for dk in range(1, self.n_pv):
            for dj in range(1, self.n_pv):
                if dj == dk:
                    continue
                # axis/idx depend on the *global* block ids, hence on p_v; we
                # store placeholders (-1) and resolve per-rank in items_of().
                items.append(ThreeWayItem(ItemKind.VOL, dj, dk, -1, -1, sb))
                sb += 1
        assert sb == self.items_per_slab
        return items

    def items_of(self, p_v: int, p_r: int) -> list[ThreeWayItem]:
        """Resolved items executed by rank (p_v, p_r)."""
        out = []
        for it in self.slab_items():
            if it.sb % self.n_pr != p_r:
                continue
            if it.kind == ItemKind.VOL:
                own, bj, bk = it.blocks(p_v, self.n_pv)
                ax, idx = vol_slice_rule(own, bj, bk)
                it = ThreeWayItem(it.kind, it.dj, it.dk, ax, idx, it.sb)
            out.append(it)
        return out

    # -- index geometry ---------------------------------------------------

    def sixth_bounds(self, n_vp: int, slice_idx: int, stage: int) -> tuple[int, int]:
        """Pipeline index range [lo, hi) for (sixth, stage) — Algorithm 3."""
        denom = 6 * self.n_st
        lo = (stage + self.n_st * slice_idx) * n_vp // denom
        hi = (stage + 1 + self.n_st * slice_idx) * n_vp // denom
        return lo, hi

    def item_cells(
        self, p_v: int, it: ThreeWayItem, n_vp: int, stage: int
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Global index arrays (I, J, K) of every result cell the item
        computes in the given stage — for verification.  Shapes (pipe, l, r)
        flattened after masking."""
        own, bj, bk = it.blocks(p_v, self.n_pv)
        lo, hi = self.sixth_bounds(n_vp, it.slice_idx, stage)
        pipe = np.arange(lo, hi)
        full = np.arange(n_vp)
        if it.kind == ItemKind.DIAG:
            # pipe j in own sixth; rows i < j; cols k > j (all own block)
            P = pipe[:, None, None]
            I = full[None, :, None]
            K = full[None, None, :]
            mask = (I < P) & (K > P)
            gi = own * n_vp + np.broadcast_to(I, mask.shape)[mask]
            gj = own * n_vp + np.broadcast_to(P, mask.shape)[mask]
            gk = own * n_vp + np.broadcast_to(K, mask.shape)[mask]
            return gi, gj, gk
        if it.kind == ItemKind.FACE:
            # pipe b in J sixth; rows a in own (full); cols c in J with c > b
            P = pipe[:, None, None]
            A = full[None, :, None]
            C = full[None, None, :]
            mask = np.broadcast_to(C > P, (len(pipe), n_vp, n_vp))
            gi = own * n_vp + np.broadcast_to(A, mask.shape)[mask]
            gj = bj * n_vp + np.broadcast_to(P, mask.shape)[mask]
            gk = bj * n_vp + np.broadcast_to(C, mask.shape)[mask]
            return gi, gj, gk
        # VOL: sixth applies to the axis holding the middle block id
        axes = [full, full, full]
        axes[it.slice_axis] = pipe
        A, B, C = np.meshgrid(axes[0], axes[1], axes[2], indexing="ij")
        gi = own * n_vp + A.ravel()
        gj = bj * n_vp + B.ravel()
        gk = bk * n_vp + C.ravel()
        return gi, gj, gk

    def work_per_rank(self) -> np.ndarray:
        w = np.zeros((self.n_pr,), np.int64)
        for it in self.slab_items():
            w[it.sb % self.n_pr] += 1
        return w
