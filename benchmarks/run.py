"""Benchmark driver — one module per paper table/figure, plus the ``api``
module covering the unified SimilarityEngine per registered metric.

Prints ``name,us_per_call,derived`` CSV.  Scaling (Figs 6-10) runs in a
subprocess with 8 virtual devices; everything else runs on this process's
single device.  Dry-run-derived rows appear when results/dryrun is populated
(python -m repro.launch.dryrun --all).

Also writes ``BENCH_kernels.json`` at the repo root — the impl × size kernel
sweep (GiB/s and comparisons/s per entry) that anchors the perf trajectory:
future PRs regress their kernel changes against the last committed numbers.

CLI (so CI can smoke the sweep at tiny shapes and validate the schema):

    python -m benchmarks.run --kernels-only --shapes 32,64,32 --out /tmp/b.json
    python -m benchmarks.run --validate BENCH_kernels.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

BENCH_KERNELS = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_kernels.json",
)

#: every impl the sweep may emit; --validate rejects anything else so the
#: perf-trajectory file cannot silently rot.  "host_encode"/"store_load"
#: are the ingest entries (repro.store): matrix -> campaign-ready packed
#: planes via the host encoder vs the on-disk dataset store.
#: "stream"/"stream_seq" are the out-of-core overlap entries
#: (repro.stream): the double-buffered prefetch pipeline vs the same
#: chunks staged and contracted serially.
#: "popcount" is the binary (levels=1) bit-GEMM fast path
#: (repro.kernels.popgemm) — its entries carry "levels": 1, alongside
#: levels=1 "fused-levels"/"levels_xla" rows on the same binary operands.
#: "batched"/"batched_seq" are the batched-campaign entries (multi-metric
#: x multi-subset through one ring traversal vs the sequential loop it
#: replaces) — they carry "campaigns".
KNOWN_IMPLS = {
    "xla", "levels_xla", "levels_xla_hoisted", "levels",
    "pallas", "pallas_fused", "fused-levels", "popcount",
    "host_encode", "store_load",
    "stream", "stream_seq",
    "batched", "batched_seq",
}
_ENTRY_NUMBER_KEYS = ("seconds", "gib_per_s", "comparisons_per_s")
_ENTRY_INT_KEYS = ("m", "k", "n")


def validate_bench_kernels(path: str) -> None:
    """Raise ValueError unless ``path`` is a well-formed kernel-sweep file."""
    with open(path) as f:
        payload = json.load(f)
    for key in ("backend", "note", "entries"):
        if key not in payload:
            raise ValueError(f"{path}: missing top-level key {key!r}")
    if not isinstance(payload["entries"], list) or not payload["entries"]:
        raise ValueError(f"{path}: 'entries' must be a non-empty list")
    for i, e in enumerate(payload["entries"]):
        if e.get("impl") not in KNOWN_IMPLS:
            raise ValueError(
                f"{path}: entries[{i}] impl {e.get('impl')!r} not in "
                f"{sorted(KNOWN_IMPLS)}"
            )
        for key in _ENTRY_INT_KEYS:
            if not isinstance(e.get(key), int) or e[key] <= 0:
                raise ValueError(f"{path}: entries[{i}].{key} must be a "
                                 f"positive int, got {e.get(key)!r}")
        for key in _ENTRY_NUMBER_KEYS:
            v = e.get(key)
            if not isinstance(v, (int, float)) or not v > 0:
                raise ValueError(f"{path}: entries[{i}].{key} must be a "
                                 f"positive number, got {v!r}")
        obs = e.get("obs")
        if obs is not None:  # optional per-phase breakdown (traced rerun)
            if not isinstance(obs, dict) \
                    or not isinstance(obs.get("phases"), dict):
                raise ValueError(f"{path}: entries[{i}].obs must be a dict "
                                 "with a 'phases' dict")
            for pname, secs in obs["phases"].items():
                if not isinstance(pname, str) \
                        or not isinstance(secs, (int, float)) or secs < 0:
                    raise ValueError(
                        f"{path}: entries[{i}].obs.phases[{pname!r}] must "
                        f"be a non-negative number, got {secs!r}"
                    )


def _parse_shapes(text: str):
    """'m,k,n[;m,k,n...]' -> [(m, k, n), ...]"""
    shapes = []
    for part in text.split(";"):
        dims = tuple(int(x) for x in part.split(","))
        if len(dims) != 3:
            raise ValueError(f"shape {part!r} is not m,k,n")
        shapes.append(dims)
    return shapes


def write_bench_kernels(shapes=None, out: str = BENCH_KERNELS,
                        max_value: int = 3) -> str:
    import jax

    from benchmarks.bench_kernel import (
        BATCHED_SHAPE,
        INGEST_SHAPES,
        STREAM_SHAPE,
        SWEEP_SHAPES,
        batched_sweep,
        binary_sweep,
        ingest_entries,
        kernel_sweep,
        stream_entries,
    )

    payload = {
        "backend": jax.default_backend(),
        "note": "pallas* entries run in interpret mode off-TPU; "
                "host_encode/store_load are ingest entries "
                "(comparisons_per_s = matrix elements ingested per second); "
                "stream/stream_seq are out-of-core overlap entries with "
                "staging floored to bench_kernel.STREAM_MODEL_MIB_S; "
                "entries with levels=1 are the binary sweep (popcount "
                "bit-GEMM vs the bf16 plane kernels on {0,1} data); "
                "batched/batched_seq entries (tagged 'campaigns') run one "
                "multi-metric x multi-subset job through one ring traversal "
                "vs the sequential per-campaign loop",
        "entries": (kernel_sweep(shapes or SWEEP_SHAPES, max_value=max_value)
                    + binary_sweep(shapes or SWEEP_SHAPES)
                    + ingest_entries(shapes or INGEST_SHAPES,
                                     max_value=max_value)
                    + stream_entries(shapes[-1] if shapes else STREAM_SHAPE,
                                     max_value=max_value)
                    + batched_sweep(shapes[-1] if shapes
                                    else BATCHED_SHAPE)),
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--shapes", default="",
                    help="kernel-sweep shapes m,k,n[;m,k,n...] "
                         "(default: the built-in grid)")
    ap.add_argument("--max-value", type=int, default=3,
                    help="synthetic integer level ceiling for the sweep")
    ap.add_argument("--out", default=BENCH_KERNELS,
                    help="where to write the kernel-sweep JSON")
    ap.add_argument("--kernels-only", action="store_true",
                    help="only run the kernel sweep (skip paper tables)")
    ap.add_argument("--validate", metavar="PATH", default="",
                    help="validate a kernel-sweep JSON schema and exit")
    args = ap.parse_args(argv)

    if args.validate:
        validate_bench_kernels(args.validate)
        print(f"{args.validate}: schema OK")
        return

    shapes = _parse_shapes(args.shapes) if args.shapes else None
    if args.kernels_only:
        path = write_bench_kernels(shapes, args.out, args.max_value)
        validate_bench_kernels(path)
        print(f"wrote {path}")
        return

    _run_all(shapes, args.out, args.max_value)


def _run_all(shapes, out, max_value) -> None:
    from benchmarks import (
        bench_accel_ratio,
        bench_kernel,
        bench_max_rates,
        bench_metrics,
        bench_normalized,
        bench_phewas_sample,
        bench_scaling,
        roofline_report,
    )
    from benchmarks.util import print_rows

    modules = [
        ("table1", bench_kernel),
        ("api", bench_metrics),
        ("table2", bench_accel_ratio),
        ("fig6-10", bench_scaling),
        ("table3-4", bench_max_rates),
        ("table5", bench_phewas_sample),
        ("table6", bench_normalized),
        ("roofline", roofline_report),
    ]
    failed = []
    for name, mod in modules:
        try:
            rows = mod.main()
            if rows:
                print_rows(rows)
        except Exception:
            traceback.print_exc()
            failed.append(name)
    try:
        path = write_bench_kernels(shapes, out, max_value)
        validate_bench_kernels(path)
        print(f"wrote {path}")
    except Exception:
        traceback.print_exc()
        failed.append("bench-kernels-json")
    if failed:
        print(f"FAILED: {failed}")
        sys.exit(1)


if __name__ == "__main__":
    main()
