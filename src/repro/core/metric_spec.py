"""Metric specification: what the distributed engines need to know about a
similarity metric.

The paper's engines hard-coded Proportional Similarity (Czekanowski): a
min-plus contraction for numerators, row sums ring-carried for denominators,
and the ``2n/d`` / ``1.5 n3/d3`` assemblies.  Its companion paper (Joubert et
al., arXiv:1705.08213) runs the *same* decomposition/ring machinery for a
different metric — so the machinery is parameterized here by a ``MetricSpec``:

* ``combine``   — the elementwise pairing op folded into the inner GEMM
                  (``min`` for Czekanowski, ``*`` for correlation-family).
* ``stat``      — the per-vector statistic psummed over "pf" and ring-carried
                  alongside V (row sums / sums of squares).
* ``contract``  — the (m, k) x (k, n) "GEMM-like" numerator contraction
                  ``sum_q combine(A[i, q], B[q, j])``; Czekanowski dispatches
                  through the mgemm impl registry (XLA / Pallas / levels),
                  dot-product metrics hit the plain MXU GEMM.
* ``assemble2`` / ``assemble3`` — numerator(s) + stats -> metric values.
* ``assemble_tile`` — the Pallas-composable 2-way epilogue: the same
                  arithmetic as ``assemble2`` restricted to ops that lower
                  inside a kernel flush (elementwise jnp on the accumulator
                  tile and broadcast-ready stat tiles).  When present (and
                  ``combine_sum_contract`` holds) the ``TileExecutor``
                  generates the fused metric kernel for the metric — the
                  numerator tile is divided in VMEM and never round-trips
                  through HBM.  Denominators MUST go through ``safe_denom``
                  so the kernel path guards all-zero vectors identically to
                  the XLA path.

The Czekanowski spec below reproduces the pre-refactor engines' arithmetic
op-for-op, so every campaign checksum is bit-identical to the inlined code it
replaced (verified in tests/distributed_harness.py).

The registry that maps metric *names* to specs lives in ``repro.api.registry``
(the user-facing layer); this module only defines the contract and the
built-in Czekanowski entry the core engines default to.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax.numpy as jnp

from repro.core.metrics import safe_denom

__all__ = [
    "MetricSpec",
    "CZEKANOWSKI",
    "czek_assemble_tile",
    "family_key",
    "group_families",
    "plane_native",
    "batch_lead",
]


@dataclass(frozen=True)
class MetricSpec:
    """Everything the 2-way/3-way distributed programs need for one metric."""

    name: str
    description: str = ""
    ways: tuple = (2, 3)
    #: elementwise combine op used to build the 3-way batched contraction
    combine: Callable = jnp.minimum
    #: (n_fp, m) local block -> (m,) per-vector statistic (pre-psum)
    stat: Callable = None
    #: (n2, s_i, s_j) -> 2-way metric values (broadcast-ready stats)
    assemble2: Callable = None
    #: (b3, n2_pl, n2_pr, n2_lr, s_p, s_l, s_r) -> (L, m, m) 3-way values
    assemble3: Callable = None
    #: (acc, sa, sb) -> 2-way values, composable inside a Pallas kernel
    #: flush (acc (bm, bn) fp32, sa (bm, 1), sb (1, bn)); None disables the
    #: fused-epilogue path for this metric
    assemble_tile: Callable = None
    #: the numerator contraction equals the plain sum-over-combine reduction
    #: ``sum_q combine(A[i, q], B[q, j])`` — true for min-plus (Czekanowski)
    #: and dot-product (CCC) metrics; required for the fused Pallas kernels,
    #: which realize the contraction exactly that way.  ``None`` (default)
    #: auto-derives: True iff the metric has no custom ``contract`` (mgemm
    #: dispatch and the generic combine-sum fallback both qualify), so a
    #: registered metric with an unrelated contraction is never silently
    #: routed to the fused kernels; set True explicitly when the custom
    #: contract IS a combine-sum (e.g. a plain dot).
    combine_sum_contract: bool = None
    #: route the contraction through the mgemm impl registry (CometConfig.impl)
    uses_mgemm: bool = False
    #: fixed contraction when not using the registry (e.g. a plain dot)
    contract: Callable = None
    #: 3-way assembly consumes the pairwise numerator terms (Czekanowski
    #: does; pure product metrics don't — their computation is skipped)
    needs_pair_terms: bool = True
    #: numpy float64 references, (n_f, n_v) -> (n_v, n_v) / (n_v,)*3
    oracle2: Callable = None
    oracle3: Callable = None

    @property
    def contract_is_combine_sum(self) -> bool:
        """Whether the fused Pallas kernels may realize this contraction."""
        if self.combine_sum_contract is not None:
            return self.combine_sum_contract
        return self.uses_mgemm or self.contract is None

    def contract_fn(self, cfg) -> Callable:
        """Numerator contraction for this metric under a CometConfig.

        ``uses_mgemm`` metrics dispatch through the impl registry so the
        Pallas / level-decomposition kernels keep working; otherwise the
        spec's own ``contract`` runs (falling back to a generic chunk-free
        broadcast-combine reduction so a new metric needs nothing beyond
        ``combine`` to be runnable).
        """
        if self.uses_mgemm:
            return cfg.impl_fn()
        if self.contract is not None:
            return self.contract
        comb = self.combine

        def generic(A, B):
            # cast BEFORE combining: ring_dtype="auto" ships int8 payloads
            # for small-integer data, and a multiply-like combine would
            # overflow in int8 (cf. _ccc_combine, which casts for the same
            # reason inside its own definition)
            A = A.astype(jnp.float32)
            B = B.astype(jnp.float32)
            return comb(A[:, :, None], B[None, :, :]).astype(jnp.float32).sum(1)

        return generic


def family_key(spec: MetricSpec) -> tuple:
    """Batching family of a metric: metrics in one family share a numerator.

    Two metrics may share a single ring-step contraction (and differ only
    in their assemble epilogues) iff they fold the same ``combine`` op over
    the same contraction machinery and ring-carry the same per-vector
    ``stat``.  Czekanowski and Sorenson are one family (min-plus numerator,
    row-sum stat — Sorenson reuses Czekanowski's stat/assemble objects, so
    identity comparison suffices); CCC is its own family (product combine,
    custom contraction).  Batched campaigns compute ONE numerator per
    family per tile and fan it out through each member's epilogue.
    """
    return (spec.combine, spec.stat,
            "mgemm" if spec.uses_mgemm else spec.contract)


def group_families(specs) -> list:
    """Group MetricSpecs into numerator-sharing families, order-preserving.

    Returns a list of lists; each inner list shares a ``family_key`` and
    keeps the caller's metric order (results are emitted per-metric in
    request order regardless of grouping).
    """
    groups, index = [], {}
    for spec in specs:
        key = family_key(spec)
        if key not in index:
            index[key] = len(groups)
            groups.append([])
        groups[index[key]].append(spec)
    return groups


def plane_native(spec: MetricSpec) -> bool:
    """Whether this metric's numerator runs natively on packed bit-planes.

    True for the min-plus family (the fused levels / popcount kernels
    realize ``sum_q min`` directly on the packed payload).  Product-family
    metrics (CCC) ride the same plane ring in a batch but reconstruct
    exact values via ``values_from_planes`` before their own contraction.
    """
    return spec.contract_is_combine_sum and spec.combine is jnp.minimum


def batch_lead(specs) -> MetricSpec:
    """The spec whose knobs drive ``resolve_config`` for a batched campaign.

    Plane-native metrics constrain encoding/ring choices the most, so the
    first plane-native spec leads; an all-product batch falls back to the
    first metric in request order.
    """
    for spec in specs:
        if plane_native(spec):
            return spec
    return specs[0]


def _czek_stat(Vl):
    return Vl.astype(jnp.float32).sum(axis=0)


def _czek_assemble2(n2, si, sj):
    return 2.0 * n2 / safe_denom(si + sj)


#: Same fp ops as ``_czek_assemble2`` — the fused kernel path stays
#: bit-identical to the out-of-kernel assembly (both divide the exact fp32
#: integer numerator by the safe_denom-guarded sum).
czek_assemble_tile = _czek_assemble2


def _czek_assemble3(b3, n2_pl, n2_pr, n2_lr, sp, sl, sr):
    n3 = n2_pl[:, :, None] + n2_pr[:, None, :] + n2_lr[None, :, :] - b3
    d3 = sp[:, None, None] + sl[None, :, None] + sr[None, None, :]
    return 1.5 * n3 / safe_denom(d3)


def _czek_oracle2(V):
    from repro.core.metrics import czek2_metric_np

    return czek2_metric_np(V)


def _czek_oracle3(V):
    from repro.core.metrics import czek3_metric_np

    return czek3_metric_np(V)


CZEKANOWSKI = MetricSpec(
    name="czekanowski",
    description="Proportional Similarity (paper §2): 2 Σ min / Σ sums",
    ways=(2, 3),
    combine=jnp.minimum,
    stat=_czek_stat,
    assemble2=_czek_assemble2,
    assemble3=_czek_assemble3,
    assemble_tile=czek_assemble_tile,
    uses_mgemm=True,
    needs_pair_terms=True,
    oracle2=_czek_oracle2,
    oracle3=_czek_oracle3,
)
