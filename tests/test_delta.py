"""Incremental delta campaigns: store append + border-block delta engine.

Pins the append/delta contract (docs/BITPLANE_FORMAT.md "Append & delta"):

* ``append_dataset(D, new)`` is byte- and checksum-identical to encoding
  the concatenated matrix from scratch — for non-multiple-of-8 field AND
  vector counts, growing in place or to ``out=``, across shard counts
  (property-tested under hypothesis when installed);
* appended datasets carry lineage: ``dataset_version`` bumps and the
  ``parent`` block records the pre-append checksum (``read_manifest``
  rejects malformed lineage, ``origin()`` forwards it to results);
* delta-merged results are checksum-BIT-IDENTICAL to full recomputes
  across impls (xla / fused-levels / popcount) on the in-memory,
  store-backed and streamed paths — multi-device decompositions are swept
  in tests/distributed_harness.py ``check_delta`` and re-checked here
  when the process has enough devices;
* ``meta["delta"]`` proves border-proportional compute (m*n + m^2/2
  entries, zero ring payload bytes — the delta program has no ring);
* a merged result is itself a valid prior: deltas chain across appends;
* the engine rejects cross-lineage priors, metric / dtype / field-count
  mismatches, and no-op deltas with specific errors.
"""
import os

import numpy as np
import pytest

from repro.api import InputSpec, SimilarityEngine, SimilarityRequest, SimilarityResult
from repro.core.delta import (
    delta_accounting,
    merge_delta,
    packed_upper_index,
    twoway_delta,
)
from repro.core.synthetic import random_integer_vectors
from repro.core.twoway import CometConfig, twoway_distributed
from repro.parallel.mesh import make_comet_mesh
from repro.store import append_dataset, read_manifest, write_dataset

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


def _devices() -> int:
    import jax

    return jax.device_count()


def _matrix(n_f, n_v, levels, seed=0):
    return random_integer_vectors(n_f, n_v, max_value=levels, seed=seed)


# -- store append == encode-from-scratch -------------------------------------


def _check_append(tmp_path, n_f, n0, m, levels, n_shards, in_place=False):
    V0 = _matrix(n_f, n0, levels, seed=1)
    Vn = _matrix(n_f, m, levels, seed=2)
    tag = f"{n_f}x{n0}+{m}_{levels}_{n_shards}_{in_place}"
    parent = os.path.join(str(tmp_path), f"parent_{tag}")
    write_dataset(parent, V0, levels=levels, n_shards=n_shards)
    parent_ck = read_manifest(parent)["checksum"]
    if in_place:
        grown_path = parent
        manifest = append_dataset(parent, Vn)
    else:
        grown_path = os.path.join(str(tmp_path), f"grown_{tag}")
        manifest = append_dataset(parent, Vn, out=grown_path)
    scratch = os.path.join(str(tmp_path), f"scratch_{tag}")
    want = write_dataset(
        scratch, np.concatenate([V0, Vn], axis=1), levels=levels,
        n_shards=n_shards,
    )
    # the normative equality: byte-column append == full re-encode
    assert manifest["checksum"] == want["checksum"], tag
    assert manifest["n_v"] == n0 + m and manifest["n_f"] == n_f
    # lineage
    assert manifest["dataset_version"] == 2
    assert manifest["parent"]["checksum"] == parent_ck
    assert manifest["parent"]["n_v"] == n0
    # the grown dataset revalidates (stats sidecar extended correctly)
    from repro.store import DatasetReader

    DatasetReader(grown_path).validate()


@pytest.mark.parametrize(
    "n_f,n0,m,levels,n_shards",
    [
        (16, 8, 4, 2, 1),       # aligned everything
        (23, 11, 5, 2, 1),      # non-multiple-of-8 fields, odd counts
        (9, 3, 7, 3, 2),        # more appended than existing, sharded
        (33, 6, 1, 1, 2),       # binary single-vector append
        (40, 12, 9, 2, 4),      # many shards
    ],
)
def test_append_equals_full_encode(tmp_path, n_f, n0, m, levels, n_shards):
    _check_append(tmp_path, n_f, n0, m, levels, n_shards)


def test_append_in_place(tmp_path):
    _check_append(tmp_path, 23, 11, 5, 2, 2, in_place=True)


def test_append_chains_versions(tmp_path):
    """Two successive appends: versions 1 -> 2 -> 3, each parent block
    pointing at the immediately preceding checksum."""
    path = os.path.join(str(tmp_path), "ds")
    write_dataset(path, _matrix(19, 7, 2, seed=1), levels=2, n_shards=1)
    ck1 = read_manifest(path)["checksum"]
    m2 = append_dataset(path, _matrix(19, 4, 2, seed=2))
    assert m2["dataset_version"] == 2 and m2["parent"]["checksum"] == ck1
    m3 = append_dataset(path, _matrix(19, 3, 2, seed=3))
    assert m3["dataset_version"] == 3
    assert m3["parent"]["checksum"] == m2["checksum"]
    assert m3["parent"]["n_v"] == 11
    want = write_dataset(
        os.path.join(str(tmp_path), "scratch"),
        np.concatenate([_matrix(19, 7, 2, seed=1), _matrix(19, 4, 2, seed=2),
                        _matrix(19, 3, 2, seed=3)], axis=1),
        levels=2, n_shards=1,
    )
    assert m3["checksum"] == want["checksum"]


def test_append_rejects_mismatched_vectors(tmp_path):
    path = os.path.join(str(tmp_path), "ds")
    write_dataset(path, _matrix(16, 6, 2, seed=1), levels=2, n_shards=1)
    with pytest.raises(ValueError, match="n_f"):
        append_dataset(path, _matrix(17, 3, 2, seed=2))
    with pytest.raises(ValueError, match="levels"):
        append_dataset(path, _matrix(16, 3, 2, seed=2) + 5)


def test_read_manifest_rejects_malformed_lineage(tmp_path):
    import json

    path = os.path.join(str(tmp_path), "ds")
    write_dataset(path, _matrix(16, 6, 2, seed=1), levels=2, n_shards=1)
    append_dataset(path, _matrix(16, 3, 2, seed=2))
    target = os.path.join(path, "dataset.json")
    good = json.load(open(target))
    for corrupt, msg in [
        ({"dataset_version": 0}, "dataset_version"),
        ({"parent": "nope"}, "parent"),
        ({"parent": {"checksum": "md5:x", "n_v": 6}}, "parent.checksum"),
        ({"parent": {"checksum": good["parent"]["checksum"], "n_v": 99}},
         "parent.n_v"),
    ]:
        bad = dict(good)
        bad.update(corrupt)
        json.dump(bad, open(target, "w"))
        with pytest.raises(ValueError, match=msg.replace(".", r"\.")):
            read_manifest(path)
    json.dump(good, open(target, "w"))
    read_manifest(path)  # restored manifest is valid again


def test_origin_carries_lineage(tmp_path):
    from repro.store import DatasetReader

    path = os.path.join(str(tmp_path), "ds")
    write_dataset(path, _matrix(16, 6, 2, seed=1), levels=2, n_shards=1)
    ck1 = read_manifest(path)["checksum"]
    append_dataset(path, _matrix(16, 3, 2, seed=2))
    origin = DatasetReader(path).origin()
    assert origin["dataset_version"] == 2
    assert origin["parent"]["checksum"] == ck1


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        n_f=st.integers(1, 40),
        n0=st.integers(1, 12),
        m=st.integers(1, 12),
        levels=st.integers(1, 3),
        n_shards=st.sampled_from([1, 2]),
    )
    def test_append_property(tmp_path_factory, n_f, n0, m, levels, n_shards):
        # kb must divide n_shards: round n_f up via the writer's own rule —
        # shard counts that don't divide kb raise, so only test valid ones
        kb = (n_f + 7) // 8
        if kb % n_shards:
            n_shards = 1
        _check_append(
            tmp_path_factory.mktemp("append_prop"), n_f, n0, m, levels,
            n_shards,
        )


# -- packed merge geometry ---------------------------------------------------


def test_packed_upper_index_matches_triu_order():
    for N in (2, 3, 7, 12):
        I, J = np.triu_indices(N, 1)
        for pos, (i, j) in enumerate(zip(I, J)):
            assert packed_upper_index(int(i), int(j), N) == pos


def test_delta_accounting_is_border_proportional():
    cfg = CometConfig(n_pv=2, n_pr=2)
    a = delta_accounting(cfg, n_old=100, n_new=10, n_op=25,
                         payload_bytes=1234)
    assert a["border_entries"] == 100 * 10 + 45
    assert a["full_entries"] == 110 * 109 // 2
    assert a["border_entries"] < a["full_entries"] // 4
    assert a["computed_entries"] == 4 * 25 * 10 + 45
    assert a["ring_payload_bytes"] == 0  # the delta program has no ring
    assert a["decomposition"] == [1, 2, 2]


# -- delta == full recompute (single-device; multi-device in the harness) ----


def _full_checksum(V, cfg):
    out = twoway_distributed(V, make_comet_mesh(1, 1, 1),
                             CometConfig(impl=cfg.impl, levels=cfg.levels))
    return out.checksum()


def _delta_checksum(V, n_old, cfg):
    mesh = make_comet_mesh(cfg.n_pf, cfg.n_pv, cfg.n_pr)
    prior_out = twoway_distributed(
        V[:, :n_old], make_comet_mesh(1, 1, 1),
        CometConfig(impl=cfg.impl, levels=cfg.levels),
    )
    rect, tri, rcfg, info = twoway_delta(V, n_old, mesh, cfg)
    merged = merge_delta(prior_out.pack(), rect, tri, n_old,
                         V.shape[1] - n_old, rcfg.out_dtype)
    return merged.checksum(), info


@pytest.mark.parametrize(
    "impl,levels,maxval",
    [("xla", 0, 7), ("levels", 2, 2), ("levels", 1, 1)],  # incl. popcount
)
def test_delta_matches_full(impl, levels, maxval):
    V = _matrix(21, 18, maxval, seed=5)
    cfg = CometConfig(impl=impl, levels=max(levels, 1))
    want = _full_checksum(V, cfg)
    got, info = _delta_checksum(V, 13, cfg)
    assert got == want, (impl, levels)
    assert info["computed_entries"] < info["full_entries"]


@pytest.mark.parametrize("decomp", [(1, 2, 2), (2, 2, 1), (1, 4, 2)])
def test_delta_matches_full_multidevice(decomp):
    n_pf, n_pv, n_pr = decomp
    if _devices() < n_pf * n_pv * n_pr:
        pytest.skip("needs a forced multi-device process "
                    "(covered by distributed_harness.check_delta)")
    V = _matrix(21, 18, 2, seed=5)
    cfg = CometConfig(n_pf=n_pf, n_pv=n_pv, n_pr=n_pr, impl="levels",
                      levels=2)
    want = _full_checksum(V, cfg)
    got, _ = _delta_checksum(V, 13, cfg)
    assert got == want, decomp


def test_delta_chains():
    """A merged delta result is a valid prior for the NEXT append."""
    V = _matrix(17, 20, 2, seed=6)
    cfg = CometConfig(impl="levels", levels=2)
    mesh = make_comet_mesh(1, 1, 1)
    prior = twoway_distributed(V[:, :10], mesh, cfg).pack()
    for n_old, n_new in [(10, 6), (16, 4)]:
        sub = V[:, : n_old + n_new]
        rect, tri, rcfg, _ = twoway_delta(sub, n_old, mesh, cfg)
        prior = merge_delta(prior, rect, tri, n_old, n_new, rcfg.out_dtype)
    assert prior.checksum() == _full_checksum(V, cfg)


def test_delta_store_backed_and_streamed(tmp_path):
    from repro.store import DatasetReader
    from repro.stream import stream_twoway_delta

    n_f, n0, m = 40, 14, 5
    V0, Vn = _matrix(n_f, n0, 2, seed=7), _matrix(n_f, m, 2, seed=8)
    path = os.path.join(str(tmp_path), "ds")
    write_dataset(path, V0, levels=2, n_shards=2)
    append_dataset(path, Vn)
    cfg = CometConfig(impl="levels", levels=2)
    want = _full_checksum(np.concatenate([V0, Vn], axis=1), cfg)
    mesh = make_comet_mesh(1, 1, 1)
    prior = twoway_distributed(V0, mesh, cfg)

    # store-backed (materialized planes — no host re-encode by contract)
    pp = DatasetReader(path).packed()
    rect, tri, rcfg, _ = twoway_delta(pp, n0, mesh, cfg)
    got = merge_delta(prior.pack(), rect, tri, n0, m, rcfg.out_dtype)
    assert got.checksum() == want

    # streamed (chunked border blocks + merge epilogue), budget forcing
    # more than one chunk per shard
    sh = DatasetReader(path).sharded()
    scfg = CometConfig(impl="levels", levels=2, streaming="on",
                       max_host_bytes=120)
    rect, tri, rcfg, dinfo, sinfo = stream_twoway_delta(sh, n0, mesh, scfg)
    got = merge_delta(prior.pack(), rect, tri, n0, m, rcfg.out_dtype)
    assert got.checksum() == want
    assert dinfo["streamed"] and sinfo["chunks"] > sh.n_shards
    assert sinfo["peak_host_bytes"] <= 120


# -- engine front door (delta_from) ------------------------------------------


def _engine_pair(tmp_path, n0=12, m=5):
    """-> (engine, request base kwargs, grown dataset path, prior dir,
    full-recompute checksum)."""
    n_f = 24
    V0, Vn = _matrix(n_f, n0, 2, seed=9), _matrix(n_f, m, 2, seed=10)
    path = os.path.join(str(tmp_path), "ds")
    write_dataset(path, V0, levels=2, n_shards=2)
    eng = SimilarityEngine()
    base = dict(way=2, metric="czekanowski", impl="levels", levels=2)
    prior = eng.run(SimilarityRequest(
        **base, input=InputSpec(source="planes", path=path)))
    pdir = os.path.join(str(tmp_path), "prior")
    prior.save(pdir)
    append_dataset(path, Vn)
    want = eng.run(SimilarityRequest(
        **base, input=InputSpec(source="planes", path=path))).checksum()
    return eng, base, path, pdir, want


def test_engine_delta_from(tmp_path):
    eng, base, path, pdir, want = _engine_pair(tmp_path)
    for streaming in ("off", "on"):
        got = eng.run(SimilarityRequest(
            **base, streaming=streaming, max_host_bytes=400,
            input=InputSpec(source="planes", path=path), delta_from=pdir))
        assert got.checksum() == want, streaming
        d = got.meta["delta"]
        assert d["n_old"] == 12 and d["n_new"] == 5
        assert d["computed_entries"] < d["full_entries"]
        assert d["ring_payload_bytes"] == 0
        assert d["streamed"] == (streaming == "on")
        assert got.meta["dataset"]["dataset_version"] == 2
        # the merged result round-trips and is a valid next prior
        mdir = os.path.join(str(tmp_path), f"merged_{streaming}")
        got.save(mdir)
        assert SimilarityResult.load(mdir).checksum() == want


def test_engine_delta_guards(tmp_path):
    eng, base, path, pdir, _ = _engine_pair(tmp_path)
    spec = InputSpec(source="planes", path=path)

    with pytest.raises(ValueError, match="metric"):
        eng.run(SimilarityRequest(**dict(base, metric="ccc"),
                                  input=spec, delta_from=pdir))
    with pytest.raises(ValueError, match="out_dtype"):
        eng.run(SimilarityRequest(**base, out_dtype="bfloat16",
                                  input=spec, delta_from=pdir))
    # nothing appended: prior already covers the whole parent dataset
    parent_only = os.path.join(str(tmp_path), "same")
    write_dataset(parent_only, _matrix(24, 12, 2, seed=9), levels=2,
                  n_shards=2)
    with pytest.raises(ValueError, match="appended"):
        eng.run(SimilarityRequest(
            **base, input=InputSpec(source="planes", path=parent_only),
            delta_from=pdir))
    # cross-lineage prior: same geometry, different ancestry -> refused
    stranger = os.path.join(str(tmp_path), "stranger")
    write_dataset(stranger, _matrix(24, 12, 2, seed=77), levels=2,
                  n_shards=2)
    sres = eng.run(SimilarityRequest(
        **base, input=InputSpec(source="planes", path=stranger)))
    sdir = os.path.join(str(tmp_path), "stranger_prior")
    sres.save(sdir)
    with pytest.raises(ValueError, match="lineage"):
        eng.run(SimilarityRequest(**base, input=spec, delta_from=sdir))
    # field-count mismatch is not the same cohort
    other = _matrix(25, 14, 2, seed=11)
    with pytest.raises(ValueError, match="n_f"):
        eng.run(SimilarityRequest(**base, delta_from=pdir), V=other)


def test_delta_request_validation():
    req = SimilarityRequest(way=3, delta_from="/tmp/x")
    with pytest.raises(ValueError, match="2-way"):
        req.validate()
    req = SimilarityRequest(way=2, metrics=("sorenson",),
                            delta_from="/tmp/x")
    with pytest.raises(ValueError, match="batched"):
        req.validate()
