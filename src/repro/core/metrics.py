"""Proportional Similarity (Czekanowski) metric definitions — paper §2.

Reference (oracle) implementations of the 2-way and 3-way metrics.  These are
deliberately simple O(n_f n_v^2) / O(n_f n_v^3) formulations used as the
ground truth for every optimized path (Pallas kernels, distributed engines).

Conventions
-----------
``V`` is the matrix of column vectors, shape ``(n_f, n_v)`` — fields (vector
elements) down the rows, vectors across the columns, matching the paper's
``V = [v_1 v_2 ... v_nv]``.

2-way (paper §2.1):
    c2(vi, vj)  = 2 * n2(vi, vj) / d2(vi, vj)
    n2(vi, vj)  = sum_q min(v_iq, v_jq)
    d2(vi, vj)  = sum_q v_iq + sum_q v_jq

3-way (paper §2.2):
    c3(vi,vj,vk) = (3/2) * n3 / d3
    n3  = n2(vi,vj) + n2(vi,vk) + n2(vj,vk) - n3'(vi,vj,vk)
    n3' = sum_q min(v_iq, v_jq, v_kq)
    d3  = sum_q v_iq + v_jq + v_kq
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = [
    "safe_denom",
    "czek2_numerators",
    "czek2_metric",
    "czek3_nprime",
    "czek3_metric",
    "czek2_from_parts",
    "czek3_from_parts",
]

#: Smallest denominator admitted by any metric assembly.  All-zero vectors
#: produce a zero numerator AND a zero denominator; clamping yields metric 0
#: (no similarity evidence) instead of NaN, identically on every path.
DENOM_EPS = 1e-30


def safe_denom(d, eps: float = DENOM_EPS):
    """Clamp a metric denominator away from zero (all-zero-vector guard).

    Works on numpy arrays (oracles) and jax values (engines/kernels); for
    any nonzero denominator this is the identity, so it never perturbs real
    metric values.
    """
    if isinstance(d, np.ndarray) or np.isscalar(d):
        return np.maximum(d, eps)
    return jnp.maximum(d, eps)


def czek2_numerators(V):
    """All-pairs 2-way numerators: N[i, j] = sum_q min(V[q, i], V[q, j]).

    Returns an (n_v, n_v) symmetric matrix (full, including redundant half).
    """
    V = jnp.asarray(V)
    # (n_f, n_v, 1) vs (n_f, 1, n_v) -> (n_v, n_v)
    return jnp.minimum(V[:, :, None], V[:, None, :]).sum(axis=0)


def czek2_metric(V):
    """All-pairs 2-way Proportional Similarity matrix c2[i, j]."""
    V = jnp.asarray(V)
    n = czek2_numerators(V)
    s = V.sum(axis=0)  # (n_v,)
    d = s[:, None] + s[None, :]
    return 2.0 * n / safe_denom(d)


def czek2_from_parts(n2, si, sj):
    """Assemble c2 from numerator(s) and the two row sums (broadcasts)."""
    return 2.0 * n2 / safe_denom(si + sj)


def czek3_nprime(V):
    """All-triples n3'[i,j,k] = sum_q min(V[q,i], V[q,j], V[q,k])."""
    V = jnp.asarray(V)
    m3 = jnp.minimum(
        jnp.minimum(V[:, :, None, None], V[:, None, :, None]),
        V[:, None, None, :],
    )
    return m3.sum(axis=0)


def czek3_metric(V):
    """All-triples 3-way Proportional Similarity tensor c3[i,j,k]."""
    V = jnp.asarray(V)
    n2 = czek2_numerators(V)
    np3 = czek3_nprime(V)
    s = V.sum(axis=0)
    n3 = n2[:, :, None] + n2[:, None, :] + n2[None, :, :] - np3
    d3 = s[:, None, None] + s[None, :, None] + s[None, None, :]
    return 1.5 * n3 / safe_denom(d3)


def czek3_from_parts(n2_ij, n2_ik, n2_jk, np3, si, sj, sk):
    """Assemble c3 from pairwise numerators, the 3-way term and row sums."""
    n3 = n2_ij + n2_ik + n2_jk - np3
    d3 = si + sj + sk
    return 1.5 * n3 / safe_denom(d3)


# ---------------------------------------------------------------------------
# numpy oracles (used by tests that want to stay outside jit / device memory)
# ---------------------------------------------------------------------------

def czek2_metric_np(V: np.ndarray) -> np.ndarray:
    V = np.asarray(V, dtype=np.float64)
    n = np.minimum(V[:, :, None], V[:, None, :]).sum(axis=0)
    s = V.sum(axis=0)
    return 2.0 * n / safe_denom(s[:, None] + s[None, :])


def czek3_metric_np(V: np.ndarray) -> np.ndarray:
    V = np.asarray(V, dtype=np.float64)
    n2 = np.minimum(V[:, :, None], V[:, None, :]).sum(axis=0)
    np3 = np.minimum(
        np.minimum(V[:, :, None, None], V[:, None, :, None]), V[:, None, None, :]
    ).sum(axis=0)
    s = V.sum(axis=0)
    n3 = n2[:, :, None] + n2[:, None, :] + n2[None, :, :] - np3
    d3 = s[:, None, None] + s[None, :, None] + s[None, None, :]
    return 1.5 * n3 / safe_denom(d3)
