"""Runs the multi-device decomposition-invariance harness in a subprocess
(device count must be set before jax initializes; the main pytest process
keeps the default single CPU device)."""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(os.path.abspath(__file__))
SRC = os.path.join(os.path.dirname(HERE), "src")


@pytest.mark.slow
def test_decomposition_invariance():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "distributed_harness.py")],
        capture_output=True,
        text=True,
        env=env,
        timeout=900,
    )
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, "distributed harness failed"
    assert "ALL DISTRIBUTED CHECKS PASSED" in proc.stdout
