"""SimilarityRequest: one frozen object describing a similarity campaign.

A request is the complete, hashable description of *what* to compute: the
metric, the way (2- or 3-way), the parallel decomposition, implementation /
dtype knobs, 3-way staging, and (optionally) where the input comes from.
``SimilarityEngine`` turns a request into a ``SimilarityResult``; the serving
layer caches results keyed by the request, which is why it must be frozen
and hashable.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np

from repro.core.twoway import CometConfig

__all__ = ["InputSpec", "SimilarityRequest"]


@dataclass(frozen=True)
class InputSpec:
    """Where the (n_f, n_v) vector matrix comes from.

    ``synthetic`` draws the paper's random-integer dataset (fp-exact sums);
    ``npy`` loads a saved matrix from ``path`` (validated on load — see
    ``_validate_matrix``); ``planes`` opens a ``repro.store`` packed
    bit-plane dataset directory and materializes a ``PackedPlanes`` handle
    (the engines consume it directly — the campaign never runs the host
    encoder); ``bed`` decodes a PLINK 1 ``.bed/.bim/.fam`` fileset into the
    {0, 1, 2} dosage matrix (``missing`` names the missing-genotype
    policy: "error" | "zero" | "drop").
    """

    source: str = "synthetic"  # "synthetic" | "npy" | "planes" | "bed"
    n_f: int = 512
    n_v: int = 240
    max_value: int = 15
    seed: int = 0
    path: str = ""
    #: PLINK missing-genotype policy (source="bed" only)
    missing: str = "error"

    def materialize(self):
        """-> (n_f, n_v) ndarray, or PackedPlanes for ``source="planes"``."""
        if self.source == "npy":
            if not self.path:
                raise ValueError("InputSpec(source='npy') needs a path")
            return _validate_matrix(np.load(self.path), what=self.path)
        if self.source == "planes":
            if not self.path:
                raise ValueError("InputSpec(source='planes') needs a dataset path")
            from repro.store import DatasetReader

            return DatasetReader(self.path).packed()
        if self.source == "bed":
            if not self.path:
                raise ValueError("InputSpec(source='bed') needs a fileset path")
            from repro.store import read_bed

            V, _ = read_bed(self.path, missing=self.missing)
            return V
        if self.source == "synthetic":
            from repro.core.synthetic import random_integer_vectors

            return random_integer_vectors(
                self.n_f, self.n_v, max_value=self.max_value, seed=self.seed
            )
        raise ValueError(f"unknown input source {self.source!r}")


def _validate_matrix(V: np.ndarray, *, what: str) -> np.ndarray:
    """Gate for externally loaded matrices (shared core validator).

    The engines' exactness contract assumes a finite, non-negative numeric
    (n_f, n_v) matrix whose actual column sums stay below the fp32 mantissa
    limit (paper §5); a hostile ``.npy`` violating any of these used to
    flow straight into the engines and surface only as a wrong checksum.
    Errors name the offending stat.
    """
    from repro.core.validate import validate_matrix

    return validate_matrix(V, what=what, check_fp32_sums=True)


@dataclass(frozen=True)
class SimilarityRequest:
    """Frozen description of one similarity campaign.

    Batching: ``metrics`` adds further metrics evaluated in the SAME ring
    traversal (the primary ``metric`` always runs; duplicates are an
    error), and ``subsets`` names vector-index subsets — ``(name, indices)``
    pairs — each evaluated as its own campaign against a byte-slice view of
    the shared plane payload (no re-encode).  Either field makes the
    request *batched*: the engine returns a ``BatchedSimilarityResult``
    holding one ordinary ``SimilarityResult`` per (metric, subset)
    campaign, every one bit-identical to its sequential single-campaign
    run, and ``meta["batch"]`` accounts the ring bytes moved (independent
    of the campaign count).
    """

    metric: str = "czekanowski"
    way: int = 2
    # parallel decomposition (paper's three axes) + 3-way staging
    n_pf: int = 1
    n_pv: int = 1
    n_pr: int = 1
    n_st: int = 1
    #: which 3-way stages to run; None -> every stage of n_st
    stages: tuple = None
    # implementation / dtype knobs (threaded into CometConfig)
    impl: str = "xla"
    #: plane count for the levels impls; ``levels=1`` (binary {0,1} data,
    #: e.g. the sorenson metric) additionally swaps the plane-dot kernels
    #: for the popcount bit-GEMM fast path (``path == "fused-popcount"``)
    levels: int = 2
    out_dtype: str = "float32"
    #: "auto" ring-carries int8 when the data is integer-valued with
    #: |values| <= 127 (4x less ICI wire than fp32); "float32" opts out
    ring_dtype: str = "auto"
    #: bit-plane pre-encoding for the levels path: "auto" | "bitplane" |
    #: "none" — see CometConfig.encoding
    encoding: str = "auto"
    chunk: int = 128
    #: store 2-way result blocks in packed upper-triangular form (the
    #: diagonal block keeps only its strict upper triangle — roughly halves
    #: slot-buffer memory for small decompositions); values and checksum
    #: are unchanged
    packed: bool = False
    #: out-of-core streaming over a ``repro.store`` dataset: "auto" streams
    #: multi-shard (or host-budgeted) ``source="planes"`` inputs through
    #: ``repro.stream``, "on" requires a store-backed input, "off" always
    #: materializes in memory.  Streamed results are bit-identical
    #: (checksum) to in-memory runs — see docs/BITPLANE_FORMAT.md
    #: "Cross-shard merge".
    streaming: str = "auto"
    #: staging-buffer budget in bytes for the streamed pipeline (0 = one
    #: disk shard per chunk); peak host payload memory stays at or below
    #: this across the campaign
    max_host_bytes: int = 0
    #: optional input description (run() can also take V directly)
    input: InputSpec = None
    #: extra metric names evaluated in the same ring traversal (the primary
    #: ``metric`` is always first; names must be unique across both fields)
    metrics: tuple = ()
    #: named vector-index subsets, ``((name, (i0, i1, ...)), ...)`` — each
    #: becomes its own campaign over a plane byte-slice view; ``()`` runs
    #: the full vector set
    subsets: tuple = ()
    #: path to a saved prior ``SimilarityResult`` covering the input's first
    #: vectors: the engine then runs a border-block DELTA campaign — only
    #: the new-vs-all rectangle and new-vs-new triangle are computed and
    #: merged into the prior (``repro.core.delta``); checksum bit-identical
    #: to a full recompute, ``meta["delta"]`` proves border-proportional
    #: compute.  2-way, non-batched requests only.
    delta_from: str = ""

    # -- derived -----------------------------------------------------------

    @property
    def n_ranks(self) -> int:
        return self.n_pf * self.n_pv * self.n_pr

    @property
    def is_batched(self) -> bool:
        """True when the request describes more than one campaign (extra
        metrics and/or named subsets) — the engine then returns a
        ``BatchedSimilarityResult`` instead of a ``SimilarityResult``."""
        return bool(self.metrics) or bool(self.subsets)

    def campaign_metrics(self) -> tuple:
        """All metric names in request order (primary first)."""
        return (self.metric,) + tuple(self.metrics)

    def campaign_subsets(self) -> tuple:
        """Normalized ``(name, indices)`` pairs; ``(("", None),)`` when the
        request runs the full vector set."""
        if not self.subsets:
            return (("", None),)
        return tuple(
            (str(name), tuple(int(i) for i in idx))
            for name, idx in self.subsets
        )

    def campaign_key(self) -> tuple:
        """Hashable identity of WHICH campaigns this request computes —
        metric names and subset (name, indices) pairs.  Cache layers key on
        this so two requests differing only in campaign composition never
        collide (same input + decomposition is not the same answer)."""
        return (self.campaign_metrics(), self.campaign_subsets())

    def resolved_stages(self) -> tuple:
        if self.way == 2:
            return (0,)
        return self.stages if self.stages is not None else tuple(range(self.n_st))

    def to_comet_config(self) -> CometConfig:
        return CometConfig(
            n_pf=self.n_pf, n_pv=self.n_pv, n_pr=self.n_pr, n_st=self.n_st,
            impl=self.impl, levels=self.levels,
            out_dtype=self.out_dtype, ring_dtype=self.ring_dtype,
            encoding=self.encoding, chunk=self.chunk,
            streaming=self.streaming, max_host_bytes=self.max_host_bytes,
        )

    def with_decomposition(self, n_pf: int, n_pv: int, n_pr: int) -> "SimilarityRequest":
        return replace(self, n_pf=n_pf, n_pv=n_pv, n_pr=n_pr)

    # -- validation --------------------------------------------------------

    def validate(self, *, n_devices: int = None, metric_spec=None) -> None:
        """Raise ValueError on an unsatisfiable request.

        Metric-name resolution errors are raised by the registry
        (UnknownMetricError) before this runs; here we check shape/placement
        consistency, including decomposition vs the available device count.
        """
        if self.way not in (2, 3):
            raise ValueError(f"way must be 2 or 3, got {self.way}")
        for name in ("n_pf", "n_pv", "n_pr", "n_st"):
            v = getattr(self, name)
            if not (isinstance(v, int) and v >= 1):
                raise ValueError(f"{name} must be a positive int, got {v!r}")
        if n_devices is not None and self.n_ranks > n_devices:
            raise ValueError(
                f"decomposition ({self.n_pf}, {self.n_pv}, {self.n_pr}) needs "
                f"{self.n_ranks} devices, have {n_devices}"
            )
        if self.way == 2 and self.n_st != 1:
            raise ValueError("staging (n_st > 1) applies to 3-way only")
        if self.encoding not in ("auto", "bitplane", "none"):
            raise ValueError(
                f"encoding must be 'auto', 'bitplane' or 'none', "
                f"got {self.encoding!r}"
            )
        if self.packed and self.way != 2:
            raise ValueError("packed triangular storage applies to 2-way only")
        if self.streaming not in ("auto", "on", "off"):
            raise ValueError(
                f"streaming must be 'auto', 'on' or 'off', "
                f"got {self.streaming!r}"
            )
        if not (isinstance(self.max_host_bytes, int) and self.max_host_bytes >= 0):
            raise ValueError(
                f"max_host_bytes must be a non-negative int, "
                f"got {self.max_host_bytes!r}"
            )
        if self.streaming == "on" and self.input is not None \
                and self.input.source != "planes":
            raise ValueError(
                "streaming='on' needs a store-backed dataset input "
                "(source='planes')"
            )
        if self.delta_from:
            if not isinstance(self.delta_from, str):
                raise ValueError(
                    f"delta_from must be a path string, got {self.delta_from!r}"
                )
            if self.way != 2:
                raise ValueError("delta campaigns are 2-way only")
            if self.is_batched:
                raise ValueError(
                    "delta campaigns cannot be batched (metrics/subsets): "
                    "a prior result covers exactly one campaign"
                )
        if self.stages is not None:
            if self.way == 2:
                raise ValueError("stages apply to 3-way requests only")
            bad = [s for s in self.stages if not 0 <= s < self.n_st]
            if bad:
                raise ValueError(f"stages {bad} out of range for n_st={self.n_st}")
        if metric_spec is not None and self.way not in metric_spec.ways:
            raise ValueError(
                f"metric {self.metric!r} supports ways {metric_spec.ways}, "
                f"requested {self.way}"
            )
        names = self.campaign_metrics()
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate metric names in batch: {names}")
        if self.subsets:
            seen = set()
            for entry in self.subsets:
                if not (isinstance(entry, tuple) and len(entry) == 2):
                    raise ValueError(
                        f"subsets entries must be (name, indices) pairs, "
                        f"got {entry!r}"
                    )
                name, idx = entry
                if not (isinstance(name, str) and name):
                    raise ValueError(f"subset name must be a non-empty str, got {name!r}")
                if name in seen:
                    raise ValueError(f"duplicate subset name {name!r}")
                seen.add(name)
                idx = tuple(idx)
                if not idx:
                    raise ValueError(f"subset {name!r} is empty")
                if any(not isinstance(i, (int, np.integer)) or i < 0 for i in idx):
                    raise ValueError(
                        f"subset {name!r} indices must be non-negative ints"
                    )
                if len(set(idx)) != len(idx):
                    raise ValueError(f"subset {name!r} has duplicate indices")
            if self.way == 3:
                # subset extraction re-indexes triples out of the union
                # run, so every computed triple must exist: a partial
                # stage sweep would silently drop subset results
                if set(self.resolved_stages()) != set(range(self.n_st)):
                    raise ValueError(
                        "way=3 with named subsets needs complete stage "
                        f"coverage: stages {self.resolved_stages()} do not "
                        f"cover n_st={self.n_st}"
                    )
