"""qwen1.5-0.5b [dense] — hf:Qwen/Qwen1.5-0.5B (hf-verified).

24L d_model=1024 16H (GQA kv=16) d_ff=2816 vocab=151936, QKV bias.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    head_dim=64,
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="qwen1.5-0.5b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
)
