"""Bit-plane encoding for the level-decomposition path.

For integer data quantized to levels {0, 1, ..., L} the indicator planes
``plane_t = 1[V >= t]`` (t = 1..L) fully describe V: each plane is one bit
per element and ``V = sum_t plane_t``.  This module packs the planes along
the *field* (contraction) axis, 8 plane-bits per byte, LSB-first — byte r
of a plane covers fields ``8r .. 8r+7`` with bit j holding field ``8r+j``.

This layout is a documented, stable contract: both distributed engines
ring-carry it, the fused MXU kernels consume it, and any change to it is a
wire/storage format break.  The normative spec — bit order, padding rules,
byte-axis "pf" sharding, and the exact 2-way / 3-way ring payload shapes —
lives in docs/BITPLANE_FORMAT.md; the invariants below restate the parts
this module owns:

* plane array shape is ``(levels, kb, n)`` uint8 with ``kb = ceil(k / 8)``,
  field-major, LSB-first within each byte;
* padding bits (fields past ``k``) are ZERO in every plane, so they are
  inert in any plane GEMM — exactly like the engines' zero-padded values;
* slicing along the trailing *vector* axis commutes with encoding
  (``encode(V)[:, :, a:b] == encode(V[:, a:b])``) — pipeline slices of the
  3-way ring are plain byte-range views, see ``slice_planes_vectors``;
* slicing whole bytes along the *byte* axis selects fields ``8*b0 ..
  8*b1 - 1`` — the "pf" sharding of the ring payload, see
  ``shard_planes_fields``.

Why pack: the packed representation is what the distributed engines
ring-carry and what the fused MXU kernels consume.  For SNP {0,1,2} data
(L=2) the packed planes are ``2 * n_f/8`` bytes per vector vs ``4 * n_f``
for the fp32 ring payload — 16x less ICI wire traffic and HBM read volume —
and encoding happens ONCE per campaign instead of ``(V >= t)`` being
recomputed from fp32 data at every ring step.

A worked example (doctested; 3 fields, 2 vectors, levels=2):

>>> import numpy as np
>>> V = np.array([[0, 1],
...               [2, 1],
...               [1, 0]])                  # (k=3 fields, n=2 vectors)
>>> P = encode_bitplanes_np(V, levels=2)
>>> P.shape                                 # (levels, ceil(3/8), 2)
(2, 1, 2)
>>> [bin(b) for b in P[0, 0]]               # plane 1 = 1[V >= 1], LSB-first
['0b110', '0b11']
>>> [int(b) for b in P[1, 0]]               # plane 2 = 1[V >= 2]
[2, 0]
>>> np.asarray(values_from_planes(P))[:3].astype(int).tolist()
[[0, 1], [2, 1], [1, 0]]

Per-plane popcounts via the shared ``POPCOUNT`` byte table (the store's
stats sidecar and the popgemm reference both count planes this way):

>>> [int(POPCOUNT[b]) for b in (0b0, 0b1, 0b1011, 0xFF)]
[0, 1, 3, 8]
>>> POPCOUNT[P].sum(axis=1).astype(int).tolist()  # == column sums per plane
[[2, 2], [1, 0]]
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "PackedPlanes",
    "POPCOUNT",
    "encode_bitplanes",
    "encode_bitplanes_np",
    "decode_bitplanes",
    "values_from_planes",
    "planes_nbytes",
    "pad_planes",
    "slice_planes_vectors",
    "take_planes_vectors",
    "shard_planes_fields",
]

#: Byte-popcount lookup: ``POPCOUNT[byte]`` = number of set bits.  The one
#: shared table behind every host-side popcount over packed planes — the
#: store writer's stats sidecar, the reader's ``validate()`` scan, and the
#: popgemm reference oracle all index it, so "popcount of a plane byte"
#: has exactly one definition next to the format it counts.
POPCOUNT = np.array([bin(i).count("1") for i in range(256)], np.uint8)


@dataclass(frozen=True, eq=False)
class PackedPlanes:
    """In-memory handle of a pre-encoded plane payload (the wire format).

    ``eq=False``: a generated ``__eq__`` would compare the ndarray field
    elementwise (raising on truth-value ambiguity) and break hashing;
    handles compare by identity — compare payloads with ``np.array_equal``.

    Wraps a ``(levels, kb, n_v)`` uint8 plane array together with the TRUE
    field count ``n_f`` (the byte axis may carry write-time alignment
    padding beyond ``ceil(n_f / 8)`` — pad bits are zero and inert).  This
    is what ``repro.store`` readers hand to the engines: both
    ``twoway_distributed`` and ``threeway_distributed`` accept it in place
    of a value matrix and ring-carry the planes WITHOUT ever re-encoding
    on the host (``pad_planes`` re-pads the packed payload directly).

    >>> import numpy as np
    >>> pp = PackedPlanes(encode_bitplanes_np(np.ones((13, 3)), 2), n_f=13)
    >>> (pp.levels, pp.kb, pp.n_v, pp.n_f)
    (2, 2, 3, 13)
    """

    planes: np.ndarray  # (levels, kb, n_v) uint8
    n_f: int  # true field count (<= 8 * kb)
    #: free-form origin metadata travelling WITH the payload (the store
    #: reader records dataset path + checksum here, and the engine copies
    #: it into result manifests — so provenance survives any entry point
    #: that forwards the handle, and nothing re-reads the manifest)
    origin: dict = None

    def __post_init__(self):
        P = self.planes
        if getattr(P, "ndim", None) != 3:
            raise ValueError(
                f"PackedPlanes needs a (levels, kb, n_v) array, got "
                f"{getattr(P, 'shape', None)}"
            )
        if np.dtype(P.dtype) != np.uint8:
            raise ValueError(f"plane payload must be uint8, got {P.dtype}")
        if not (0 < self.n_f <= 8 * P.shape[1]):
            raise ValueError(
                f"n_f={self.n_f} outside (0, 8*kb={8 * P.shape[1]}]"
            )

    @property
    def levels(self) -> int:
        return int(self.planes.shape[0])

    @property
    def kb(self) -> int:
        return int(self.planes.shape[1])

    @property
    def n_v(self) -> int:
        return int(self.planes.shape[2])

    @property
    def nbytes(self) -> int:
        return int(self.planes.nbytes)


def pad_planes(P, *, byte_align: int = 1, n_v: int = None) -> np.ndarray:
    """Re-pad a packed payload with zero bytes / zero columns — no re-encode.

    Zero BYTES on the byte axis are the encoding of eight zero fields and
    zero COLUMNS on the vector axis are the encoding of zero vectors, so
    padding the packed array commutes with encoding the padded matrix
    (``pad_planes(encode(V)) == encode(pad(V))`` whenever the byte padding
    is whole bytes).  This is what lets pre-encoded datasets from
    ``repro.store`` be re-shaped to any campaign decomposition without the
    host encoder ever running.

    ``byte_align``: pad the byte axis to a multiple (the "pf" shard rule);
    ``n_v``: pad the vector axis up to this count.

    >>> import numpy as np
    >>> P = encode_bitplanes_np(np.ones((8, 3)), 1)
    >>> pad_planes(P, byte_align=2, n_v=4).shape
    (1, 2, 4)
    """
    levels, kb, w = P.shape
    bp = (-kb) % max(1, byte_align)
    vp = 0 if n_v is None else n_v - w
    if vp < 0:
        raise ValueError(f"cannot shrink vector axis {w} -> {n_v}")
    if bp or vp:
        P = np.pad(np.asarray(P), ((0, 0), (0, bp), (0, vp)))
    return P


def encode_bitplanes_np(V, levels: int, *, field_align: int = 1) -> np.ndarray:
    """Host-side packer: (k, n) leveled values -> (levels, kb, n) uint8.

    ``field_align``: pad the field count to a multiple of ``8 * field_align``
    so the *byte* axis splits evenly over ``field_align`` ranks (the "pf"
    sharding of the packed ring payload).

    >>> import numpy as np
    >>> P = encode_bitplanes_np(np.ones((13, 3)), levels=1, field_align=2)
    >>> P.shape                        # 13 fields -> 16 (pad) -> 2 bytes
    (1, 2, 3)
    """
    V = np.asarray(V)
    k, n = V.shape
    kp = (-k) % (8 * max(1, field_align))
    if kp:
        V = np.pad(V, ((0, kp), (0, 0)))
    thresholds = np.arange(1, levels + 1).reshape(-1, 1, 1).astype(V.dtype)
    planes = V[None, :, :] >= thresholds  # (levels, K, n) bool
    return np.packbits(planes, axis=1, bitorder="little")


def encode_bitplanes(V, levels: int):
    """jnp packer (jit-composable): (k, n) -> (levels, ceil(k/8), n) uint8.

    Byte-identical to ``encode_bitplanes_np`` (asserted in
    tests/test_bitplanes.py), so host-encoded campaign payloads and
    device-encoded standalone calls can never disagree."""
    V = jnp.asarray(V)
    k, n = V.shape
    kp = (-k) % 8
    if kp:
        V = jnp.pad(V, ((0, kp), (0, 0)))
    K = k + kp
    thresholds = jnp.arange(1, levels + 1, dtype=jnp.int32).astype(V.dtype)
    planes = (V[None] >= thresholds[:, None, None]).astype(jnp.int32)
    shifts = jnp.arange(8, dtype=jnp.int32).reshape(1, 1, 8, 1)
    packed = (planes.reshape(levels, K // 8, 8, n) << shifts).sum(axis=2)
    return packed.astype(jnp.uint8)


def decode_bitplanes(P):
    """(levels, kb, n) uint8 -> (levels, 8*kb, n) int32 {0, 1} planes."""
    P = jnp.asarray(P)
    levels, kb, n = P.shape
    shifts = jnp.arange(8, dtype=jnp.int32).reshape(1, 1, 8, 1)
    bits = (P.astype(jnp.int32)[:, :, None, :] >> shifts) & 1
    return bits.reshape(levels, kb * 8, n)


def values_from_planes(P, dtype=jnp.float32):
    """Exact value reconstruction V = sum_t plane_t for leveled data.

    Returns (8*kb, n); rows past the true field count are the zero padding.
    The distributed engines use this for per-vector stats on the plane
    ring, so denominators come from the SAME payload the kernels consume.
    """
    return decode_bitplanes(P).sum(axis=0).astype(dtype)


def slice_planes_vectors(P, start, count: int):
    """Pipeline slice: vectors [start, start+count) of packed planes.

    Packing is along the *field* axis, so a vector-axis slice is exact and
    byte-aligned by construction — ``slice_planes_vectors(encode(V), a, c)
    == encode(V[:, a:a+c])`` bit-for-bit (property-tested in
    tests/test_plane_slicing.py).  jit-composable: ``start`` may be a
    traced index (the 3-way engine slices with the traced pipeline offset
    ``j0``); ``count`` must be static.

    >>> import numpy as np
    >>> V = np.arange(12).reshape(3, 4) % 3
    >>> lhs = np.asarray(slice_planes_vectors(encode_bitplanes_np(V, 2), 1, 2))
    >>> bool((lhs == encode_bitplanes_np(V[:, 1:3], 2)).all())
    True
    """
    levels, kb, _ = P.shape
    return jax.lax.dynamic_slice(P, (0, 0, start), (levels, kb, count))


def take_planes_vectors(P, idx) -> np.ndarray:
    """Subset view: gather arbitrary vector columns of packed planes.

    The general-index sibling of ``slice_planes_vectors``: packing is along
    the *field* axis, so ANY vector-axis gather commutes with encoding —
    ``take_planes_vectors(encode(V), idx) == encode(V[:, idx])``
    bit-for-bit.  This is what lets batched phenotype-subset campaigns
    share one encoded payload: the union of all subsets is gathered once
    and the wire format is reused unmodified (no re-encode).  Host-side
    (numpy); indices may repeat and need not be sorted.

    >>> import numpy as np
    >>> V = np.arange(24).reshape(4, 6) % 3
    >>> lhs = take_planes_vectors(encode_bitplanes_np(V, 2), [4, 1, 3])
    >>> bool((lhs == encode_bitplanes_np(V[:, [4, 1, 3]], 2)).all())
    True
    """
    idx = np.asarray(idx, dtype=np.int64)
    return np.asarray(P)[:, :, idx]


def shard_planes_fields(P, rank: int, n_shards: int):
    """Byte-axis shard: the ``rank``-th of ``n_shards`` equal byte ranges.

    This is the "pf" sharding of the ring payload (``in_specs`` place the
    byte axis over the mesh's "pf" axis): shard ``r`` holds bytes
    ``[r*kb/n, (r+1)*kb/n)``, i.e. fields ``[8*r*kb/n, 8*(r+1)*kb/n)`` —
    encode the payload with ``field_align=n_shards`` so ``kb`` divides
    evenly.  Host-side mirror of what ``shard_map`` does, used by tests to
    pin the sharding semantics.
    """
    levels, kb, _ = P.shape
    if kb % n_shards:
        raise ValueError(
            f"byte axis ({kb}) does not split over {n_shards} shards; "
            f"encode with field_align={n_shards}"
        )
    kbs = kb // n_shards
    return P[:, rank * kbs:(rank + 1) * kbs, :]


def planes_nbytes(n_f: int, n_v: int, levels: int) -> int:
    """Packed payload size — the ring-traffic accounting used in docs/bench.

    >>> planes_nbytes(n_f=1000, n_v=512, levels=2)   # vs 4*1000*512 fp32
    128000
    """
    return levels * (-(-n_f // 8)) * n_v
