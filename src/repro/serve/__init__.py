from repro.serve.engine import ServeEngine, SimilarityService  # noqa: F401
