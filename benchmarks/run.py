"""Benchmark driver — one module per paper table/figure, plus the ``api``
module covering the unified SimilarityEngine per registered metric.

Prints ``name,us_per_call,derived`` CSV.  Scaling (Figs 6-10) runs in a
subprocess with 8 virtual devices; everything else runs on this process's
single device.  Dry-run-derived rows appear when results/dryrun is populated
(python -m repro.launch.dryrun --all).

Also writes ``BENCH_kernels.json`` at the repo root — the impl × size kernel
sweep (GiB/s and comparisons/s per entry) that anchors the perf trajectory:
future PRs regress their kernel changes against the last committed numbers.
"""
from __future__ import annotations

import json
import os
import sys
import traceback

BENCH_KERNELS = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_kernels.json",
)


def write_bench_kernels() -> str:
    import jax

    from benchmarks.bench_kernel import kernel_sweep

    payload = {
        "backend": jax.default_backend(),
        "note": "pallas* entries run in interpret mode off-TPU",
        "entries": kernel_sweep(),
    }
    with open(BENCH_KERNELS, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return BENCH_KERNELS


def main() -> None:
    from benchmarks import (
        bench_accel_ratio,
        bench_kernel,
        bench_max_rates,
        bench_metrics,
        bench_normalized,
        bench_phewas_sample,
        bench_scaling,
        roofline_report,
    )
    from benchmarks.util import print_rows

    modules = [
        ("table1", bench_kernel),
        ("api", bench_metrics),
        ("table2", bench_accel_ratio),
        ("fig6-10", bench_scaling),
        ("table3-4", bench_max_rates),
        ("table5", bench_phewas_sample),
        ("table6", bench_normalized),
        ("roofline", roofline_report),
    ]
    failed = []
    for name, mod in modules:
        try:
            rows = mod.main()
            if rows:
                print_rows(rows)
        except Exception:
            traceback.print_exc()
            failed.append(name)
    try:
        path = write_bench_kernels()
        print(f"wrote {path}")
    except Exception:
        traceback.print_exc()
        failed.append("bench-kernels-json")
    if failed:
        print(f"FAILED: {failed}")
        sys.exit(1)


if __name__ == "__main__":
    main()
