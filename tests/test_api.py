"""Tests for the unified repro.api subsystem: registry round-trip, request
validation, engine parity with the direct paths, streaming results,
save/load manifests, CCC oracle parity, and the zero-denominator guard."""
import numpy as np
import pytest

from repro.api import (
    InputSpec,
    MetricSpec,
    SimilarityEngine,
    SimilarityRequest,
    SimilarityResult,
    UnknownMetricError,
    available_metrics,
    get_metric,
    register_metric,
)
from repro.core.metrics import czek2_metric_np, safe_denom
from repro.core.synthetic import random_integer_vectors
from repro.core.threeway import czek3_distributed
from repro.core.twoway import CometConfig, czek2_distributed
from repro.parallel.mesh import make_comet_mesh


@pytest.fixture(scope="module")
def engine():
    return SimilarityEngine()


@pytest.fixture(scope="module")
def V():
    return random_integer_vectors(40, 18, max_value=15, seed=3)


# ---------------------------------------------------------------- registry --

def test_registry_roundtrip():
    names = available_metrics()
    assert "czekanowski" in names and "ccc" in names
    for name in names:
        spec = get_metric(name)
        assert spec.name == name
        assert 2 in spec.ways


def test_unknown_metric_error_lists_available():
    with pytest.raises(UnknownMetricError) as ei:
        get_metric("sorensen")
    assert "sorensen" in str(ei.value)
    assert "czekanowski" in str(ei.value)


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError):
        register_metric(get_metric("ccc"))


def test_custom_metric_plugs_in(engine, V):
    """A user-defined metric runs through the whole engine untouched."""
    import jax.numpy as jnp

    overlap = MetricSpec(
        name="test-overlap",
        description="unnormalized min overlap",
        ways=(2,),
        combine=jnp.minimum,
        stat=lambda Vl: Vl.astype(jnp.float32).sum(axis=0),
        assemble2=lambda n2, si, sj: n2,
        uses_mgemm=True,
    )
    try:
        register_metric(overlap)
        out = engine.run(SimilarityRequest(metric="test-overlap", way=2), V)
        n2 = np.minimum(V[:, :, None], V[:, None, :]).sum(axis=0)
        iu = np.triu_indices(V.shape[1], 1)
        np.testing.assert_allclose(out.dense()[iu], n2[iu], rtol=1e-6)
    finally:
        from repro.api import registry

        registry._METRICS.pop("test-overlap", None)


# -------------------------------------------------------------- validation --

def test_request_validation_bad_way():
    with pytest.raises(ValueError, match="way"):
        SimilarityRequest(way=4).validate()


def test_request_validation_decomposition_vs_devices(engine, V):
    req = SimilarityRequest(way=2, n_pv=4, n_pr=4)  # 16 ranks on 1 device
    with pytest.raises(ValueError, match="devices"):
        engine.run(req, V)


def test_request_validation_stages():
    with pytest.raises(ValueError, match="stages"):
        SimilarityRequest(way=3, n_st=2, stages=(2,)).validate()
    with pytest.raises(ValueError, match="3-way"):
        SimilarityRequest(way=2, stages=(0,)).validate()
    with pytest.raises(ValueError, match="staging"):
        SimilarityRequest(way=2, n_st=2).validate()


def test_unknown_metric_via_engine(engine, V):
    with pytest.raises(UnknownMetricError):
        engine.run(SimilarityRequest(metric="nope"), V)


def test_input_spec_materialize(engine):
    req = SimilarityRequest(
        way=2, input=InputSpec(source="synthetic", n_f=32, n_v=10, seed=1)
    )
    out = engine.run(req)
    assert out.num_results() == 10 * 9 // 2


# ------------------------------------------------------------------ parity --

def test_engine_matches_direct_czek2(engine, V):
    direct = czek2_distributed(V, make_comet_mesh(1, 1, 1), CometConfig())
    out = engine.run(SimilarityRequest(metric="czekanowski", way=2), V)
    assert out.checksum() == direct.checksum()
    assert out.num_results() == direct.num_pairs()


def test_engine_matches_direct_czek3(engine, V):
    direct = czek3_distributed(
        V[:, :12], make_comet_mesh(1, 1, 1), CometConfig(), stage=0
    )
    out = engine.run(SimilarityRequest(metric="czekanowski", way=3), V[:, :12])
    assert out.checksum() == direct.checksum()


def test_engine_staged_3way_unions_all_triples(engine, V):
    out = engine.run(
        SimilarityRequest(way=3, n_st=2, stages=None), V[:, :12]
    )
    assert out.stages == (0, 1)
    assert out.num_results() == 12 * 11 * 10 // 6
    # staged union checksum == single-stage run checksum
    single = engine.run(SimilarityRequest(way=3), V[:, :12])
    assert out.checksum() == single.checksum()


def test_ccc_matches_numpy_oracle_2way(engine, V):
    out = engine.run(SimilarityRequest(metric="ccc", way=2), V)
    ref = get_metric("ccc").oracle2(V).astype(np.float32)
    iu = np.triu_indices(V.shape[1], 1)
    np.testing.assert_allclose(out.dense()[iu], ref[iu], rtol=1e-5)


def test_ccc_matches_numpy_oracle_3way(engine, V):
    W = V[:, :10]
    out = engine.run(SimilarityRequest(metric="ccc", way=3), W)
    ref = get_metric("ccc").oracle3(W).astype(np.float32)
    d = out.dense()
    for i in range(10):
        for j in range(i + 1, 10):
            for k in range(j + 1, 10):
                np.testing.assert_allclose(d[i, j, k], ref[i, j, k], rtol=2e-5)


# ------------------------------------------------------------------ result --

def test_tiles_stream_covers_entries(engine, V):
    out = engine.run(SimilarityRequest(way=2), V)
    from_tiles = sum(len(t) for t in out.tiles())
    assert from_tiles == out.num_results() == V.shape[1] * (V.shape[1] - 1) // 2
    for tile in out.tiles():
        assert tile.way == 2
        assert len(tile.index) == 2
        assert len(tile.index[0]) == len(tile.values)


def test_save_load_checksum_equality_2way(engine, V, tmp_path):
    out = engine.run(SimilarityRequest(way=2), V)
    out.save(str(tmp_path / "c2"))
    back = SimilarityResult.load(str(tmp_path / "c2"))
    assert back.checksum() == out.checksum()
    assert back.metric == "czekanowski"
    np.testing.assert_array_equal(back.dense(), out.dense())


def test_save_load_checksum_equality_3way_staged(engine, V, tmp_path):
    out = engine.run(SimilarityRequest(way=3, n_st=2, stages=None), V[:, :12])
    out.save(str(tmp_path / "c3"))
    back = SimilarityResult.load(str(tmp_path / "c3"))
    assert back.checksum() == out.checksum()
    assert back.stages == (0, 1)


def test_save_load_packed_storage(engine, V, tmp_path):
    """packed=True: smaller blocks on disk, identical checksum after load."""
    dense = engine.run(SimilarityRequest(way=2), V)
    packed = engine.run(SimilarityRequest(way=2, packed=True), V)
    assert packed.storage == "packed"
    assert packed.checksum() == dense.checksum()
    assert packed.outputs[0].nbytes < dense.outputs[0].nbytes
    packed.save(str(tmp_path / "cp"))
    back = SimilarityResult.load(str(tmp_path / "cp"))
    assert back.storage == "packed"
    assert back.checksum() == dense.checksum()
    np.testing.assert_array_equal(back.dense(), dense.dense())


def test_packed_request_rejected_for_3way():
    with pytest.raises(ValueError, match="packed"):
        SimilarityRequest(way=3, packed=True).validate()


def test_load_detects_corruption(engine, V, tmp_path):
    out = engine.run(SimilarityRequest(way=2), V)
    out.save(str(tmp_path / "c"))
    blocks = np.load(tmp_path / "c" / "blocks_s0.npy")
    blocks[blocks > 0] *= np.float32(0.5)
    np.save(tmp_path / "c" / "blocks_s0.npy", blocks)
    with pytest.raises(ValueError, match="checksum"):
        SimilarityResult.load(str(tmp_path / "c"))


# ------------------------------------------------------- zero-denominators --

def test_all_zero_vector_yields_zero_not_nan(engine, V):
    Vz = V.copy()
    Vz[:, 4] = 0
    for metric in available_metrics():
        out = engine.run(SimilarityRequest(metric=metric, way=2), Vz)
        d = out.dense()
        assert np.isfinite(d).all(), f"{metric}: non-finite metric values"
        assert (d[4] == 0).all() and (d[:, 4] == 0).all(), metric
    # oracles agree (safe_denom unification)
    ref = czek2_metric_np(Vz)
    assert np.isfinite(ref).all()
    assert (ref[4, :4] == 0).all()


def test_safe_denom_identity_on_nonzero():
    d = np.array([1e-3, 2.0, 7.5])
    np.testing.assert_array_equal(safe_denom(d), d)


# ----------------------------------------------------------------- serving --

def test_similarity_service_routes_through_engine(V):
    from repro.serve import SimilarityService

    svc = SimilarityService(max_cached_results=2)
    req = SimilarityRequest(metric="czekanowski", way=2)
    r1 = svc.submit(req, V)
    r2 = svc.submit(req, V)  # identical request+input -> cache hit
    assert r2 is r1
    assert svc.stats() == {
        "hits": 1, "misses": 1, "cached_results": 1, "delta_hits": 0,
        "in_flight": 0, "submitted": 2, "warmups": 0, "errors": 0,
    }
    direct = czek2_distributed(V, make_comet_mesh(1, 1, 1), CometConfig())
    assert r1.checksum() == direct.checksum()
    # different input -> distinct result
    r3 = svc.submit(req, V + 1)
    assert r3.checksum() != r1.checksum()
    assert svc.stats()["misses"] == 2
