"""Pure-jnp oracle for the level-decomposition mGEMM."""
import jax.numpy as jnp


def mgemm_levels_ref(A, B, *, levels: int, out_dtype=jnp.float32):
    """sum_t 1[A>=t] @ 1[B>=t] — exact min-plus GEMM for ints in [0, levels]."""
    acc = jnp.zeros((A.shape[0], B.shape[1]), jnp.float32)
    for t in range(1, levels + 1):
        acc += (A >= t).astype(jnp.float32) @ (B >= t).astype(jnp.float32)
    return acc.astype(out_dtype)
