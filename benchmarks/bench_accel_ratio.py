"""Paper Table 2: accelerated vs reference implementation ratio.

The paper reports GPU/CPU = 41x (2-way) and 27x (3-way).  The analogue
here: the vectorized engine path vs a naive nested-loop reference on the
same hardware (CPU), measuring the framework's acceleration over the
straightforward implementation.
"""
from __future__ import annotations

import numpy as np

from benchmarks.util import row, time_fn
from repro.core.mgemm import mgemm_xla
from repro.core.synthetic import random_integer_vectors

N_F, N_V = 256, 192
N_V3 = 48


def _naive_2way(V):
    n_f, n_v = V.shape
    out = np.zeros((n_v, n_v), np.float32)
    for i in range(n_v):
        for j in range(i + 1, n_v):
            out[i, j] = np.minimum(V[:, i], V[:, j]).sum()
    return out


def _naive_3way(V):
    n_f, n_v = V.shape
    out = np.zeros((n_v, n_v, n_v), np.float32)
    for i in range(n_v):
        for j in range(i + 1, n_v):
            mij = np.minimum(V[:, i], V[:, j])
            for k in range(j + 1, n_v):
                out[i, j, k] = np.minimum(mij, V[:, k]).sum()
    return out


def main():
    import jax.numpy as jnp

    V = random_integer_vectors(N_F, N_V, seed=0)
    Vj = jnp.asarray(V)
    t_naive2 = time_fn(lambda v: _naive_2way(v), V, warmup=0, iters=1)
    t_fast2 = time_fn(lambda v: mgemm_xla(v.T, v), Vj)

    V3 = random_integer_vectors(N_F, N_V3, seed=1)
    V3j = jnp.asarray(V3)

    def fast3(v):
        # B_j sweep via batched min-plus GEMM (the engine's inner step)
        X = jnp.minimum(v[:, :, None], v[:, None, :]).reshape(N_F, -1)
        return mgemm_xla(X.T, v)

    t_naive3 = time_fn(lambda v: _naive_3way(v), V3, warmup=0, iters=1)
    t_fast3 = time_fn(fast3, V3j)

    return [
        row("table2/2way_naive", t_naive2, ""),
        row("table2/2way_accel", t_fast2, f"ratio={t_naive2 / t_fast2:.1f}x"),
        row("table2/3way_naive", t_naive3, ""),
        row("table2/3way_accel", t_fast3, f"ratio={t_naive3 / t_fast3:.1f}x"),
    ]


if __name__ == "__main__":
    from benchmarks.util import print_rows

    print_rows(main())
