"""StreamPlan — the out-of-core chunk schedule over a store's byte axis.

A streamed campaign never holds the full ``(levels, kb, n_v)`` payload in
host RAM.  Instead the global byte (field) axis is cut into fixed-size
chunks of ``chunk_kb`` bytes; each chunk is staged into a reusable host
buffer of shape ``(levels, chunk_kb, n_v_padded)`` and fed through the
deferred device program as if it were the whole campaign payload.  Because
the byte axis is the CONTRACTION axis and zero bytes encode zero fields
(inert in every plane GEMM), the per-chunk partial numerators and partial
stats simply ADD across chunks — the cross-shard merge epilogue
(``repro.stream.pipeline``) applies the metric assembly once at the end.

Geometry rules:

* ``chunk_kb`` is a multiple of ``n_pf`` so every chunk's byte axis splits
  evenly over the "pf" mesh axis (the same rule ``pad_planes(byte_align=
  n_pf)`` enforces for in-memory campaigns).
* every chunk buffer has the SAME static shape — the tail chunk is
  zero-padded — so one compiled program serves the whole stream.
* disk shards are mmap views; a chunk may span shard-file boundaries, so
  each chunk carries explicit ``(shard, lo, hi, buf_offset)`` spans.

Host-memory accounting: double buffering stages at most two chunks at once
(one being computed, one being prefetched), so

    peak_host_bytes = min(2, n_chunks) * levels * chunk_kb * n_v_padded

and ``max_host_bytes`` bounds that peak — NOT the dataset size.  When the
budget cannot fit two minimal (``chunk_kb = n_pf``) chunks the plan raises
instead of silently overshooting.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

__all__ = ["StreamChunk", "StreamPlan", "fill_chunk"]


@dataclass(frozen=True)
class StreamChunk:
    """One staged byte range of the global payload."""

    index: int
    start: int  # global byte offset (inclusive)
    stop: int  # global byte offset (exclusive), <= plan.kb
    #: ((shard_rank, shard_lo, shard_hi, buf_offset), ...) — the mmap
    #: sub-ranges that fill this chunk's buffer (chunks may cross disk
    #: shard file boundaries)
    spans: tuple

    @property
    def nbytes_valid(self) -> int:
        return self.stop - self.start


@dataclass(frozen=True)
class StreamPlan:
    """Chunk schedule for one streamed campaign.

    ``kb``/``kbs``/``n_shards``/``levels`` describe the on-disk payload;
    ``n_v`` is the PADDED campaign vector count (the staging buffers carry
    the campaign geometry so chunks feed ``shard_map`` directly);
    ``n_v_data`` the true on-disk column count (columns past it stay zero).
    """

    levels: int
    kb: int  # true payload byte length (ceil(n_f / 8))
    kbs: int  # disk shard byte length (kb / n_shards)
    n_shards: int
    n_v: int  # padded campaign vector count (buffer width)
    n_v_data: int  # true dataset vector count
    n_pf: int
    chunk_kb: int
    max_host_bytes: int = 0  # 0 = unbounded (informational)

    def __post_init__(self):
        if self.chunk_kb < 1 or self.chunk_kb % self.n_pf:
            raise ValueError(
                f"chunk_kb={self.chunk_kb} must be a positive multiple of "
                f"n_pf={self.n_pf}"
            )
        if self.kb != self.kbs * self.n_shards:
            raise ValueError(
                f"kb={self.kb} != kbs={self.kbs} * n_shards={self.n_shards}"
            )

    # -- derived sizes ------------------------------------------------------

    @property
    def n_chunks(self) -> int:
        return max(1, math.ceil(self.kb / self.chunk_kb))

    @property
    def chunk_shape(self) -> tuple:
        """Static staging-buffer shape (identical for every chunk)."""
        return (self.levels, self.chunk_kb, self.n_v)

    @property
    def chunk_nbytes(self) -> int:
        return self.levels * self.chunk_kb * self.n_v

    @property
    def n_buffers(self) -> int:
        """Staging buffers allocated: 2 (double buffering), or 1 when the
        whole payload fits a single chunk."""
        return min(2, self.n_chunks)

    @property
    def peak_host_bytes(self) -> int:
        """Bound on staged payload bytes resident at once."""
        return self.n_buffers * self.chunk_nbytes

    # -- schedule -----------------------------------------------------------

    def chunks(self) -> list:
        """All chunks in stream order, with their disk-shard spans."""
        out = []
        for c in range(self.n_chunks):
            start = c * self.chunk_kb
            stop = min(start + self.chunk_kb, self.kb)
            spans = []
            g = start
            while g < stop:
                rank = g // self.kbs
                lo = g - rank * self.kbs
                hi = min(self.kbs, lo + (stop - g))
                spans.append((rank, lo, hi, g - start))
                g += hi - lo
            out.append(StreamChunk(index=c, start=start, stop=stop,
                                   spans=tuple(spans)))
        return out

    # -- construction -------------------------------------------------------

    @classmethod
    def plan(
        cls, *, levels: int, kb: int, kbs: int, n_shards: int, n_v: int,
        n_v_data: int, n_pf: int = 1, max_host_bytes: int = 0,
    ) -> "StreamPlan":
        """Pick ``chunk_kb`` for a campaign.

        Default (no budget): one disk shard per chunk, rounded up to the
        ``n_pf`` multiple — the store's shard files ARE the natural I/O
        unit.  With ``max_host_bytes``: the largest ``n_pf``-multiple chunk
        whose double-buffered staging fits the budget.
        """
        full = -(-kb // n_pf) * n_pf  # one chunk covering everything
        if max_host_bytes:
            row_bytes = levels * n_v  # host bytes per staged payload byte
            budget_kb = max_host_bytes // (2 * row_bytes)
            chunk_kb = (budget_kb // n_pf) * n_pf
            if chunk_kb < n_pf:
                need = 2 * row_bytes * n_pf
                raise ValueError(
                    f"max_host_bytes={max_host_bytes} cannot stage two "
                    f"minimal chunks (need >= {need} bytes for chunk_kb="
                    f"{n_pf} double-buffered); raise the budget or lower "
                    f"n_pf/levels"
                )
            chunk_kb = min(chunk_kb, full)
        else:
            chunk_kb = min(max(-(-kbs // n_pf) * n_pf, n_pf), full)
        return cls(
            levels=levels, kb=kb, kbs=kbs, n_shards=n_shards, n_v=n_v,
            n_v_data=n_v_data, n_pf=n_pf, chunk_kb=chunk_kb,
            max_host_bytes=max_host_bytes,
        )

    @classmethod
    def for_reader(cls, reader, *, n_v: int, n_pf: int = 1,
                   max_host_bytes: int = 0) -> "StreamPlan":
        """Plan over a ``DatasetReader``-shaped object (manifest dims)."""
        return cls.plan(
            levels=reader.levels, kb=reader.kb,
            kbs=reader.kb // reader.n_shards, n_shards=reader.n_shards,
            n_v=n_v, n_v_data=reader.n_v, n_pf=n_pf,
            max_host_bytes=max_host_bytes,
        )


def fill_chunk(buf, chunk: StreamChunk, shard_of, n_v_data: int) -> None:
    """Copy one chunk's shard spans into a staging buffer (in place).

    ``shard_of(rank)`` returns the ``(levels, kbs, n_v_data)`` shard view
    (typically an ``np.memmap``); the copy out of it is what actually
    faults the file pages in, so running this on the prefetch thread
    overlaps disk I/O with device compute.  Bytes past the valid range
    (tail chunk) are zeroed — zero bytes encode zero fields, inert in any
    plane contraction.  Columns past ``n_v_data`` are campaign padding and
    are never written (the pipeline zeroes them once at allocation).
    """
    for rank, lo, hi, off in chunk.spans:
        buf[:, off:off + (hi - lo), :n_v_data] = shard_of(rank)[:, lo:hi, :]
    used = chunk.nbytes_valid
    if used < buf.shape[1]:
        buf[:, used:, :] = 0
