"""Oracle-level tests for the Proportional Similarity metric definitions."""
import numpy as np
import pytest

from repro.core import metrics
from repro.core.synthetic import analytic_window_vectors, random_integer_vectors


def test_czek2_matches_numpy_oracle():
    V = random_integer_vectors(40, 12, seed=1)
    got = np.asarray(metrics.czek2_metric(V))
    want = metrics.czek2_metric_np(V)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_czek2_symmetry_and_selfsimilarity():
    V = random_integer_vectors(30, 9, seed=2).astype(np.float64)
    c = np.asarray(metrics.czek2_metric(V))
    np.testing.assert_allclose(c, c.T)
    np.testing.assert_allclose(np.diag(c), 1.0)  # c2(v, v) = 1


def test_czek2_range():
    V = random_integer_vectors(25, 14, seed=3)
    c = np.asarray(metrics.czek2_metric(V))
    assert (c >= 0).all() and (c <= 1 + 1e-6).all()


def test_czek3_matches_numpy_oracle():
    V = random_integer_vectors(20, 7, seed=4)
    got = np.asarray(metrics.czek3_metric(V))
    want = metrics.czek3_metric_np(V)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_czek3_permutation_symmetry():
    V = random_integer_vectors(15, 6, seed=5).astype(np.float64)
    c = np.asarray(metrics.czek3_metric(V))
    for perm in [(0, 2, 1), (1, 0, 2), (2, 1, 0), (1, 2, 0), (2, 0, 1)]:
        np.testing.assert_allclose(c, np.transpose(c, perm))


def test_czek3_reduces_to_czek2_when_duplicated():
    # c3(u, u, w): n3 = n2(u,u) + 2 n2(u,w) - n2(u,w) = s_u + n2(u,w)
    V = random_integer_vectors(18, 5, seed=6).astype(np.float64)
    c3 = np.asarray(metrics.czek3_metric(V))
    s = V.sum(axis=0)
    n2 = np.asarray(metrics.czek2_numerators(V))
    for u in range(5):
        for w in range(5):
            want = 1.5 * (s[u] + n2[u, w]) / (2 * s[u] + s[w])
            np.testing.assert_allclose(c3[u, u, w], want, rtol=1e-6)


def test_analytic_windows_n2_and_n3():
    V, aw = analytic_window_vectors(48, 20, width=10, seed=7)
    # brute force overlaps
    n2_ref = np.minimum(V[:, :, None], V[:, None, :]).sum(axis=0)
    I, J = np.meshgrid(np.arange(20), np.arange(20), indexing="ij")
    np.testing.assert_allclose(aw.n2(I, J), n2_ref)
    np3_ref = np.minimum(
        np.minimum(V[:, :, None, None], V[:, None, :, None]), V[:, None, None, :]
    ).sum(axis=0)
    I, J, K = np.meshgrid(*([np.arange(20)] * 3), indexing="ij")
    np.testing.assert_allclose(aw.nprime3(I, J, K), np3_ref)


def test_analytic_windows_metrics():
    V, aw = analytic_window_vectors(60, 15, width=12, seed=8)
    c2 = metrics.czek2_metric_np(V)
    I, J = np.meshgrid(np.arange(15), np.arange(15), indexing="ij")
    np.testing.assert_allclose(aw.c2(I, J), c2, rtol=1e-12)
    c3 = metrics.czek3_metric_np(V)
    I, J, K = np.meshgrid(*([np.arange(15)] * 3), indexing="ij")
    np.testing.assert_allclose(aw.c3(I, J, K), c3, rtol=1e-12)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_integer_inputs_are_exact(dtype):
    """Integer-valued inputs make sums order-independent (paper's bit-for-bit
    reproducibility depends on this)."""
    V = random_integer_vectors(100, 8, max_value=31, seed=9, dtype=dtype)
    n = np.asarray(metrics.czek2_numerators(V))
    # permuting the field axis must give bit-identical numerators
    perm = np.random.default_rng(0).permutation(100)
    n2 = np.asarray(metrics.czek2_numerators(V[perm]))
    assert (n == n2).all()
