"""Similarity-campaign launcher: the paper's workload as a CLI.

    python -m repro.launch.similarity --way 2 --n-f 1000 --n-v 512 \
        --n-pv 4 --n-pr 2 --devices 8 --out /tmp/metrics

Computes all unique 2-way (or staged 3-way) Proportional Similarity metrics
over a synthetic or .npy dataset, writes per-rank metric blocks + a manifest
with the exact checksum (paper §5), and prints throughput in elementwise
comparisons/second (the paper's headline metric).
"""
import argparse
import json
import os
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--way", type=int, default=2, choices=(2, 3))
    ap.add_argument("--n-f", type=int, default=512)
    ap.add_argument("--n-v", type=int, default=240)
    ap.add_argument("--n-pf", type=int, default=1)
    ap.add_argument("--n-pv", type=int, default=1)
    ap.add_argument("--n-pr", type=int, default=1)
    ap.add_argument("--n-st", type=int, default=1)
    ap.add_argument("--stage", type=int, default=0)
    ap.add_argument("--devices", type=int, default=0,
                    help="force host device count (set before jax init)")
    ap.add_argument("--impl", default="xla")
    ap.add_argument("--levels", type=int, default=2)
    ap.add_argument("--input", default="", help=".npy (n_f, n_v) input")
    ap.add_argument("--max-value", type=int, default=15)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", "")
        )
    import numpy as np

    from repro.core.synthetic import random_integer_vectors
    from repro.core.threeway import czek3_distributed
    from repro.core.twoway import CometConfig, czek2_distributed
    from repro.parallel.mesh import make_comet_mesh

    if args.input:
        V = np.load(args.input)
    else:
        V = random_integer_vectors(
            args.n_f, args.n_v, max_value=args.max_value, seed=args.seed
        )
    cfg = CometConfig(
        n_pf=args.n_pf, n_pv=args.n_pv, n_pr=args.n_pr, n_st=args.n_st,
        impl=args.impl, levels=args.levels,
    )
    mesh = make_comet_mesh(args.n_pf, args.n_pv, args.n_pr)
    t0 = time.time()
    if args.way == 2:
        out = czek2_distributed(V, mesh, cfg)
        n_results = out.num_pairs()
        comparisons = n_results * V.shape[0]
    else:
        out = czek3_distributed(V, mesh, cfg, stage=args.stage)
        n_results = out.num_triples()
        comparisons = n_results * V.shape[0]
    dt = time.time() - t0
    checksum = out.checksum()
    print(f"way={args.way} n_f={V.shape[0]} n_v={V.shape[1]} "
          f"decomp=({cfg.n_pf},{cfg.n_pv},{cfg.n_pr}) stage={args.stage}")
    print(f"results={n_results} time={dt:.3f}s "
          f"rate={comparisons / dt:.3e} comparisons/s")
    print(f"checksum={hex(checksum)}")
    if args.out:
        os.makedirs(args.out, exist_ok=True)
        np.save(os.path.join(args.out, "blocks.npy"), out.blocks)
        with open(os.path.join(args.out, "manifest.json"), "w") as f:
            json.dump(
                {
                    "way": args.way, "n_f": int(V.shape[0]), "n_v": int(V.shape[1]),
                    "decomposition": [cfg.n_pf, cfg.n_pv, cfg.n_pr],
                    "n_st": cfg.n_st, "stage": args.stage,
                    "results": int(n_results), "seconds": dt,
                    "checksum": hex(checksum),
                },
                f, indent=2,
            )
    return 0


if __name__ == "__main__":
    sys.exit(main())
