"""2-way block-circulant schedule — paper §4.1, Figure 2(c), Algorithm 1.

The all-pairs result matrix M (n_v x n_v, symmetric) is tiled into
``n_pv x n_pv`` blocks by the vector-number decomposition.  A naive
upper-triangle assignment load-imbalances block rows; the paper instead
computes the *block-circulant* subset

    step d = 0 .. floor(n_pv / 2):   rank p computes block (p, (p + d) % n_pv)

which covers every unordered block pair exactly once and gives every rank the
same number of blocks (±1 when n_pv is even: at the final step d = n_pv/2
only ranks p < n_pv/2 compute, since block (p, p + n_pv/2) and block
(p + n_pv/2, p) are transposes of each other).

The extra ``n_pr`` axis round-robins ring steps across replicas:
rank (p_v, p_r) executes step d iff d % n_pr == p_r  (Algorithm 1).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

__all__ = ["TwoWayPlan", "covered_block_pairs", "global_pairs_of_block"]


@dataclass(frozen=True)
class TwoWayPlan:
    n_pv: int  # ranks along the vector-number axis
    n_pr: int  # round-robin replicas per block row

    @property
    def n_steps(self) -> int:
        """Ring steps d = 0 .. n_pv // 2 inclusive."""
        return self.n_pv // 2 + 1

    @property
    def ring_steps(self) -> int:
        """Payload ppermutes per rank across the traversal (step 0 uses the
        resident block, every later step is one ring hop) — the batched-
        campaign accounting's per-rank hop count."""
        return self.n_steps - 1

    @property
    def slots_per_rank(self) -> int:
        """Upper bound of steps any (p_v, p_r) rank executes (buffer size)."""
        return math.ceil(self.n_steps / self.n_pr)

    def steps_of_pr(self, p_r: int) -> list[int]:
        return [d for d in range(self.n_steps) if d % self.n_pr == p_r]

    def is_half_step(self, d: int) -> bool:
        """Even n_pv final step: only ranks p_v < n_pv/2 compute."""
        return self.n_pv % 2 == 0 and d == self.n_pv // 2

    def rank_computes(self, p_v: int, p_r: int, d: int) -> bool:
        if d % self.n_pr != p_r:
            return False
        if self.is_half_step(d):
            return p_v < self.n_pv // 2
        return True

    def block_of(self, p_v: int, d: int) -> tuple[int, int]:
        """(row_block, col_block) computed by rank row p_v at step d."""
        return (p_v, (p_v + d) % self.n_pv)

    # -- verification helpers (tests) ------------------------------------

    def all_computed_blocks(self) -> list[tuple[int, int, int]]:
        """Every (p_v, d, col_block) actually computed across ranks."""
        out = []
        for d in range(self.n_steps):
            for p_v in range(self.n_pv):
                if self.is_half_step(d) and p_v >= self.n_pv // 2:
                    continue
                out.append((p_v, d, (p_v + d) % self.n_pv))
        return out

    def work_per_rank(self) -> np.ndarray:
        """(n_pv, n_pr) block counts — load balance check."""
        w = np.zeros((self.n_pv, self.n_pr), np.int64)
        for d in range(self.n_steps):
            p_r = d % self.n_pr
            for p_v in range(self.n_pv):
                if self.is_half_step(d) and p_v >= self.n_pv // 2:
                    continue
                w[p_v, p_r] += 1
        return w


def covered_block_pairs(n_pv: int) -> list[tuple[int, int]]:
    """Unordered block pairs covered by the circulant schedule (w/ diagonal)."""
    plan = TwoWayPlan(n_pv, 1)
    return [tuple(sorted((r, c))) for r, _, c in plan.all_computed_blocks()]


def global_pairs_of_block(
    row_block: int, col_block: int, n_vp: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Global (i, j) indices + validity mask for one computed block.

    Returns (I, J, mask) each (n_vp, n_vp); mask excludes the redundant
    lower-triangle + diagonal of diagonal blocks (i == j never a pair).
    """
    li = np.arange(n_vp)
    I = row_block * n_vp + li[:, None] + np.zeros((1, n_vp), np.int64)
    J = col_block * n_vp + li[None, :] + np.zeros((n_vp, 1), np.int64)
    if row_block == col_block:
        mask = li[:, None] < li[None, :]
    else:
        mask = np.ones((n_vp, n_vp), bool)
    return I, J, mask
