"""Normalization layers (RMSNorm / LayerNorm) — fp32 statistics."""
from __future__ import annotations

import jax.numpy as jnp


def rms_norm(x, scale, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jnp.reciprocal(jnp.sqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jnp.reciprocal(jnp.sqrt(var + eps))
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)
