from .kernel import tri_tile_coords, unpack_tri_tiles  # noqa: F401
from .ops import czek2_metric, metric2_tiles, metric2_tri, mgemm  # noqa: F401
from .ref import czek2_metric_ref, mgemm_ref  # noqa: F401
