"""Streamed campaigns: disk -> host -> device chunks + cross-shard merge.

``stream_twoway`` / ``stream_threeway`` run the SAME block-circulant /
tetrahedral schedules as the in-memory engines, but over the store's byte
axis one chunk at a time:

1. ``StreamPlan`` cuts the payload byte (field) axis into fixed-shape
   chunks (``repro.stream.plan``);
2. ``ShardPrefetcher`` stages chunk ``s+1`` from the shard mmaps while the
   device runs chunk ``s`` (``repro.stream.prefetch``);
3. each chunk runs a deferred-flush device program (``_twoway_deferred_
   program`` / ``_threeway_program(deferred=True)``) that emits raw fp32
   numerator partials psummed over "pf", plus the chunk's per-vector stat
   partial;
4. the host accumulates partials across chunks in fp32, and the **cross-
   shard merge epilogue** applies the metric assembly + symmetry masks
   once — producing ``TwoWayOutput`` / ``ThreeWayOutput`` blocks laid out
   exactly like an in-memory run's.

Bit-exactness: the byte axis is the CONTRACTION axis, numerator and stat
partials of leveled integer data are exact fp32 integers, and fp32
addition of exact integers is associative — so chunk-order accumulation is
bit-identical to the in-memory single-pass psum, and the merged assembly
(the same ``assemble2`` / ``assemble3`` fp32 ops) yields bit-identical
checksums across ANY chunking (pinned in tests/test_stream.py against
``impl="xla"`` in-memory runs).

Peak host payload memory is ``StreamPlan.peak_host_bytes`` — the staging
buffers, bounded by ``max_host_bytes`` — never the dataset size.
"""
from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.obs import trace as obs
from repro.parallel.compat import shard_map

from repro.core.metric_spec import (
    CZEKANOWSKI,
    MetricSpec,
    batch_lead,
    group_families,
)
from repro.core.plan2 import TwoWayPlan
from repro.core.plan3 import ItemKind, ThreeWayPlan
from repro.core.threeway import ThreeWayOutput, _threeway_program
from repro.core.tile_executor import TileExecutor
from repro.core.twoway import (
    CometConfig,
    TwoWayOutput,
    _twoway_deferred_batched_program,
    _twoway_deferred_program,
    batch_accounting,
    resolve_config,
)
from repro.stream.plan import StreamPlan, fill_chunk
from repro.stream.prefetch import ShardPrefetcher

__all__ = [
    "stream_twoway",
    "stream_threeway",
    "stream_twoway_batched",
    "stream_threeway_batched",
    "stream_twoway_delta",
]


def _as_sharded(dataset):
    """Accept a dataset path, DatasetReader, or ShardedPlanes handle."""
    from repro.store.reader import DatasetReader, ShardedPlanes

    if isinstance(dataset, ShardedPlanes):
        return dataset
    if isinstance(dataset, DatasetReader):
        return dataset.sharded()
    return DatasetReader(dataset).sharded()


def _stream_info(splan: StreamPlan, cfg: CometConfig, n_shards: int) -> dict:
    """The accounting block engines record as ``meta["stream"]``."""
    return {
        "chunks": splan.n_chunks,
        "chunk_kb": splan.chunk_kb,
        "chunk_bytes": splan.chunk_nbytes,
        "n_buffers": splan.n_buffers,
        "peak_host_bytes": splan.peak_host_bytes,
        "max_host_bytes": cfg.max_host_bytes,
        "n_shards": n_shards,
    }


def _run_chunks(sh, splan: StreamPlan, jfn, accs, stat_acc, n_devices=1):
    """Drive the prefetch/compute loop: stage each chunk, run the deferred
    program, fold the fp32 partials into the host accumulators.

    ``accs`` is a list of numpy accumulator arrays matching the program's
    leading outputs; the last program output is always the stat partial,
    folded into ``stat_acc``.  Returns ``(staged_bytes, overlap)`` —
    measured peak staged bytes (the buffers actually allocated, the
    number ``max_host_bytes`` bounds) and the staging-vs-compute overlap
    accounting (``stage_seconds``, ``stall_seconds``, ``compute_seconds``)
    that joins ``meta["stream"]``.
    """
    chunks = splan.chunks()
    buffers = [np.zeros(splan.chunk_shape, np.uint8)
               for _ in range(splan.n_buffers)]
    shard_cache = {}

    def shard_of(rank):
        if rank not in shard_cache:
            shard_cache[rank] = sh.reader.shard(rank)
        return shard_cache[rank]

    def fill(idx, buf):
        fill_chunk(buf, chunks[idx], shard_of, splan.n_v_data)

    compute_s = 0.0
    with ShardPrefetcher(fill, len(chunks), buffers) as pf:
        for _idx, buf in pf:
            t0 = time.perf_counter()
            with obs.span("ring-step") as sp:
                outs = jfn(jnp.asarray(buf))
                # np.asarray blocks until the chunk program is done (GIL
                # released inside XLA — the prefetch thread fills the next
                # buffer meanwhile); only then is the staging buffer reusable
                for acc, out in zip(accs, outs[:-1]):
                    np.add(acc, np.asarray(out).reshape(acc.shape), out=acc)
                np.add(stat_acc, np.asarray(outs[-1]).reshape(stat_acc.shape),
                       out=stat_acc)
                sp.add(chunk=_idx, chunk_bytes=int(buf.nbytes))
            compute_s += time.perf_counter() - t0
            pf.release(buf)
        overlap = {
            "stage_seconds": pf.stage_seconds,
            "stall_seconds": pf.stall_seconds,
            "compute_seconds": compute_s,
        }
    if obs.enabled():
        obs.roofline_event(jfn, (jnp.asarray(buffers[0]),), n_devices,
                           repeats=len(chunks))
    return sum(b.nbytes for b in buffers), overlap


def _merge_twoway_blocks(cfg, plan, executor, acc, stats) -> np.ndarray:
    """Cross-shard merge epilogue for ONE metric: assemble every computed
    block once from its complete fp32 numerator/stat partials.  ``acc`` is
    (n_pv, n_pr, slots, m, m), ``stats`` (n_pv, m) — the single-metric
    slices; batched campaigns call this once per metric over the shared
    per-family accumulators."""
    blocks = np.zeros(acc.shape, executor.out_dtype)
    for p_v in range(cfg.n_pv):
        for p_r in range(cfg.n_pr):
            for d in plan.steps_of_pr(p_r):
                if not plan.rank_computes(p_v, p_r, d):
                    continue
                row, col = plan.block_of(p_v, d)
                blocks[p_v, p_r, d // cfg.n_pr] = np.asarray(
                    executor.merge_pair(
                        acc[p_v, p_r, d // cfg.n_pr],
                        stats[row], stats[col], diagonal=(d == 0),
                    )
                )
    return blocks


def stream_twoway(
    dataset, mesh, cfg: CometConfig, metric: MetricSpec = None,
) -> tuple:
    """Streamed 2-way campaign over a ``repro.store`` dataset.

    Returns ``(TwoWayOutput, info)`` — the output bit-identical to
    ``twoway_distributed`` on the materialized payload, ``info`` the
    streaming accounting (chunks, peak host bytes).
    """
    metric = metric or CZEKANOWSKI
    sh = _as_sharded(dataset)
    cfg = resolve_config(cfg, sh, metric)  # plane path or raises
    n_v = sh.n_v
    n_vp = -(-n_v // cfg.n_pv)
    plan = TwoWayPlan(cfg.n_pv, cfg.n_pr)
    splan = StreamPlan.for_reader(
        sh.reader, n_v=cfg.n_pv * n_vp, n_pf=cfg.n_pf,
        max_host_bytes=cfg.max_host_bytes,
    )

    jfn = jax.jit(shard_map(
        partial(_twoway_deferred_program, cfg=cfg, plan=plan, metric=metric),
        mesh=mesh,
        in_specs=P(None, "pf", "pv"),
        out_specs=(P("pv", "pr", None, None, None), P("pv", None)),
        check=False,
    ))

    acc = np.zeros(
        (cfg.n_pv, cfg.n_pr, plan.slots_per_rank, n_vp, n_vp), np.float32
    )
    stats = np.zeros((cfg.n_pv, n_vp), np.float32)
    staged, overlap = _run_chunks(
        sh, splan, jfn, [acc], stats, n_devices=int(mesh.devices.size)
    )

    # -- cross-shard merge epilogue: assemble once from complete partials --
    executor = TileExecutor(
        cfg=cfg, metric=metric, out_dtype=jnp.dtype(cfg.out_dtype),
        axis=None, deferred=True,
    )
    with obs.span("merge") as sp:
        blocks = _merge_twoway_blocks(cfg, plan, executor, acc, stats)
        sp.add(blocks=int(blocks.size))
    out = TwoWayOutput(blocks=blocks, plan=plan, n_v=n_v, n_vp=n_vp)
    info = _stream_info(splan, cfg, sh.n_shards)
    info["staged_bytes"] = staged
    info.update(overlap)
    return out, info


def _merge_threeway_blocks(
    cfg, plan, stage, executor, needs, accs, stats, L, n_vp,
) -> np.ndarray:
    """Cross-shard 3-way merge epilogue for ONE metric (mask logic mirrors
    ``ThreeWayOutput.entries()``).  ``accs`` is the single-metric 4-tuple
    of slot-partial accumulators, ``stats`` the metric's (n_pv, m) stat
    rows; batched campaigns call this once per metric over its family's
    slices of the shared accumulators."""
    B_acc, pl_acc, pr_acc, lr_acc = accs
    blocks = np.zeros(B_acc.shape, executor.out_dtype)
    li = np.arange(n_vp)
    for p_v in range(cfg.n_pv):
        for p_r in range(cfg.n_pr):
            for slot, it in enumerate(plan.items_of(p_v, p_r)):
                own, bj, bk = it.blocks(p_v, cfg.n_pv)
                lo, _ = plan.sixth_bounds(n_vp, it.slice_idx, stage)
                jg = lo + np.arange(L)
                if it.kind == ItemKind.DIAG:
                    pipe_b = left_b = right_b = own
                    mask = (li[None, :, None] < jg[:, None, None]) & (
                        li[None, None, :] > jg[:, None, None]
                    )
                elif it.kind == ItemKind.FACE:
                    pipe_b, left_b, right_b = bj, own, bj
                    mask = np.broadcast_to(
                        li[None, None, :] > jg[:, None, None],
                        (L, n_vp, n_vp),
                    )
                else:
                    if it.slice_axis == 0:
                        pipe_b, left_b, right_b = own, bj, bk
                    elif it.slice_axis == 1:
                        pipe_b, left_b, right_b = bj, own, bk
                    else:
                        pipe_b, left_b, right_b = bk, own, bj
                    mask = np.ones((L, n_vp, n_vp), bool)
                c3 = np.asarray(executor.merge_three(
                    B_acc[p_v, p_r, slot],
                    pl_acc[p_v, p_r, slot] if needs else None,
                    pr_acc[p_v, p_r, slot] if needs else None,
                    lr_acc[p_v, p_r, slot] if needs else None,
                    stats[pipe_b][jg], stats[left_b], stats[right_b],
                ))
                blocks[p_v, p_r, slot] = np.where(mask, c3, 0)
    return blocks


def stream_threeway(
    dataset, mesh, cfg: CometConfig, stage: int = 0,
    metric: MetricSpec = None,
) -> tuple:
    """Streamed 3-way campaign stage over a ``repro.store`` dataset.

    Returns ``(ThreeWayOutput, info)`` bit-identical to
    ``threeway_distributed`` on the materialized payload.
    """
    metric = metric or CZEKANOWSKI
    sh = _as_sharded(dataset)
    cfg = resolve_config(cfg, sh, metric)
    n_v = sh.n_v
    unit = 6 * cfg.n_st
    n_vp = -(-n_v // cfg.n_pv)
    n_vp += (-n_vp) % unit
    L = n_vp // unit
    plan = ThreeWayPlan(cfg.n_pv, cfg.n_pr, cfg.n_st)
    slots = plan.slots_per_rank
    splan = StreamPlan.for_reader(
        sh.reader, n_v=cfg.n_pv * n_vp, n_pf=cfg.n_pf,
        max_host_bytes=cfg.max_host_bytes,
    )

    out_dtype = jnp.dtype(cfg.out_dtype)
    jfn = jax.jit(shard_map(
        partial(_threeway_program, cfg=cfg, plan=plan, stage=stage,
                out_dtype=out_dtype, metric=metric, deferred=True),
        mesh=mesh,
        in_specs=P(None, "pf", "pv"),
        out_specs=(
            P("pv", "pr", None, None, None, None),  # 3-way numerators
            P("pv", "pr", None, None, None),  # pipe x left
            P("pv", "pr", None, None, None),  # pipe x right
            P("pv", "pr", None, None, None),  # left x right
            P("pv", None),  # stat partial
        ),
        check=False,
    ))

    shape = (cfg.n_pv, cfg.n_pr, slots)
    accs = [
        np.zeros(shape + (L, n_vp, n_vp), np.float32),
        np.zeros(shape + (L, n_vp), np.float32),
        np.zeros(shape + (L, n_vp), np.float32),
        np.zeros(shape + (n_vp, n_vp), np.float32),
    ]
    stats = np.zeros((cfg.n_pv, n_vp), np.float32)
    staged, overlap = _run_chunks(
        sh, splan, jfn, accs, stats, n_devices=int(mesh.devices.size)
    )

    # -- cross-shard merge epilogue (mask logic mirrors entries()) ---------
    executor = TileExecutor(cfg=cfg, metric=metric, out_dtype=out_dtype,
                            axis=None, deferred=True)
    with obs.span("merge") as sp:
        blocks = _merge_threeway_blocks(
            cfg, plan, stage, executor, metric.needs_pair_terms, accs, stats,
            L, n_vp,
        )
        sp.add(blocks=int(blocks.size))
    out = ThreeWayOutput(blocks=blocks, plan=plan, n_v=n_v, n_vp=n_vp,
                         stage=stage)
    info = _stream_info(splan, cfg, sh.n_shards)
    info["staged_bytes"] = staged
    info.update(overlap)
    return out, info


def stream_twoway_delta(
    dataset, n_old: int, mesh, cfg: CometConfig, metric: MetricSpec = None,
) -> tuple:
    """Streamed border-block delta over a ``repro.store`` dataset whose
    first ``n_old`` columns a prior result already covers (``core.delta``).

    The chunk loop stages each byte chunk into a PAIR of staging buffers —
    the sharded old columns and the replicated new columns — following the
    overlap-staging idiom of the streamed full campaign: the prefetch
    thread splits chunk ``s+1``'s columns while the device contracts chunk
    ``s``.  Each chunk runs ``_twoway_delta_deferred_program`` (raw fp32
    rectangle/triangle partials + stat partials, no ring), the host
    accumulates, and the merge epilogue assembles once — bit-identical to
    the in-memory border and therefore to a full recompute.

    Returns ``(rect, tri, cfg, dinfo, sinfo)`` — the assembled border
    blocks (merge with ``core.delta.merge_delta``), the resolved config,
    the ``meta["delta"]`` accounting and the usual streaming accounting.
    """
    from repro.core.delta import _twoway_delta_deferred_program, delta_accounting

    metric = metric or CZEKANOWSKI
    sh = _as_sharded(dataset)
    cfg = resolve_config(cfg, sh, metric)  # plane path or raises
    n_v = sh.n_v
    if not 1 <= n_old < n_v:
        raise ValueError(f"n_old={n_old} must be in [1, n_v={n_v})")
    m = n_v - n_old
    R = cfg.n_pv * cfg.n_pr
    n_op = -(-n_old // R)
    n_op_total = n_op * R
    splan = StreamPlan.for_reader(
        sh.reader, n_v=n_op_total + m, n_pf=cfg.n_pf,
        max_host_bytes=cfg.max_host_bytes,
    )

    jfn = jax.jit(shard_map(
        partial(_twoway_delta_deferred_program, cfg=cfg, metric=metric),
        mesh=mesh,
        in_specs=(P(None, "pf", ("pv", "pr")), P(None, "pf", None)),
        out_specs=(
            P(("pv", "pr"), None),  # rectangle partial
            P(("pv", "pr"), None, None),  # triangle partial (rank 0 only)
            P(("pv", "pr")),  # old stat partial
            P(("pv", "pr"), None),  # new stat partial (replicated)
        ),
        check=False,
    ))

    rect_acc = np.zeros((n_op_total, m), np.float32)
    tri_acc = np.zeros((m, m), np.float32)
    so_acc = np.zeros((n_op_total,), np.float32)
    sn_acc = np.zeros((m,), np.float32)

    chunks = splan.chunks()
    buffers = [
        (np.zeros((splan.levels, splan.chunk_kb, n_op_total), np.uint8),
         np.zeros((splan.levels, splan.chunk_kb, m), np.uint8))
        for _ in range(splan.n_buffers)
    ]
    shard_cache = {}

    def shard_of(rank):
        if rank not in shard_cache:
            shard_cache[rank] = sh.reader.shard(rank)
        return shard_cache[rank]

    def fill(idx, bufs):
        ob, nb = bufs
        chunk = chunks[idx]
        for rank, lo, hi, off in chunk.spans:
            sv = shard_of(rank)
            ob[:, off:off + (hi - lo), :n_old] = sv[:, lo:hi, :n_old]
            nb[:, off:off + (hi - lo), :] = sv[:, lo:hi, n_old:]
        used = chunk.nbytes_valid
        if used < ob.shape[1]:
            ob[:, used:, :] = 0
            nb[:, used:, :] = 0

    compute_s = 0.0
    with ShardPrefetcher(fill, len(chunks), buffers) as pf:
        for _idx, bufs in pf:
            t0 = time.perf_counter()
            with obs.span("delta-border") as sp:
                outs = jfn(jnp.asarray(bufs[0]), jnp.asarray(bufs[1]))
                np.add(rect_acc, np.asarray(outs[0]).reshape(rect_acc.shape),
                       out=rect_acc)
                np.add(tri_acc, np.asarray(outs[1])[0], out=tri_acc)
                np.add(so_acc, np.asarray(outs[2]).reshape(so_acc.shape),
                       out=so_acc)
                np.add(sn_acc, np.asarray(outs[3])[0], out=sn_acc)
                sp.add(chunk=_idx,
                       chunk_bytes=sum(int(b.nbytes) for b in bufs))
            compute_s += time.perf_counter() - t0
            pf.release(bufs)
        overlap = {
            "stage_seconds": pf.stage_seconds,
            "stall_seconds": pf.stall_seconds,
            "compute_seconds": compute_s,
        }
    staged = sum(b.nbytes for bufs in buffers for b in bufs)
    if obs.enabled():
        obs.roofline_event(
            jfn, (jnp.asarray(buffers[0][0]), jnp.asarray(buffers[0][1])),
            int(mesh.devices.size), repeats=len(chunks),
        )

    executor = TileExecutor(
        cfg=cfg, metric=metric, out_dtype=jnp.dtype(cfg.out_dtype),
        axis=None, deferred=True,
    )
    with obs.span("merge") as sp:
        rect = np.asarray(executor.merge_pair(rect_acc, so_acc, sn_acc))
        tri = np.asarray(
            executor.merge_pair(tri_acc, sn_acc, sn_acc, diagonal=True)
        )
        sp.add(entries=int(rect.size + tri.size))
    sinfo = _stream_info(splan, cfg, sh.n_shards)
    sinfo["staged_bytes"] = staged
    sinfo.update(overlap)
    dinfo = delta_accounting(
        cfg, n_old=n_old, n_new=m, n_op=n_op,
        payload_bytes=splan.chunk_nbytes * splan.n_chunks, streamed=True,
    )
    return rect, tri, cfg, dinfo, sinfo


def stream_twoway_batched(dataset, mesh, cfg: CometConfig, specs) -> tuple:
    """Streamed batched 2-way campaigns: one chunked ring traversal, one
    ``TwoWayOutput`` per metric (request order), each bit-identical to its
    sequential streamed/in-memory run.

    The chunk program accumulates ONE raw numerator partial per metric
    FAMILY (plus per-family stat partials); after the last chunk the merge
    epilogue fans each family's accumulator out through every member's
    assembly.  Returns ``(outputs, binfo, info)`` — the batched ring
    accounting plus the usual streaming accounting.
    """
    specs = list(specs)
    sh = _as_sharded(dataset)
    cfg = resolve_config(cfg, sh, batch_lead(specs))
    groups = group_families(specs)
    flat = [s for grp in groups for s in grp]
    gidx = {s.name: g for g, grp in enumerate(groups) for s in grp}
    n_v = sh.n_v
    n_vp = -(-n_v // cfg.n_pv)
    plan = TwoWayPlan(cfg.n_pv, cfg.n_pr)
    splan = StreamPlan.for_reader(
        sh.reader, n_v=cfg.n_pv * n_vp, n_pf=cfg.n_pf,
        max_host_bytes=cfg.max_host_bytes,
    )

    jfn = jax.jit(shard_map(
        partial(_twoway_deferred_batched_program, cfg=cfg, plan=plan,
                groups=groups),
        mesh=mesh,
        in_specs=P(None, "pf", "pv"),
        out_specs=(P("pv", "pr", None, None, None, None),
                   P("pv", None, None)),
        check=False,
    ))

    G = len(groups)
    acc = np.zeros(
        (cfg.n_pv, cfg.n_pr, G, plan.slots_per_rank, n_vp, n_vp), np.float32
    )
    stats = np.zeros((cfg.n_pv, G, n_vp), np.float32)
    staged, overlap = _run_chunks(
        sh, splan, jfn, [acc], stats, n_devices=int(mesh.devices.size)
    )

    by_name = {}
    with obs.span("merge") as sp:
        for s in flat:
            g = gidx[s.name]
            executor = TileExecutor(
                cfg=cfg, metric=s, out_dtype=jnp.dtype(cfg.out_dtype),
                axis=None, deferred=True,
            )
            blocks = _merge_twoway_blocks(
                cfg, plan, executor, acc[:, :, g], stats[:, g]
            )
            by_name[s.name] = TwoWayOutput(
                blocks=blocks, plan=plan, n_v=n_v, n_vp=n_vp
            )
        sp.add(metrics=len(flat))
    info = _stream_info(splan, cfg, sh.n_shards)
    info["staged_bytes"] = staged
    info.update(overlap)
    binfo = batch_accounting(
        splan.chunk_nbytes * splan.n_chunks, cfg, plan, groups, n_vp,
        planes=True, way=2,
    )
    return [by_name[s.name] for s in specs], binfo, info


def stream_threeway_batched(
    dataset, mesh, cfg: CometConfig, specs, stage: int = 0,
) -> tuple:
    """Streamed batched 3-way campaign stage; see ``stream_twoway_batched``.

    Returns ``(outputs, binfo, info)`` with one ``ThreeWayOutput`` per
    metric in request order.
    """
    specs = list(specs)
    sh = _as_sharded(dataset)
    cfg = resolve_config(cfg, sh, batch_lead(specs))
    groups = group_families(specs)
    flat = [s for grp in groups for s in grp]
    gidx = {s.name: g for g, grp in enumerate(groups) for s in grp}
    n_v = sh.n_v
    unit = 6 * cfg.n_st
    n_vp = -(-n_v // cfg.n_pv)
    n_vp += (-n_vp) % unit
    L = n_vp // unit
    plan = ThreeWayPlan(cfg.n_pv, cfg.n_pr, cfg.n_st)
    slots = plan.slots_per_rank
    splan = StreamPlan.for_reader(
        sh.reader, n_v=cfg.n_pv * n_vp, n_pf=cfg.n_pf,
        max_host_bytes=cfg.max_host_bytes,
    )

    out_dtype = jnp.dtype(cfg.out_dtype)
    jfn = jax.jit(shard_map(
        partial(_threeway_program, cfg=cfg, plan=plan, stage=stage,
                out_dtype=out_dtype, groups=groups, deferred=True),
        mesh=mesh,
        in_specs=P(None, "pf", "pv"),
        out_specs=(
            P("pv", "pr", None, None, None, None, None),  # 3-way numerators
            P("pv", "pr", None, None, None, None),  # pipe x left
            P("pv", "pr", None, None, None, None),  # pipe x right
            P("pv", "pr", None, None, None, None),  # left x right
            P("pv", None, None),  # per-family stat partials
        ),
        check=False,
    ))

    G = len(groups)
    shape = (cfg.n_pv, cfg.n_pr, slots, G)
    accs = [
        np.zeros(shape + (L, n_vp, n_vp), np.float32),
        np.zeros(shape + (L, n_vp), np.float32),
        np.zeros(shape + (L, n_vp), np.float32),
        np.zeros(shape + (n_vp, n_vp), np.float32),
    ]
    stats = np.zeros((cfg.n_pv, G, n_vp), np.float32)
    staged, overlap = _run_chunks(
        sh, splan, jfn, accs, stats, n_devices=int(mesh.devices.size)
    )

    by_name = {}
    with obs.span("merge") as sp:
        for s in flat:
            g = gidx[s.name]
            executor = TileExecutor(cfg=cfg, metric=s, out_dtype=out_dtype,
                                    axis=None, deferred=True)
            blocks = _merge_threeway_blocks(
                cfg, plan, stage, executor, s.needs_pair_terms,
                [a[:, :, :, g] for a in accs], stats[:, g], L, n_vp,
            )
            by_name[s.name] = ThreeWayOutput(
                blocks=blocks, plan=plan, n_v=n_v, n_vp=n_vp, stage=stage
            )
        sp.add(metrics=len(flat))
    info = _stream_info(splan, cfg, sh.n_shards)
    info["staged_bytes"] = staged
    info.update(overlap)
    binfo = batch_accounting(
        splan.chunk_nbytes * splan.n_chunks, cfg, plan, groups, n_vp,
        planes=True, way=3,
    )
    return [by_name[s.name] for s in specs], binfo, info
