"""TileExecutor — the single tiled hot path under both distributed engines.

Before this layer existed, ``_twoway_program`` / ``_threeway_program`` built
their own contraction pipelines: a plain mGEMM via ``cfg.impl_fn()``, the
metric assembly in XLA *outside* the kernel (one HBM round-trip of every
numerator block), and diagonal blocks computed in full before masking one
triangle with ``jnp.where``.  The executor owns all of that now:

* **Kernel dispatch** across the implementation registry (``xla`` /
  ``pallas`` / ``levels*``) plus the *generated fused paths*: any metric
  with a Pallas-composable ``assemble_tile`` epilogue and a combine-sum
  contraction gets a fused kernel — the VPU kernel of
  ``repro.kernels.mgemm`` under ``impl="pallas"`` (``path ==
  "fused-vpu"``), or the packed bit-plane MXU kernel of
  ``repro.kernels.mgemm_levels`` under ``impl="levels"`` with a
  min-combine metric (``path == "fused-levels"``; for binary campaigns —
  ``levels == 1`` — the popcount bit-GEMM of ``repro.kernels.popgemm``
  serves the same role as ``path == "fused-popcount"``, packed AND +
  popcount with no plane unpack).  Either way the
  numerator tile is divided in VMEM and never written to HBM (paper §3.1's
  epilogue fusion, for every registered metric instead of a hard-coded
  Czekanowski one-off).  ``path`` / ``path_reason`` surface the 2-way
  decision; ``path3`` / ``path3_reason`` the 3-way one, where
  ``"fused-levels-ring"`` additionally means the doubly-nested ring
  carries packed bit-planes end to end (docs/BITPLANE_FORMAT.md) instead
  of values.  See docs/ARCHITECTURE.md for the full fallback matrix.
* **In-kernel symmetry elimination** (paper §5): diagonal blocks run the
  triangular tile schedule — the Pallas grid enumerates only tiles with
  ``tj >= ti`` — replacing compute-both-then-mask.
* **Block padding / tile selection**: operands are padded to tile multiples
  inside the kernels; tile sizes adapt to the block shape (capped at the
  TPU-sized defaults, 8-aligned for the VPU register shape) so interpret
  mode on CPU does not pay for 128x512 padding of a 12-vector test block.

Bit-exactness contract: the fused path performs op-for-op the same fp32
arithmetic as the out-of-kernel assembly (exact integer numerators, then
``assemble_tile`` == ``assemble2`` division), so every campaign checksum is
bit-identical across ``impl="xla"`` and ``impl="pallas"`` on integer data —
verified in tests/distributed_harness.py and tests/test_fused_epilogue.py.

The fused epilogue needs the *complete* numerator at flush time.  When the
contraction is split over ranks (``n_pf > 1``) the levels path now keeps
the fused MXU contraction and runs the kernels with ``epilogue=None`` (raw
fp32 numerator, triangular diagonal schedule preserved), then psums over
"pf" and applies the metric assembly out of kernel — the **merge
epilogue** (``path == "fused-levels"`` with reason ``"n_pf>1 merge
epilogue engaged"``).  The VPU path has no raw-numerator kernel form and
still falls back to unfused.

**Deferred-flush accumulator mode** (``deferred=True``) is the streamed
variant of the same idea (``repro.stream``): blocks emit raw psummed fp32
numerator partials only (``pair_partial``), the host accumulates them
across byte-axis chunks, and ``merge_pair`` / ``merge_three`` apply the
metric assembly once after the last chunk.  Partial numerators and stats
are exact fp32 integers, so chunk-order addition is bit-identical to the
single-pass contraction — the cross-shard merge guarantee
(docs/BITPLANE_FORMAT.md, "Cross-shard merge").
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.metric_spec import CZEKANOWSKI, MetricSpec

__all__ = ["TileExecutor"]

_TILE_ALIGN = 8  # VPU sublane multiple; real TPU tiles stay (8k, 128)-shaped


def _auto_tile(extent: int, cap: int) -> int:
    """Smallest 8-aligned tile covering ``extent``, capped at the default."""
    return int(min(cap, -(-extent // _TILE_ALIGN) * _TILE_ALIGN))


@dataclass(frozen=True)
class TileExecutor:
    """Tile-level kernel dispatch for one (config, metric, out_dtype) triple.

    ``axis`` is the mesh axis numerator partials are psummed over on the
    unfused path ("pf" inside the distributed programs); ``None`` outside
    shard_map (single-process tests, benchmarks).
    """

    cfg: Any  # CometConfig (duck-typed to avoid a core.twoway import cycle)
    metric: MetricSpec = None
    out_dtype: Any = jnp.float32
    axis: Optional[str] = "pf"
    #: deferred-flush accumulator mode (streamed campaigns): blocks emit
    #: raw psummed fp32 numerator partials; the metric assembly waits for
    #: the cross-shard merge epilogue (``merge_pair`` / ``merge_three``)
    deferred: bool = False

    def __post_init__(self):
        if self.metric is None:
            object.__setattr__(self, "metric", CZEKANOWSKI)

    # -- dispatch predicates ------------------------------------------------

    def _path_decision(self) -> tuple:
        """(path, reason): which 2-way kernel family serves this executor.

        ``path`` is ``"fused-vpu"`` (combine-sum VPU kernel + in-kernel
        epilogue), ``"fused-levels"`` (bit-plane MXU kernel; epilogue
        in-kernel, or — ``n_pf > 1`` — applied after the psum by the merge
        epilogue), ``"unfused"``, or a ``"streamed-*"`` deferred-flush
        variant.  ``reason`` says why the plain in-kernel epilogue is not
        running (empty on the fully fused paths), so fallbacks and merge
        modes are inspectable (``launch.similarity --dry-run``)."""
        if self.deferred:
            return self._deferred_path()
        if self.metric.assemble_tile is None:
            return "unfused", (
                "metric has no Pallas-composable assemble_tile epilogue"
            )
        if not self.metric.contract_is_combine_sum:
            return "unfused", "metric contraction is not a combine-sum"
        if self.cfg.n_pf > 1:
            if (
                self.cfg.impl == "levels"
                and self.metric.combine is jnp.minimum
            ):
                # raw-numerator kernel form + psum + out-of-kernel assembly:
                # the fused contraction and the triangular diagonal
                # schedule survive the field split
                return self._levels_pair_path(), "n_pf>1 merge epilogue engaged"
            return "unfused", (
                f"n_pf={self.cfg.n_pf} splits the contraction across ranks; "
                "the in-kernel epilogue needs the complete numerator"
            )
        if self.cfg.impl == "pallas":
            return "fused-vpu", ""
        if self.cfg.impl == "levels":
            if self.metric.combine is not jnp.minimum:
                return "unfused", (
                    "level decomposition is exact only for combine == min"
                )
            return self._levels_pair_path(), ""
        return "unfused", f"impl={self.cfg.impl!r} has no fused kernel"

    def _levels_pair_path(self) -> str:
        """Which plane kernel family serves ``impl="levels"`` 2-way blocks:
        for binary data (``levels == 1``) the single plane is the data and
        min == AND, so the popcount bit-GEMM replaces the bf16 plane dots —
        same wire format, same epilogue, no unpack."""
        return "fused-popcount" if self.cfg.levels == 1 else "fused-levels"

    def _deferred_path(self) -> tuple:
        """Path naming for deferred-flush (streamed) executors: chunks emit
        raw fp32 numerator partials either way; the name says which
        contraction kernel produces them."""
        if (
            self.cfg.impl == "levels"
            and self.metric.contract_is_combine_sum
            and self.metric.combine is jnp.minimum
        ):
            return "streamed-" + self._levels_pair_path(), (
                "deferred flush: cross-shard merge epilogue assembles "
                "after the last chunk"
            )
        return "streamed-unfused", (
            "deferred flush: raw partials accumulated across chunks, "
            f"impl={self.cfg.impl!r} contraction"
        )

    @property
    def path(self) -> str:
        """'fused-popcount' | 'fused-levels' | 'fused-vpu' | 'unfused' for
        2-way blocks (plus the 'streamed-*' deferred-flush variants)."""
        return self._path_decision()[0]

    @property
    def path_reason(self) -> str:
        """Why fusion was declined ('' when a fused path is active)."""
        return self._path_decision()[1]

    @property
    def fused(self) -> bool:
        """True when 2-way blocks run a fused Pallas contraction kernel
        (epilogue in-kernel, or deferred to the merge epilogue)."""
        return "unfused" not in self.path

    def _path3_decision(self) -> tuple:
        """(path, reason) for the 3-way pipeline slice.  Unlike 2-way, no
        ``n_pf`` condition: the slice kernel emits a non-psummed numerator
        and the assembly runs outside the kernel either way.

        ``"fused-levels-ring"`` is the end-to-end plane campaign: the 3-way
        doubly-nested ring carries packed uint8 planes (encoded once before
        ``shard_map``) and every slice kernel reads them directly.  Plain
        ``"fused-levels"`` means the same slice kernel but a value ring —
        planes re-encoded per pipeline slice (``encoding="none"`` opt-out,
        or an executor built from an unresolved config).  Deferred
        executors prefix the same names with ``"streamed-"`` (raw partials
        accumulated across chunks, assembly in the merge epilogue)."""
        if self.deferred:
            base, _ = self._path3_base()
            return "streamed-" + base, (
                "deferred flush: cross-shard merge epilogue assembles "
                "after the last chunk"
            )
        return self._path3_base()

    def _path3_base(self) -> tuple:
        if not self.metric.contract_is_combine_sum:
            return "unfused", "metric contraction is not a combine-sum"
        if self.cfg.impl == "pallas":
            return "fused-vpu", ""
        if self.cfg.impl == "levels":
            if self.metric.combine is not jnp.minimum:
                return "unfused", (
                    "level decomposition is exact only for combine == min"
                )
            base = self._levels_pair_path()  # popcount when levels == 1
            if self.cfg.encoding == "bitplane":
                return base + "-ring", ""
            return base, (
                f"encoding={self.cfg.encoding!r}: ring carries "
                f"{self.cfg.ring_dtype} values, planes encoded per slice"
            )
        return "unfused", f"impl={self.cfg.impl!r} has no fused kernel"

    @property
    def path3(self) -> str:
        """'fused-popcount-ring' | 'fused-popcount' | 'fused-levels-ring' |
        'fused-levels' | 'fused-vpu' | 'unfused' for 3-way slices."""
        return self._path3_decision()[0]

    @property
    def path3_reason(self) -> str:
        return self._path3_decision()[1]

    @property
    def fused3(self) -> bool:
        """True when 3-way pipeline steps run a fused X_j Pallas kernel."""
        return "unfused" not in self.path3


    # -- internals ----------------------------------------------------------

    def _psum(self, x):
        return jax.lax.psum(x, self.axis) if self.axis is not None else x

    def contract(self, A, B):
        """Numerator contraction via the metric's registry dispatch."""
        return self.metric.contract_fn(self.cfg)(A, B)

    # -- 2-way --------------------------------------------------------------

    def _pair_planes(self, Va, Vb):
        """Packed bit-planes of the two operand blocks.

        Accepts either pre-encoded planes (3-D uint8 — the campaign path,
        where encoding happened once before the ring) or raw field-major
        value blocks (standalone/benchmark calls), encoded on the fly."""
        from repro.kernels.mgemm_levels import encode_bitplanes

        if Va.ndim == 3:
            return Va, Vb
        Pa = encode_bitplanes(Va, self.cfg.levels)
        Pb = Pa if Vb is Va else encode_bitplanes(Vb, self.cfg.levels)
        return Pa, Pb

    def pair_block(self, Va, sa, Vb, sb, *, diagonal: bool = False):
        """One (m, n) block of 2-way metric values.

        Va / Vb are field-major vector blocks — (n_fp, m) / (n_fp, n) values,
        or (levels, kb, m) / (levels, kb, n) packed bit-planes when the
        campaign pre-encoded them (``cfg.encoding == "bitplane"``, resolved
        by ``core.twoway.resolve_config``).  sa / sb the psummed
        per-vector stats.  ``diagonal`` marks Va and Vb as the same block:
        only the strict upper triangle is returned (zeros elsewhere),
        computed on the triangular tile schedule on the fused paths.
        """
        m = Va.shape[-1]
        n = Vb.shape[-1]
        path = self.path
        if path == "fused-vpu":
            # late import: kernels register against core.mgemm at import time
            from repro.kernels.mgemm import (
                metric2_tiles,
                metric2_tri,
                unpack_tri_tiles,
            )
            from repro.kernels.mgemm.kernel import (
                DEFAULT_BK,
                DEFAULT_BM,
                DEFAULT_BN,
            )

            k = Va.shape[0]
            kw = dict(
                combine=self.metric.combine,
                epilogue=self.metric.assemble_tile,
                bk=_auto_tile(k, DEFAULT_BK),
                out_dtype=jnp.dtype(self.out_dtype),
            )
            if diagonal:
                bt = _auto_tile(m, DEFAULT_BM)
                packed = metric2_tri(Va.T, Vb, sa, sb, bt=bt, **kw)
                return unpack_tri_tiles(packed, m, bt)
            return metric2_tiles(
                Va.T, Vb, sa, sb,
                bm=_auto_tile(m, DEFAULT_BM), bn=_auto_tile(n, DEFAULT_BN),
                **kw,
            )
        if path in ("fused-levels", "fused-popcount"):
            from repro.kernels.mgemm import unpack_tri_tiles

            if path == "fused-popcount":
                # binary fast path: packed AND + popcount, no plane unpack
                from repro.kernels.popgemm import (
                    metric2_pop as metric2_fn,
                    metric2_pop_tri as metric2_tri_fn,
                )
                from repro.kernels.popgemm.kernel import (
                    DEFAULT_BKB,
                    DEFAULT_BM as LEVELS_BM,
                    DEFAULT_BN as LEVELS_BN,
                )
            else:
                from repro.kernels.mgemm_levels import (
                    metric2_levels as metric2_fn,
                    metric2_levels_tri as metric2_tri_fn,
                )
                from repro.kernels.mgemm_levels.kernel import (
                    DEFAULT_BKB,
                    DEFAULT_BM as LEVELS_BM,
                    DEFAULT_BN as LEVELS_BN,
                )

            # n_pf > 1: the kernels run with ``epilogue=None`` (raw fp32
            # numerator, triangular diagonal schedule preserved) and the
            # merge epilogue — psum over "pf", then the SAME assemble2 ops
            # as the unfused path — flushes out of kernel.
            merge = self.cfg.n_pf > 1
            Pa, Pb = self._pair_planes(Va, Vb if not diagonal else Va)
            kw = dict(
                epilogue=None if merge else self.metric.assemble_tile,
                bkb=max(1, min(DEFAULT_BKB, Pa.shape[1])),
                out_dtype=jnp.float32 if merge
                else jnp.dtype(self.out_dtype),
            )
            if diagonal:
                bt = _auto_tile(m, LEVELS_BM)
                packed = metric2_tri_fn(Pa, sa, bt=bt, **kw)
                vals = unpack_tri_tiles(packed, m, bt)
            else:
                vals = metric2_fn(
                    Pa, Pb, sa, sb,
                    bm=_auto_tile(m, LEVELS_BM), bn=_auto_tile(n, LEVELS_BN),
                    **kw,
                )
            if merge:
                vals = self.merge_pair(
                    self._psum(vals), sa, sb, diagonal=diagonal
                )
            return vals
        # unfused: contraction (registry impl, or the hoisted plane
        # contraction when the campaign pre-encoded bit-planes) + psum +
        # out-of-kernel assembly — op-for-op the pre-executor arithmetic.
        if Va.ndim == 3:
            n2 = self._contract_planes(Va, Vb)
        else:
            n2 = self.contract(Va.T, Vb)
        n2 = self._psum(n2.astype(jnp.float32))
        vals = self.metric.assemble2(n2, sa[:, None], sb[None, :]).astype(
            self.out_dtype
        )
        if diagonal:
            tri = jnp.triu(jnp.ones((m, n), bool), k=1)
            vals = jnp.where(tri, vals, 0)
        return vals

    def pair_raw(self, Va, sa, Vb, sb, *, diagonal: bool = False):
        """Raw psummed fp32 numerator of one 2-way block — the batched-
        campaign contraction primitive.

        One call produces the COMPLETE numerator a whole metric *family*
        shares; the batched programs then fan it out through each member's
        ``merge_pair`` epilogue (same ``assemble2`` fp ops as the in-kernel
        ``assemble_tile``, so batched values stay bit-identical to the
        sequential run).  On the levels paths this is exactly the
        ``n_pf > 1`` merge-epilogue contraction: the fused kernels run with
        ``epilogue=None`` and the triangular diagonal schedule preserved.
        Product-family metrics riding a plane ring reconstruct exact values
        via ``values_from_planes`` first (integer sums stay below the fp32
        mantissa limit, so this is lossless).
        """
        if diagonal:
            Vb = Va
        if Va.ndim == 3 and not (
            self.metric.contract_is_combine_sum
            and self.metric.combine is jnp.minimum
        ):
            # plane payload, non-min metric (e.g. CCC): V = Σ plane_t exactly
            from repro.kernels.mgemm_levels import values_from_planes

            Wa = values_from_planes(Va)
            Wb = Wa if Vb is Va else values_from_planes(Vb)
            return self._psum(
                self.contract(Wa.T, Wb).astype(jnp.float32)
            )
        path = self.path
        if path in ("fused-levels", "fused-popcount"):
            from repro.kernels.mgemm import unpack_tri_tiles

            if path == "fused-popcount":
                from repro.kernels.popgemm import (
                    metric2_pop as metric2_fn,
                    metric2_pop_tri as metric2_tri_fn,
                )
                from repro.kernels.popgemm.kernel import (
                    DEFAULT_BKB,
                    DEFAULT_BM as LEVELS_BM,
                    DEFAULT_BN as LEVELS_BN,
                )
            else:
                from repro.kernels.mgemm_levels import (
                    metric2_levels as metric2_fn,
                    metric2_levels_tri as metric2_tri_fn,
                )
                from repro.kernels.mgemm_levels.kernel import (
                    DEFAULT_BKB,
                    DEFAULT_BM as LEVELS_BM,
                    DEFAULT_BN as LEVELS_BN,
                )

            m = Va.shape[-1]
            n = Vb.shape[-1]
            Pa, Pb = self._pair_planes(Va, Vb)
            kw = dict(
                epilogue=None,
                bkb=max(1, min(DEFAULT_BKB, Pa.shape[1])),
                out_dtype=jnp.float32,
            )
            if diagonal:
                bt = _auto_tile(m, LEVELS_BM)
                raw = unpack_tri_tiles(metric2_tri_fn(Pa, sa, bt=bt, **kw), m, bt)
            else:
                raw = metric2_fn(
                    Pa, Pb, sa, sb,
                    bm=_auto_tile(m, LEVELS_BM), bn=_auto_tile(n, LEVELS_BN),
                    **kw,
                )
            return self._psum(raw)
        return self._psum(self.pair_numerator(Va, Vb).astype(jnp.float32))

    def pair_partial(self, Va, Vb):
        """Deferred-flush block contraction: the raw fp32 numerator partial
        psummed over the contraction axis — what streamed chunk programs
        emit instead of assembled metric values.  Partials are exact fp32
        integers for leveled data, so host-side accumulation across chunks
        commutes bit-for-bit with the single-pass contraction."""
        return self._psum(self.pair_numerator(Va, Vb).astype(jnp.float32))

    # -- merge epilogue (deferred flush / n_pf > 1) --------------------------

    def merge_pair(self, n2, sa, sb, *, diagonal: bool = False):
        """Assemble one 2-way block from a COMPLETE numerator: the same
        ``assemble2`` arithmetic the unfused path runs after its psum, plus
        the diagonal strict-upper mask.  Called in-program on the n_pf > 1
        merge path and on the host by ``repro.stream`` after the last
        chunk's partials have been accumulated."""
        n2 = jnp.asarray(n2, jnp.float32)
        vals = self.metric.assemble2(
            n2, jnp.asarray(sa)[:, None], jnp.asarray(sb)[None, :]
        ).astype(self.out_dtype)
        if diagonal:
            m, n = vals.shape
            tri = jnp.triu(jnp.ones((m, n), bool), k=1)
            vals = jnp.where(tri, vals, 0)
        return vals

    def merge_three(self, B, n2_pl, n2_pr, n2_lr, sp, sl, sr):
        """Assemble one 3-way slice from complete numerators (the streamed
        twin of the in-program ``metric.assemble3`` call); masking is the
        caller's job — it depends on the plan item's kind."""
        B = jnp.asarray(B, jnp.float32)
        if n2_pl is not None:
            n2_pl = jnp.asarray(n2_pl, jnp.float32)
            n2_pr = jnp.asarray(n2_pr, jnp.float32)
            n2_lr = jnp.asarray(n2_lr, jnp.float32)
        return self.metric.assemble3(
            B, n2_pl, n2_pr, n2_lr,
            jnp.asarray(sp), jnp.asarray(sl), jnp.asarray(sr),
        ).astype(self.out_dtype)

    def pair_numerator(self, Va, Vb):
        """Raw (m, n) pairwise numerator block, NOT psummed.

        Accepts (k, m)/(k, n) field-major values or (levels, kb, m)/
        (levels, kb, n) packed bit-planes (docs/BITPLANE_FORMAT.md) — the
        3-way engine calls this for the pairwise terms of the metric
        assembly, so the plane ring serves them without decoding."""
        if Va.ndim == 3:
            return self._contract_planes(Va, Vb)
        return self.contract(Va.T, Vb)

    def _contract_planes(self, Pa, Pb):
        """Unfused numerator from pre-encoded planes: the per-ring-step
        ``(V >= t)`` indicator construction is gone from the hot loop.
        Binary planes (``levels == 1``) contract via the popcount bit-GEMM
        — this one routing point serves ``pair_partial`` (streamed chunks),
        ``pair_numerator`` (3-way pair terms), and the unfused-plane 3-way
        slice alike."""
        if self.cfg.impl == "levels":
            if self.cfg.levels == 1:
                from repro.kernels.popgemm import pop_planes
                from repro.kernels.popgemm.kernel import (
                    DEFAULT_BKB as POP_BKB,
                )

                return pop_planes(
                    Pa, Pb, bkb=max(1, min(POP_BKB, Pa.shape[1]))
                )
            from repro.kernels.mgemm_levels import mgemm_levels_planes
            from repro.kernels.mgemm_levels.kernel import DEFAULT_BKB

            return mgemm_levels_planes(
                Pa, Pb, bkb=max(1, min(DEFAULT_BKB, Pa.shape[1]))
            )
        from repro.kernels.mgemm_levels import mgemm_levels_planes_xla

        return mgemm_levels_planes_xla(Pa, Pb)

    # -- 3-way --------------------------------------------------------------

    def threeway_slice(self, ps, left, right):
        """Batched 3-way numerator B[t, l, r] = Σ_q combine(ps_t, left_l,
        right_r) for one pipeline slice.  NOT psummed — the caller fuses the
        psum with the pairwise terms into one collective.

        Operands are (n_fp, ·) field-major value blocks, or — on the plane
        ring (``path3 == "fused-levels-ring"``, and the unfused plane
        contraction under ``impl="levels_xla"``) — (levels, kb, ·) packed
        uint8 bit-planes exactly as ring-carried (docs/BITPLANE_FORMAT.md);
        the per-slice re-encode only runs when values arrive with
        ``impl="levels"`` (``encoding="none"`` opt-out).

        Fused path: one batched ``threeway_batch`` launch (the pipeline axis
        is a kernel grid dimension, so trace/compile cost is O(1) in L), the
        X_j = combine(left, ps_t) tiles built in VMEM (never HBM).  Unfused:
        the pipeline axis folds into the GEMM M dimension (one batched
        contraction), exactly the pre-executor formulation.
        """
        planes = ps.ndim == 3
        L = ps.shape[-1]
        m = left.shape[-1]
        n = right.shape[-1]
        if self.fused3:
            from repro.kernels.czek3 import threeway_batch
            from repro.kernels.czek3.kernel import (
                DEFAULT_BK,
                DEFAULT_BKB,
                DEFAULT_BM,
                DEFAULT_BN,
            )

            if self.cfg.impl == "levels":
                # level-decomposed slice: X_j is a packed AND of plane
                # bytes, the contraction L MXU dot_generals per K-tile —
                # or, for binary planes, a popcount of the packed AND (the
                # whole slice never unpacks a byte).  On the plane ring the
                # operands arrive pre-encoded.
                if self.cfg.levels == 1:
                    from repro.kernels.popgemm import threeway_batch_pop as batch_fn
                    from repro.kernels.popgemm.kernel import (
                        DEFAULT_BKB,
                        DEFAULT_BM3 as BM3,
                        DEFAULT_BN3 as BN3,
                    )
                else:
                    from repro.kernels.czek3 import (
                        threeway_batch_levels as batch_fn,
                    )

                    BM3, BN3 = DEFAULT_BM, DEFAULT_BN
                if planes:
                    Pl, Pp, Pr = left, ps, right
                else:
                    from repro.kernels.mgemm_levels import encode_bitplanes

                    lv = self.cfg.levels
                    Pl = encode_bitplanes(left, lv)
                    Pp = encode_bitplanes(ps, lv)
                    Pr = Pl if right is left else encode_bitplanes(right, lv)
                return batch_fn(
                    Pl, Pp, Pr,
                    bm=_auto_tile(m, BM3),
                    bn=_auto_tile(n, BN3),
                    bkb=max(1, min(DEFAULT_BKB, Pl.shape[1])),
                )
            return threeway_batch(
                left, ps, right,
                combine=self.metric.combine,
                bm=_auto_tile(m, DEFAULT_BM),
                bn=_auto_tile(n, DEFAULT_BN),
                bk=_auto_tile(ps.shape[0], DEFAULT_BK),
            )
        if planes:
            # plane of min(left_l, ps_t) == packed AND of the plane bytes;
            # fold the pipeline axis into the GEMM M dimension and run the
            # (unfused) plane contraction — no decode, no re-encode
            levels, kb = ps.shape[:2]
            Xp = (left[:, :, :, None] & ps[:, :, None, :]).reshape(
                levels, kb, m * L
            )
            return self._contract_planes(Xp, right).reshape(
                m, L, n
            ).transpose(1, 0, 2)
        n_fp = ps.shape[0]
        X = self.metric.combine(left[:, :, None], ps[:, None, :]).reshape(
            n_fp, m * L
        )
        return self.contract(X.T, right).reshape(m, L, n).transpose(1, 0, 2)
