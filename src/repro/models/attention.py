"""GQA attention: train/prefill (chunked online-softmax) + decode (KV cache).

Sharding strategy (see DESIGN.md §4): projections constrain the *flat*
feature dims (B, S, H*hd) — always divisible by the model axis for the
assigned archs even when head counts (12, 24) or KV head counts (2, 8) are
not.  For the attention math itself, KV heads are repeated to the full query
head count so every intermediate carries one flat head dim that divides the
model axis (q-head parallelism; the repeat is fused by XLA).  The KV cache
shards its sequence axis over "model", so decode attention reduces over a
sharded T with two small collectives per layer instead of all-gathering the
cache.

Long sequences use a doubly-chunked (query x key) online-softmax scan — the
flash-attention recurrence in pure JAX — bounding live buffers to
(B, Hq, Cq, Ck) tiles so 32k prefill fits HBM.  Causally-dead chunk pairs
are masked, not skipped (static shapes); the roofline accounts for the 2x
and §Perf discusses the Pallas grid-pruned alternative.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init
from repro.models.rope import apply_rope
from repro.parallel.sharding import DATA_AXES, shard

CHUNK_Q = 1024
CHUNK_K = 1024
_NEG = -1e30


def init_attention(cfg: ModelConfig, key, *, cross: bool = False):
    hd = cfg.hd
    kq, kk, kv, ko, kb = jax.random.split(key, 5)
    p = {
        "wq": dense_init(kq, (cfg.d_model, cfg.n_heads * hd), cfg.pdt),
        "wk": dense_init(kk, (cfg.d_model, cfg.n_kv_heads * hd), cfg.pdt),
        "wv": dense_init(kv, (cfg.d_model, cfg.n_kv_heads * hd), cfg.pdt),
        "wo": dense_init(ko, (cfg.n_heads * hd, cfg.d_model), cfg.pdt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), cfg.pdt)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), cfg.pdt)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), cfg.pdt)
    return p


def _repeat_kv(x, n_rep: int):
    """(B, T, Hkv, hd) -> (B, T, Hkv*n_rep, hd) — flat q-head layout."""
    if n_rep == 1:
        return x
    b, t, h, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, t, h, n_rep, d)).reshape(
        b, t, h * n_rep, d
    )


def _dense_attend(q, k, v, *, causal: bool, q_offset, kv_len=None):
    """q (B,S,Hq,hd), k/v (B,T,Hq,hd) (kv already repeated)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    s = shard(s, DATA_AXES, "model", None, None)
    T = k.shape[1]
    t_idx = jnp.arange(T)
    if causal:
        qpos = q_offset + jnp.arange(q.shape[1])
        s = jnp.where(t_idx[None, None, None, :] <= qpos[None, None, :, None],
                      s, _NEG)
    if kv_len is not None:  # mask unwritten cache slots
        s = jnp.where(t_idx[None, None, None, :] < kv_len, s, _NEG)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bthd->bshd", w.astype(v.dtype), v)


def _flash_attend(q, k, v, *, causal: bool, q_offset=0, cq=CHUNK_Q, ck=CHUNK_K,
                  p_bf16: bool = False):
    """Doubly-chunked online-softmax. q (B,S,Hq,hd), k/v (B,T,Hq,hd)."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    cq = min(cq, S)
    ck = min(ck, T)
    assert S % cq == 0 and T % ck == 0, (S, cq, T, ck)
    nq, nk = S // cq, T // ck
    scale = hd**-0.5
    # keep the streamed K/V/Q stacks in their compute dtype (bf16); upcasts
    # happen per-tile inside the scan so no O(S)/O(T) fp32 buffer exists
    qc = jnp.moveaxis(q.reshape(B, nq, cq, H, hd), 1, 0)
    kc = jnp.moveaxis(k.reshape(B, nk, ck, H, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nk, ck, H, hd), 1, 0)

    def q_step(_, qi_q):
        qi, qblk = qi_q  # qblk (B, cq, H, hd)
        qblk = qblk.astype(jnp.float32) * scale

        def kv_step(carry, ki_kv):
            m, l, acc = carry
            ki, kblk, vblk = ki_kv
            s = jnp.einsum("bshd,bthd->bhst", qblk,
                           kblk.astype(jnp.float32))  # (B,H,cq,ck)
            s = shard(s, DATA_AXES, "model", None, None)
            if causal:
                qpos = q_offset + qi * cq + jnp.arange(cq)
                tpos = ki * ck + jnp.arange(ck)
                s = jnp.where(
                    tpos[None, None, None, :] <= qpos[None, None, :, None], s, _NEG
                )
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            if p_bf16:
                # halve the dominant tile traffic; error < 0.4% per chunk,
                # accumulator stays fp32
                p = p.astype(jnp.bfloat16)
                pv = jnp.einsum("bhst,bthd->bhsd", p,
                                vblk.astype(jnp.bfloat16)).astype(jnp.float32)
            else:
                pv = jnp.einsum("bhst,bthd->bhsd", p, vblk.astype(jnp.float32))
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, cq), _NEG, jnp.float32)
        l0 = jnp.zeros((B, H, cq), jnp.float32)
        a0 = jnp.zeros((B, H, cq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kc, vc)
        )
        o = acc / jnp.maximum(l[..., None], 1e-30)  # (B,H,cq,hd)
        return None, jnp.moveaxis(o, 1, 2)  # (B,cq,H,hd)

    _, o = jax.lax.scan(q_step, None, (jnp.arange(nq), qc))
    return jnp.moveaxis(o, 0, 1).reshape(B, S, H, hd)


def attention(
    cfg: ModelConfig,
    p,
    x,
    *,
    cos_sin=None,
    kv_src=None,  # encoder states for cross-attention
    cache=None,  # {"k": (B,T,Hkv,hd), "v": ...} or None
    cache_index=None,  # scalar: #tokens already in cache
    causal: bool = True,
    flash_threshold: int = 2048,
):
    """Returns (output (B,S,D), new_cache)."""
    hd = cfg.hd
    B, S, _ = x.shape
    src = x if kv_src is None else kv_src
    cdt = cfg.cdt

    q = x @ p["wq"].astype(cdt)
    k = src @ p["wk"].astype(cdt)
    v = src @ p["wv"].astype(cdt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cdt)
        k = k + p["bk"].astype(cdt)
        v = v + p["bv"].astype(cdt)
    q = shard(q, DATA_AXES, None, "model")
    k = shard(k, DATA_AXES, None, "model")
    v = shard(v, DATA_AXES, None, "model")

    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, -1, cfg.n_kv_heads, hd)
    v = v.reshape(B, -1, cfg.n_kv_heads, hd)
    if cos_sin is not None and kv_src is None:
        cos, sin = cos_sin
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    elif cos_sin is not None:
        q = apply_rope(q, *cos_sin)

    kv_len = None
    new_cache = None
    if cache is not None:
        ck_, cv_ = cache["k"], cache["v"]
        k = jax.lax.dynamic_update_slice(ck_, k.astype(ck_.dtype), (0, cache_index, 0, 0))
        v = jax.lax.dynamic_update_slice(cv_, v.astype(cv_.dtype), (0, cache_index, 0, 0))
        k = shard(k, DATA_AXES, "model", None, None)
        v = shard(v, DATA_AXES, "model", None, None)
        new_cache = {"k": k, "v": v}
        kv_len = cache_index + S

    G = cfg.n_heads // cfg.n_kv_heads
    kq = _repeat_kv(k, G)
    vq = _repeat_kv(v, G)
    q_offset = 0 if cache_index is None else cache_index
    T = kq.shape[1]
    if S > 1 and max(S, T) > flash_threshold and (causal or cache is None):
        # train + long prefill (encoder/cross included): chunked online
        # softmax; with a cache, causal masking also hides the unwritten
        # tail (t > q_offset + S - 1)
        o = _flash_attend(q, kq, vq, causal=causal, q_offset=q_offset,
                          p_bf16=cfg.flash_p_bf16)
    else:
        o = _dense_attend(q, kq, vq, causal=causal, q_offset=q_offset, kv_len=kv_len)
    o = o.reshape(B, S, cfg.n_heads * hd).astype(cdt)
    o = shard(o, DATA_AXES, None, "model")
    out = o @ p["wo"].astype(cdt)
    return shard(out, DATA_AXES, None, None), new_cache


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int, dtype):
    shape = (n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
