"""Subprocess scaling harness (Figs 6-10): runs strong/weak scaling sweeps
over virtual CPU devices and emits JSON.  Invoked by bench_scaling.py so the
main benchmark process keeps the default single device.
"""
import json
import os
import sys

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)

import time  # noqa: E402

import numpy as np  # noqa: E402


def measure(way, n_f, n_v, n_pv, n_pr=1, n_st=1):
    from repro.core.threeway import czek3_distributed
    from repro.core.twoway import CometConfig, czek2_distributed
    from repro.parallel.mesh import make_comet_mesh

    from repro.core.synthetic import random_integer_vectors

    V = random_integer_vectors(n_f, n_v, seed=0)
    cfg = CometConfig(n_pv=n_pv, n_pr=n_pr, n_st=n_st)
    mesh = make_comet_mesh(1, n_pv, n_pr)
    run = (
        (lambda: czek2_distributed(V, mesh, cfg))
        if way == 2
        else (lambda: czek3_distributed(V, mesh, cfg, stage=0))
    )
    out = run()  # warmup/compile
    t0 = time.perf_counter()
    out = run()
    dt = time.perf_counter() - t0
    n_results = out.num_pairs() if way == 2 else out.num_triples()
    return {
        "way": way, "n_f": n_f, "n_v": n_v, "n_pv": n_pv, "n_pr": n_pr,
        "seconds": dt, "results": n_results,
        "comparisons": n_results * n_f,
        "rate": n_results * n_f / dt,
        "rate_per_rank": n_results * n_f / dt / (n_pv * n_pr),
    }


def main():
    results = {"strong_2way": [], "strong_3way": [], "weak_2way": [], "weak_3way": []}
    # Fig 6 analog: strong scaling, fixed problem
    for n_pv in (1, 2, 4, 8):
        results["strong_2way"].append(measure(2, 512, 1024, n_pv))
    for n_pv in (1, 2, 4):
        results["strong_3way"].append(measure(3, 64, 96, n_pv))
    # Figs 7-10 analog: weak scaling, fixed per-rank work
    for n_pv in (1, 2, 4, 8):
        results["weak_2way"].append(measure(2, 512, 512 * n_pv, n_pv))
    for n_pv in (1, 2, 4):
        results["weak_3way"].append(measure(3, 64, 48 * n_pv, n_pv))
    print(json.dumps(results))
    return 0


if __name__ == "__main__":
    sys.exit(main())
