"""On-disk packed bit-plane dataset format — manifest schema + checksum rule.

A dataset is a directory:

    ds/
      dataset.json           manifest (this module owns the schema)
      planes.shard00.npy     field shard 0: (levels, kb / n_shards, n_v) uint8
      planes.shard01.npy     ...
      stats.npy              exact-stats sidecar: (levels, n_v) int64

The shard payloads are laid out EXACTLY as the wire format in
docs/BITPLANE_FORMAT.md ("On-disk storage" chapter): each ``.npy`` holds a
C-contiguous ``(levels, kbs, n_v)`` uint8 array — LSB-first bit packing
along the byte (field) axis — where ``kbs = kb / n_shards`` and shard ``r``
covers bytes ``[r·kbs, (r+1)·kbs)``, i.e. fields ``[8·r·kbs, 8·(r+1)·kbs)``.
A disk shard therefore IS the ``shard_planes_fields`` byte range the engines
place on the "pf" mesh axis (property-tested in tests/test_store.py).

Stats sidecar: ``stats[t-1, c]`` is the popcount of plane ``t`` for vector
``c``.  Because ``V = Σ_t plane_t``, the per-vector column sums — the
Czekanowski denominators — are ``stats.sum(axis=0)``; for ``levels=1``
(binary / Sorenson data) the stats ARE the popcounts, seeding the ROADMAP
popcount-kernel item.

Checksum rule: ``sha256`` over the raw C-order bytes of every shard array,
shards concatenated in rank order (array bytes, NOT file bytes — the npy
header is excluded so the rule survives npy-version bumps).  Stored as
``"sha256:<hex>"`` in the manifest; ``DatasetReader.validate`` recomputes it.

Lineage (append): a dataset grown with ``append_dataset`` carries
``dataset_version`` (parent's + 1) and a ``parent`` block —
``{path, checksum, n_v, dataset_version}`` of the dataset it was appended
onto — so delta campaigns can prove a prior result belongs to this
dataset's ancestry before merging border blocks into it.
"""
from __future__ import annotations

import hashlib
import json
import os

import numpy as np

FORMAT_NAME = "repro-bitplane-dataset"
FORMAT_VERSION = 1
MANIFEST_NAME = "dataset.json"
STATS_NAME = "stats.npy"

__all__ = [
    "FORMAT_NAME",
    "FORMAT_VERSION",
    "MANIFEST_NAME",
    "STATS_NAME",
    "shard_name",
    "payload_checksum",
    "write_manifest",
    "read_manifest",
]


def shard_name(rank: int) -> str:
    return f"planes.shard{rank:02d}.npy"


def payload_checksum(shard_arrays) -> str:
    """The normative dataset checksum over shard payloads in rank order."""
    h = hashlib.sha256()
    for arr in shard_arrays:
        h.update(np.ascontiguousarray(arr).tobytes())
    return "sha256:" + h.hexdigest()


def write_manifest(path: str, manifest: dict) -> str:
    target = os.path.join(path, MANIFEST_NAME)
    with open(target, "w") as f:
        json.dump(manifest, f, indent=2)
        f.write("\n")
    return target


def read_manifest(path: str) -> dict:
    """Load + structurally validate a dataset manifest.

    ``path`` is the dataset directory.  Raises ValueError with a specific
    message on every malformed field, so `dataset validate` and the
    campaign loader fail loudly instead of mis-reading payloads.
    """
    target = os.path.join(path, MANIFEST_NAME)
    if not os.path.exists(target):
        raise ValueError(f"{path!r} is not a dataset directory (no {MANIFEST_NAME})")
    with open(target) as f:
        m = json.load(f)
    if m.get("format") != FORMAT_NAME:
        raise ValueError(
            f"{target}: format {m.get('format')!r} != {FORMAT_NAME!r}"
        )
    if m.get("format_version") != FORMAT_VERSION:
        raise ValueError(
            f"{target}: format_version {m.get('format_version')!r} "
            f"unsupported (expected {FORMAT_VERSION})"
        )
    for key in ("levels", "n_f", "n_v", "kb", "n_shards"):
        v = m.get(key)
        if not isinstance(v, int) or v < 1:
            raise ValueError(f"{target}: {key} must be a positive int, got {v!r}")
    if m["n_f"] > 8 * m["kb"]:
        raise ValueError(f"{target}: n_f={m['n_f']} > 8*kb={8 * m['kb']}")
    if m["kb"] % m["n_shards"]:
        raise ValueError(
            f"{target}: kb={m['kb']} not divisible by n_shards={m['n_shards']}"
        )
    shards = m.get("shard_files")
    if (
        not isinstance(shards, list)
        or len(shards) != m["n_shards"]
        or not all(isinstance(s, str) and s for s in shards)
    ):
        raise ValueError(
            f"{target}: shard_files must list exactly n_shards="
            f"{m['n_shards']} file names, got {shards!r}"
        )
    if not isinstance(m.get("stats_file"), str) or not m["stats_file"]:
        raise ValueError(
            f"{target}: stats_file must be a file name, got "
            f"{m.get('stats_file')!r}"
        )
    if not isinstance(m.get("checksum"), str) or not m["checksum"].startswith("sha256:"):
        raise ValueError(f"{target}: checksum must be 'sha256:<hex>'")
    dv = m.get("dataset_version", 1)
    if not isinstance(dv, int) or dv < 1:
        raise ValueError(
            f"{target}: dataset_version must be a positive int, got {dv!r}"
        )
    parent = m.get("parent")
    if parent is not None:
        if not isinstance(parent, dict):
            raise ValueError(f"{target}: parent must be a dict, got {parent!r}")
        if (
            not isinstance(parent.get("checksum"), str)
            or not parent["checksum"].startswith("sha256:")
        ):
            raise ValueError(f"{target}: parent.checksum must be 'sha256:<hex>'")
        pn = parent.get("n_v")
        if not isinstance(pn, int) or not 1 <= pn < m["n_v"]:
            raise ValueError(
                f"{target}: parent.n_v must be an int in [1, n_v), got {pn!r}"
            )
    return m
