from .ops import (  # noqa: F401
    czek3_step,
    threeway_batch,
    threeway_batch_levels,
    threeway_step,
)
from .ref import czek3_step_ref  # noqa: F401
