"""Paper Tables 3/4: maximum operation + comparison rates.

Measured on CPU (this container) and MODELED for the v5e target from the
committed dry-run roofline artifacts (results/comet/comet_*.json —
see results/README.md for the directory contract): rate =
comparisons_per_step / max(t_compute, t_memory, t_collective).  The paper's
headline: 2-way 4.29e15 cmp/s SP (17472 K20X nodes), 3-way 5.70e15 cmp/s.
"""
from __future__ import annotations

import glob
import json
import os

import jax.numpy as jnp

from benchmarks.util import row, time_fn
from repro.core.mgemm import mgemm_xla
from repro.core.synthetic import random_integer_vectors

HERE = os.path.dirname(os.path.abspath(__file__))
COMET_RESULTS = os.path.join(HERE, "..", "results", "comet")


def main():
    rows = []
    # measured single-CPU-core mGEMM comparison rate (1 comparison = 1 min
    # + 1 add over a vector element pair)
    V = random_integer_vectors(1024, 768, seed=0)
    Vj = jnp.asarray(V)
    t = time_fn(lambda v: mgemm_xla(v.T, v), Vj)
    comps = 1024 * 768 * 768  # full matrix (measured kernel computes all)
    rows.append(row("table3/cpu_core_2way", t, f"{comps / t:.3e}_cmp/s"))

    # modeled v5e pod rates from dry-run artifacts
    for path in sorted(glob.glob(os.path.join(COMET_RESULTS, "comet_*.json"))):
        with open(path) as f:
            r = json.load(f)
        terms = r["roofline"]
        t_bound = max(terms["t_compute"], terms["t_memory"], terms["t_collective"])
        comps = r.get("elementwise_comparisons", 0)
        if not comps or t_bound <= 0:
            continue
        tag = os.path.basename(path).replace(".json", "")
        chips = terms["n_devices"]
        rows.append(
            row(f"table3_4/v5e_model/{tag}", t_bound,
                f"{comps / t_bound:.3e}_cmp/s_{chips}chips_"
                f"bottleneck={terms['bottleneck']}")
        )
    return rows


if __name__ == "__main__":
    from benchmarks.util import print_rows

    print_rows(main())
