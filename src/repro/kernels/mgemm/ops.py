"""jit'd public wrappers for the mGEMM Pallas kernel + impl registration."""
from __future__ import annotations

import jax

from repro.core.mgemm import register_impl

from .kernel import czek2_metric_pallas, mgemm_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def mgemm(A, B, **kw):
    """Pallas mGEMM; interprets automatically off-TPU (kernel-body-on-CPU)."""
    kw.setdefault("interpret", not _on_tpu())
    return mgemm_pallas(A, B, **kw)


def czek2_metric(A, B, sa, sb, **kw):
    kw.setdefault("interpret", not _on_tpu())
    return czek2_metric_pallas(A, B, sa, sb, **kw)


register_impl("pallas", mgemm)
