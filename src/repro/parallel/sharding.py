"""Sharding rules: logical parallelism mapping for the LM stack.

Megatron-style tensor parallelism over the "model" axis, data parallelism
over "data" (x "pod"), realized through GSPMD:

* params — column-parallel QKV / gate-up (shard the output feature dim),
  row-parallel out/down projections (shard the input feature dim),
  vocab-parallel embedding + logits; MoE experts shard their hidden (d_ff)
  dim over "model" ("expert-internal TP" — exact for any expert count,
  no capacity/divisibility coupling to the mesh; see DESIGN.md §4).
* activations — batch over ("pod","data"); the residual stream is kept
  replicated over "model" between blocks, with XLA inserting the Megatron
  all-reduces after row-parallel matmuls.

``shard()`` applies a constraint only when a mesh with the named axes is
active, so the same model code runs on a laptop CPU (no mesh), under the
512-device dry-run, and on a real pod.
"""
from __future__ import annotations

from contextlib import contextmanager

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.compat import set_mesh

_ACTIVE: list[Mesh] = []
_DP_ONLY: list[bool] = []
DATA_AXES = ("pod", "data")  # folded batch axes (pod may be absent)


@contextmanager
def dp_only_mode():
    """ZeRO-3 axis remapping (§Perf): the "model" axis joins data
    parallelism — batch shards over ("data","model"), tensor-parallel
    entries are dropped, parameters fully shard over all axes.  Constraints
    written for the TP layout are translated on the fly."""
    _DP_ONLY.append(True)
    try:
        yield
    finally:
        _DP_ONLY.pop()


def dp_only_active() -> bool:
    return bool(_DP_ONLY)


def _translate_dp_only(spec: P) -> P:
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
        elif isinstance(entry, (tuple, list)):
            if tuple(entry) == DATA_AXES:
                out.append(("data", "model"))  # batch over both in-pod axes
            else:
                out.append(tuple(a for a in entry if a != "model") or None)
        else:
            out.append(None if entry == "model" else entry)
    return P(*out)


@contextmanager
def use_mesh(mesh: Mesh | None):
    if mesh is None:
        yield
        return
    _ACTIVE.append(mesh)
    try:
        with set_mesh(mesh):
            yield
    finally:
        _ACTIVE.pop()


def active_mesh() -> Mesh | None:
    return _ACTIVE[-1] if _ACTIVE else None


def _filter_spec(spec: P, mesh: Mesh, shape: tuple | None = None) -> P:
    """Drop axis names the active mesh doesn't have (e.g. 'pod' single-pod)
    and entries that don't divide the dimension (JAX rejects uneven input
    shardings — e.g. granite's vocab 49155 on a 16-wide axis stays
    replicated)."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, (tuple, list)) else (entry,)
        kept = tuple(a for a in axes if a in mesh.axis_names)
        if shape is not None and kept:
            size = 1
            for a in kept:
                size *= mesh.shape[a]
            if i >= len(shape) or shape[i] % size != 0:
                # try the first axis alone before giving up
                kept = tuple(
                    a for a in kept if shape[i] % mesh.shape[a] == 0
                )[:1]
        if not kept:
            out.append(None)
        elif len(kept) == 1 and not isinstance(entry, (tuple, list)):
            out.append(kept[0])
        else:
            out.append(kept)
    return P(*out)


def shard(x, *spec_entries):
    """with_sharding_constraint if a mesh is active, else identity."""
    mesh = active_mesh()
    if mesh is None:
        return x
    spec = P(*spec_entries)
    if dp_only_active():
        spec = _translate_dp_only(spec)
    spec = _filter_spec(spec, mesh, shape=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def batch_spec(*rest) -> tuple:
    """(('pod','data'), *rest) — batch dim over the folded data axes."""
    return (DATA_AXES, *rest)


def named_sharding(mesh: Mesh, *entries, shape: tuple | None = None) -> NamedSharding:
    return NamedSharding(mesh, _filter_spec(P(*entries), mesh, shape=shape))
