"""Thread-aware span tracer with zero overhead when disabled.

Design constraints (pinned by tests/test_obs.py):

* **Disabled is free.**  The module-global ``_tracer`` is ``None`` by
  default and ``span()`` returns ONE shared no-op singleton — a traced
  call site costs a global read and a ``is None`` branch, with no
  allocation, no lock and no clock read.  The engines therefore leave
  their span calls in place permanently; campaign checksums and hot-path
  timings are untouched unless a tracer is installed.

* **Thread-aware.**  Events carry ``threading.get_ident()`` as the
  Chrome ``tid``; span nesting is tracked in a ``contextvars.ContextVar``
  so callers that hop threads (``ShardPrefetcher``'s staging worker,
  ``SimilarityService``'s campaign workers) can carry their logical
  parent across via ``contextvars.copy_context()`` — the B event records
  the parent path in ``args["parent"]``.

* **Chrome trace-event output.**  ``Tracer.chrome_trace()`` emits
  strictly matched B/E duration pairs (ts in microseconds, monotonic
  clock) that load directly in Perfetto / ``chrome://tracing``;
  ``validate_chrome_trace`` is the stdlib-only schema checker CI runs on
  the exported file.

* **Device time.**  Wall time around an async XLA dispatch measures the
  enqueue, not the compute; ``fence(x)`` calls ``jax.block_until_ready``
  — only when tracing is enabled — so a span closed after a fence reads
  true device time.  With tracing off the fence is a no-op and XLA's
  async scheduling is undisturbed.
"""
from __future__ import annotations

import contextvars
import json
import os
import threading
import time

__all__ = [
    "Tracer",
    "aggregate_phases",
    "current_path",
    "disable",
    "enable",
    "enabled",
    "fence",
    "format_phase_table",
    "get_tracer",
    "roofline_event",
    "span",
    "validate_chrome_trace",
    "CANONICAL_PHASES",
]

#: Canonical campaign phases, in pipeline order.  ``format_phase_table``
#: always prints a row for each (count 0 when the phase never ran — an
#: encode row at 0 on a dataset campaign is the zero-encode proof), so
#: consumers can grep for a phase unconditionally.
CANONICAL_PHASES = (
    "validate",
    "encode",
    "prefetch-stage",
    "ring-step",
    "delta-border",
    "merge",
)

_tracer: "Tracer | None" = None  # None == disabled (the zero-overhead path)

_SPAN_STACK: contextvars.ContextVar[tuple] = contextvars.ContextVar(
    "repro_obs_span_stack", default=()
)


class _NullSpan:
    """The shared disabled-mode span: every method is a no-op."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def add(self, **attrs):
        return self


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_tracer", "name", "_attrs", "_token")

    def __init__(self, tracer, name, attrs):
        self._tracer = tracer
        self.name = name
        self._attrs = dict(attrs) if attrs else {}

    def add(self, **attrs):
        """Attach attributes (byte counts, step counts, ...) to the span;
        they ride on the closing E event."""
        self._attrs.update(attrs)
        return self

    def __enter__(self):
        stack = _SPAN_STACK.get()
        self._token = _SPAN_STACK.set(stack + (self.name,))
        args = {"parent": "/".join(stack)} if stack else None
        self._tracer._emit("B", self.name, self._tracer._clock(), args)
        return self

    def __exit__(self, *exc):
        self._tracer._emit(
            "E", self.name, self._tracer._clock(), self._attrs or None
        )
        _SPAN_STACK.reset(self._token)
        return False


class Tracer:
    """Collects B/E trace events; install with ``enable()``."""

    def __init__(self):
        self._lock = threading.Lock()
        self._events = []  # (ph, name, ts_ns, tid, args)
        self._clock = time.perf_counter_ns
        self._t0 = self._clock()

    # -- recording -----------------------------------------------------------

    def span(self, name: str, attrs: dict = None) -> _Span:
        return _Span(self, name, attrs)

    def _emit(self, ph, name, ts_ns, args):
        tid = threading.get_ident()
        with self._lock:
            self._events.append((ph, name, ts_ns, tid, args))

    def complete(self, name: str, t0_ns: int, t1_ns: int,
                 attrs: dict = None, tid: int = None) -> None:
        """Record an interval measured externally (e.g. a queue wait whose
        endpoints live in different threads) as a matched B/E pair.

        ``tid`` overrides the thread id — intervals that OVERLAP a
        thread's own spans (a queue wait that began while the worker was
        still computing the previous request) go on a virtual lane so B/E
        nesting stays well-formed per (pid, tid)."""
        if tid is None:
            tid = threading.get_ident()
        with self._lock:
            self._events.append(("B", name, t0_ns, tid, None))
            self._events.append(("E", name, t1_ns, tid, attrs or None))

    # -- reading -------------------------------------------------------------

    def event_count(self) -> int:
        with self._lock:
            return len(self._events)

    def events(self, since: int = 0) -> list:
        """Snapshot of recorded events (optionally from index ``since``)."""
        with self._lock:
            return list(self._events[since:])

    def phase_stats(self, since: int = 0) -> dict:
        return aggregate_phases(self.events(since))

    def chrome_trace(self) -> dict:
        """Chrome trace-event JSON object (``{"traceEvents": [...]}``).

        Events are sorted by timestamp; the sort is stable, so same-thread
        same-tick B/E pairs keep their recorded (correct) order.
        """
        pid = os.getpid()
        out = []
        for ph, name, ts, tid, args in sorted(
            self.events(), key=lambda e: e[2]
        ):
            ev = {
                "name": name,
                "ph": ph,
                "ts": (ts - self._t0) / 1000.0,  # ns -> microseconds
                "pid": pid,
                "tid": tid,
            }
            if args:
                ev["args"] = args
            out.append(ev)
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, indent=1)
            f.write("\n")


# -- module-level API (the form instrumented code calls) ----------------------


def enable(tracer: Tracer = None) -> Tracer:
    """Install (and return) the process tracer; spans record from now on."""
    global _tracer
    _tracer = tracer if tracer is not None else Tracer()
    return _tracer


def disable() -> "Tracer | None":
    """Remove the process tracer (span calls become no-ops again) and
    return it, so the caller can still export what was recorded."""
    global _tracer
    t, _tracer = _tracer, None
    return t


def enabled() -> bool:
    return _tracer is not None


def get_tracer() -> "Tracer | None":
    return _tracer


def span(name: str, attrs: dict = None):
    """Open a span: ``with span("encode", {"bytes": n}) as sp: ...``.

    Disabled, this returns the shared no-op singleton — no allocation.
    (The ``attrs`` dict literal at an instrumented call site WOULD
    allocate even when disabled; hot paths therefore pass attrs via
    ``sp.add(...)`` inside the span or not at all.)"""
    t = _tracer
    if t is None:
        return _NULL_SPAN
    return t.span(name, attrs)


def current_path() -> tuple:
    """The context's open-span name stack (propagates with copy_context)."""
    return _SPAN_STACK.get()


def fence(x):
    """``jax.block_until_ready(x)`` — only when tracing is enabled — so the
    enclosing span measures device time, not dispatch time."""
    if _tracer is not None:
        import jax

        jax.block_until_ready(x)
    return x


def roofline_event(jitted, args, n_devices: int, repeats: int = 1) -> None:
    """Record the roofline cost bound of ``repeats`` calls of
    ``jitted(*args)`` as a zero-length ``roofline`` span (attrs:
    ``bound_seconds``, per-term seconds, bottleneck).  No-op when tracing
    is disabled; best-effort when enabled (lower/compile is allowed to
    fail off-path).  Streamed campaigns pass the chunk program once with
    ``repeats=n_chunks``."""
    t = _tracer
    if t is None:
        return
    try:
        compiled = jitted.lower(*args).compile()
        from repro.roofline.analysis import analyze_compiled

        terms = analyze_compiled(compiled, n_devices)
    except Exception:
        return
    bound = max(terms["t_compute"], terms["t_memory"], terms["t_collective"])
    ts = t._clock()
    t.complete("roofline", ts, ts, {
        "bound_seconds": bound * repeats,
        "t_compute": terms["t_compute"],
        "t_memory": terms["t_memory"],
        "t_collective": terms["t_collective"],
        "bottleneck": terms["bottleneck"],
        "flops_per_device": terms["flops_per_device"],
        "n_devices": n_devices,
        "repeats": repeats,
    })


# -- aggregation + formatting -------------------------------------------------


def aggregate_phases(events) -> dict:
    """``{name: {"count", "seconds"}}`` from matched B/E pairs (per tid)."""
    stacks, agg = {}, {}
    for ph, name, ts, tid, _args in sorted(events, key=lambda e: e[2]):
        if ph == "B":
            stacks.setdefault(tid, []).append((name, ts))
        elif ph == "E":
            st = stacks.get(tid)
            if st and st[-1][0] == name:
                _, t0 = st.pop()
                a = agg.setdefault(name, {"count": 0, "seconds": 0.0})
                a["count"] += 1
                a["seconds"] += (ts - t0) / 1e9
    return agg


def format_phase_table(phases: dict) -> str:
    """Human-readable per-phase table (what the CLI prints after --trace).

    Every canonical phase gets a row even at count 0; extra recorded
    phases follow in name order.  Self-time is not computed — nested
    spans (a merge inside a campaign) each report their own wall time.
    """
    names = list(CANONICAL_PHASES) + sorted(
        n for n in phases if n not in CANONICAL_PHASES and n != "roofline"
    )
    total = sum(phases.get(n, {}).get("seconds", 0.0) for n in names) or 1.0
    rows = ["phase            count     seconds    share"]
    for n in names:
        p = phases.get(n, {"count": 0, "seconds": 0.0})
        rows.append(
            f"{n:<16s} {p['count']:>5d} {p['seconds']:>11.6f} "
            f"{100.0 * p['seconds'] / total:>7.1f}%"
        )
    return "\n".join(rows)


# -- stdlib-only trace-file checker (used by CI and the property test) --------


def validate_chrome_trace(payload) -> int:
    """Raise ValueError unless ``payload`` is a well-formed Chrome
    trace-event object as this tracer emits it: a ``traceEvents`` list of
    B/E events with the required fields, timestamps monotonically
    non-decreasing, and every E matching the innermost open B of the same
    name on its (pid, tid) stack.  Returns the event count."""
    if not isinstance(payload, dict) or "traceEvents" not in payload:
        raise ValueError("not a Chrome trace object: missing 'traceEvents'")
    events = payload["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    last_ts = None
    stacks = {}
    for i, ev in enumerate(events):
        for field in ("name", "ph", "ts", "pid", "tid"):
            if field not in ev:
                raise ValueError(f"traceEvents[{i}] missing field {field!r}")
        if ev["ph"] not in ("B", "E"):
            raise ValueError(
                f"traceEvents[{i}] phase {ev['ph']!r} is not 'B'/'E'"
            )
        if not isinstance(ev["ts"], (int, float)):
            raise ValueError(f"traceEvents[{i}].ts must be a number")
        if last_ts is not None and ev["ts"] < last_ts:
            raise ValueError(
                f"traceEvents[{i}].ts {ev['ts']} < previous {last_ts} "
                "(timestamps must be monotonic)"
            )
        last_ts = ev["ts"]
        key = (ev["pid"], ev["tid"])
        if ev["ph"] == "B":
            stacks.setdefault(key, []).append(ev["name"])
        else:
            st = stacks.get(key)
            if not st or st[-1] != ev["name"]:
                raise ValueError(
                    f"traceEvents[{i}]: E {ev['name']!r} does not match "
                    f"open B {st[-1] if st else None!r} on {key}"
                )
            st.pop()
    dangling = {k: v for k, v in stacks.items() if v}
    if dangling:
        raise ValueError(f"unclosed B events: {dangling}")
    return len(events)
