"""Correctness of the §Perf hillclimb knobs: every optimization must keep
results (bit-)exact or within documented tolerance vs the baseline."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.core.metrics import czek2_metric_np
from repro.core.synthetic import random_integer_vectors
from repro.core.twoway import CometConfig, czek2_distributed
from repro.core.threeway import czek3_distributed
from repro.models import api
from repro.parallel.mesh import make_comet_mesh


def _mesh1():
    return make_comet_mesh(1, 1, 1)


def test_int8_ring_bit_exact():
    """int8 ring payload must be BIT-exact for small-integer data (2-way)."""
    V = random_integer_vectors(50, 18, max_value=15, seed=3)
    base = czek2_distributed(V, _mesh1(), CometConfig())
    opt = czek2_distributed(V, _mesh1(), CometConfig(ring_dtype="int8"))
    assert base.checksum() == opt.checksum()


def test_int8_ring_bit_exact_3way():
    V = random_integer_vectors(30, 12, max_value=7, seed=4)
    base = czek3_distributed(V, _mesh1(), CometConfig(), stage=0)
    opt = czek3_distributed(V, _mesh1(), CometConfig(ring_dtype="int8"), stage=0)
    assert base.checksum() == opt.checksum()


def test_int8_ring_with_levels_impl():
    V = random_integer_vectors(40, 12, max_value=2, seed=5)  # SNP-style {0,1,2}
    base = czek2_distributed(V, _mesh1(), CometConfig())
    opt = czek2_distributed(
        V, _mesh1(),
        CometConfig(impl="levels_xla", levels=2, ring_dtype="int8"),
    )
    assert base.checksum() == opt.checksum()


def test_seq_parallel_same_loss():
    """seq_parallel only changes sharding constraints — identical math."""
    cfg = get_smoke_config("llama3-8b")
    params = api.init_model(cfg, jax.random.PRNGKey(0))
    batch = {
        "tokens": jnp.arange(64, dtype=jnp.int32).reshape(2, 32) % cfg.vocab_size,
        "labels": jnp.arange(64, dtype=jnp.int32).reshape(2, 32) % cfg.vocab_size,
    }
    l0 = float(api.model_loss(cfg, params, batch))
    l1 = float(api.model_loss(cfg.replace(seq_parallel=True), params, batch))
    assert l0 == l1  # no mesh active -> constraints are no-ops, math identical


def test_flash_p_bf16_close():
    cfg = get_smoke_config("llama3-8b")
    params = api.init_model(cfg, jax.random.PRNGKey(1))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (1, 64), 0, cfg.vocab_size)
    base, _ = api.model_forward(cfg, params, {"tokens": tokens})
    # force the flash path with a tiny threshold via long-enough seq? smoke
    # seq is small; exercise _flash_attend directly instead
    from repro.models.attention import _flash_attend

    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((2, 64, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 64, 4, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 64, 4, 16)), jnp.float32)
    a = _flash_attend(q, k, v, causal=True, cq=16, ck=16)
    b = _flash_attend(q, k, v, causal=True, cq=16, ck=16, p_bf16=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-2)
    # and flash agrees with dense reference
    from repro.models.attention import _dense_attend

    d = _dense_attend(q, k, v, causal=True, q_offset=0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(d), rtol=2e-4, atol=2e-5)


def test_moe_chunked_dispatch_close():
    """Chunked dispatch: same expert math, per-chunk capacity; outputs must
    match the global dispatch wherever no token was dropped."""
    cfg = get_smoke_config("grok-1-314b").replace(capacity_factor=4.0)
    params = api.init_model(cfg, jax.random.PRNGKey(3))
    from repro.models.mlp import moe

    layer0 = jax.tree.map(lambda a: a[0], params["layers"])["moe"]
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 32, cfg.d_model)) * 0.1
    y0, _ = moe(cfg, layer0, x)
    y1, _ = moe(cfg.replace(moe_dispatch_chunks=4), layer0, x)
    # with generous capacity nothing is dropped in either mode
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-5)


def test_moe_chunked_dispatch_grad_finite():
    cfg = get_smoke_config("granite-moe-3b-a800m").replace(moe_dispatch_chunks=4)
    params = api.init_model(cfg, jax.random.PRNGKey(5))
    batch = {
        "tokens": jnp.ones((2, 16), jnp.int32),
        "labels": jnp.ones((2, 16), jnp.int32),
    }
    loss, grads = jax.value_and_grad(lambda p: api.model_loss(cfg, p, batch))(params)
    assert np.isfinite(float(loss))
    assert all(np.isfinite(np.asarray(g)).all() for g in jax.tree.leaves(grads))