"""Border-block delta campaigns: incremental 2-way results for appended vectors.

When a cohort grows from ``n`` to ``n + m`` vectors (``repro.store``'s
``append_dataset``), the full triangular campaign wastes almost all of the
work already paid for: the prior result covers every pair inside ``[0, n)``.
The only NEW pairs are the **border** —

* the rectangle: old ``i in [0, n)`` vs new ``j in [n, n + m)``, and
* the small new-vs-new triangle inside ``[n, n + m)``

— ``n*m + m*(m-1)/2`` entries instead of ``(n+m)(n+m-1)/2``.  This module
computes exactly that border on the mesh and merges it with a prior
``TwoWayOutput`` into packed upper-triangular storage.

SPMD mapping: there is NO ring.  The old block shards its vector axis over
the combined ("pv", "pr") mesh axes (each rank holds ``n_op = ceil(n /
(n_pv * n_pr))`` old vectors), the new block is replicated, and fields
shard over "pf" exactly as in the full engine (numerator psums over "pf").
Each rank computes its own ``(n_op, m)`` slice of the rectangle through
``TileExecutor.pair_block`` — the SAME fused-levels / popcount / unfused
kernels as full campaigns — and rank (pv=0, pr=0) additionally computes the
new-vs-new triangle on the triangular tile schedule (``lax.cond`` skips it
elsewhere, mirroring the full engine's half-step masking).  Ring payload
bytes are zero by construction; ``delta_accounting`` records the
``m·n``-proportional compute so ``meta["delta"]`` can prove it.

Bit-exactness: border numerators are the same exact fp32 integer
contractions (any kernel path) and the same ``assemble_tile`` /
``assemble2`` elementwise assembly as the full engine's off-diagonal and
diagonal blocks, so the merged result's checksum is bit-identical to a
from-scratch recompute of the grown cohort at ANY decomposition — pinned in
tests/test_delta.py and tests/distributed_harness.py ``check_delta``.

Merged storage: a single-rank ``TwoWayPlan(1, 1)`` packed upper-triangular
``TwoWayOutput`` (``N(N-1)/2`` values in ``np.triu_indices`` row-major
order) — a valid prior for the NEXT append, so deltas chain.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.parallel.compat import shard_map

from repro.core.metric_spec import CZEKANOWSKI, MetricSpec
from repro.core.plan2 import TwoWayPlan
from repro.core.tile_executor import TileExecutor
from repro.obs import trace as obs
from repro.core.twoway import (
    CometConfig,
    TwoWayOutput,
    _cached_jit,
    resolve_config,
)

__all__ = [
    "twoway_delta",
    "merge_delta",
    "delta_accounting",
    "packed_upper_index",
]


def packed_upper_index(i, j, N: int):
    """Flat position of strict-upper pair (i < j) in ``np.triu_indices(N, 1)``
    row-major order — the packed single-rank layout ``TwoWayOutput``
    unpacks with ``out[np.triu_indices(m, 1)] = flat``."""
    return i * (2 * N - i - 1) // 2 + (j - i - 1)


def delta_accounting(
    cfg: CometConfig, *, n_old: int, n_new: int, n_op: int,
    payload_bytes: int, streamed: bool = False, ring_payload_bytes: int = 0,
) -> dict:
    """The ``meta["delta"]`` block: proof that border-mode compute scales
    with ``m·n + m²/2`` entries, not ``n²``.

    ``computed_entries`` counts what the devices actually evaluate —
    including the inert padding rows of the old-vector shards — so the
    border proportionality is honest; ``ring_payload_bytes`` is zero for
    the in-memory border (no ppermute exists in the program) and the
    chunked staging bytes for the streamed border."""
    N = n_old + n_new
    tri = n_new * (n_new - 1) // 2
    return {
        "n_old": int(n_old),
        "n_new": int(n_new),
        "border_entries": int(n_old * n_new + tri),
        "full_entries": int(N * (N - 1) // 2),
        "computed_entries": int(cfg.n_pv * cfg.n_pr * n_op * n_new + tri),
        "ring_payload_bytes": int(ring_payload_bytes),
        "payload_bytes": int(payload_bytes),
        "decomposition": [cfg.n_pf, cfg.n_pv, cfg.n_pr],
        "streamed": bool(streamed),
    }


def _prep_delta_payload(V, n_old: int, cfg: CometConfig, metric: MetricSpec):
    """Resolve the config and split the payload into the sharded old block
    and the replicated new block.

    Vector-axis slicing commutes with the bit-plane encoding (packing is
    along the field axis — ``slice_planes_vectors`` property), so a
    pre-encoded ``PackedPlanes`` payload splits by byte-column view with no
    re-encode; value matrices encode old/new separately when the plane path
    resolves (identical bytes to slicing a whole-matrix encode).  The old
    block pads its vector axis to ``n_op * n_pv * n_pr`` with inert zero
    columns.  Returns ``(cfg, args, in_specs, planes, n_op, m)``.
    """
    from repro.kernels.mgemm_levels.planes import PackedPlanes, pad_planes

    R = cfg.n_pv * cfg.n_pr
    if isinstance(V, PackedPlanes):
        n_v = V.n_v
        if not 1 <= n_old < n_v:
            raise ValueError(f"n_old={n_old} must be in [1, n_v={n_v})")
        cfg = resolve_config(cfg, V, metric)  # plane path or raises
        m = n_v - n_old
        n_op = -(-n_old // R)
        Po = pad_planes(
            np.ascontiguousarray(V.planes[:, :, :n_old]),
            byte_align=cfg.n_pf, n_v=n_op * R,
        )
        Pn = pad_planes(
            np.ascontiguousarray(V.planes[:, :, n_old:]),
            byte_align=cfg.n_pf,
        )
        return (
            cfg, (jnp.asarray(Po), jnp.asarray(Pn)),
            (P(None, "pf", ("pv", "pr")), P(None, "pf", None)),
            True, n_op, m,
        )
    V = np.asarray(V)
    n_v = V.shape[1]
    if not 1 <= n_old < n_v:
        raise ValueError(f"n_old={n_old} must be in [1, n_v={n_v})")
    cfg = resolve_config(cfg, V, metric)
    m = n_v - n_old
    n_op = -(-n_old // R)
    planes = cfg.encoding == "bitplane"
    field_align = (8 if planes else 1) * cfg.n_pf
    fp = (-V.shape[0]) % field_align
    Vp = np.pad(V, ((0, fp), (0, 0))) if fp else V
    Vo = Vp[:, :n_old]
    Vn = np.ascontiguousarray(Vp[:, n_old:])
    vp = n_op * R - n_old
    if vp:
        Vo = np.pad(Vo, ((0, 0), (0, vp)))
    if planes:
        from repro.kernels.mgemm_levels import encode_bitplanes_np

        return (
            cfg,
            (jnp.asarray(encode_bitplanes_np(Vo, cfg.levels)),
             jnp.asarray(encode_bitplanes_np(Vn, cfg.levels))),
            (P(None, "pf", ("pv", "pr")), P(None, "pf", None)),
            True, n_op, m,
        )
    dt = jnp.dtype(cfg.ring_dtype)
    return (
        cfg, (jnp.asarray(Vo, dt), jnp.asarray(Vn, dt)),
        (P("pf", ("pv", "pr")), P("pf", None)),
        False, n_op, m,
    )


def _twoway_delta_program(
    Vo, Vn, *, cfg: CometConfig, out_dtype, metric: MetricSpec = None,
    planes: bool = False,
):
    """Per-device border program (inside shard_map, NO ring).

    ``Vo``: this rank's old-vector shard — (n_f/n_pf, n_op) values or
    (levels, kb/n_pf, n_op) packed planes; ``Vn``: the replicated new
    block.  Emits the rank's (n_op, m) rectangle slice, plus — on rank
    (pv=0, pr=0) only, under ``lax.cond`` like the full engine's half-step
    masking — the (m, m) strict-upper new-vs-new triangle on the
    triangular tile schedule."""
    metric = metric or CZEKANOWSKI
    executor = TileExecutor(cfg=cfg, metric=metric, out_dtype=out_dtype,
                            axis="pf")
    if planes:
        from repro.kernels.mgemm_levels import values_from_planes

        Wo, Wn = values_from_planes(Vo), values_from_planes(Vn)
    else:
        Wo, Wn = Vo, Vn
    so = jax.lax.psum(metric.stat(Wo), "pf")
    sn = jax.lax.psum(metric.stat(Wn), "pf")
    m = Vn.shape[-1]
    rect = executor.pair_block(Vo, so, Vn, sn, diagonal=False)
    first = jnp.logical_and(
        jax.lax.axis_index("pv") == 0, jax.lax.axis_index("pr") == 0
    )
    tri = jax.lax.cond(
        first,
        lambda: executor.pair_block(Vn, sn, Vn, sn, diagonal=True),
        lambda: jnp.zeros((m, m), out_dtype),
    )
    return rect, tri[None]


def _twoway_delta_deferred_program(
    Po, Pn, *, cfg: CometConfig, metric: MetricSpec = None,
):
    """Deferred-flush border chunk program (``repro.stream``): one byte-axis
    chunk of the old/new payloads emits the rank's raw fp32 rectangle
    partial (psummed over "pf"), the rank-(0,0) new-vs-new triangle
    partial, and both stat partials; the host accumulates all four across
    chunks and the merge epilogue assembles once — bit-identical to the
    in-memory border (cross-shard merge guarantee)."""
    from repro.kernels.mgemm_levels import values_from_planes

    metric = metric or CZEKANOWSKI
    executor = TileExecutor(cfg=cfg, metric=metric, out_dtype=jnp.float32,
                            axis="pf", deferred=True)
    so = jax.lax.psum(metric.stat(values_from_planes(Po)), "pf")
    sn = jax.lax.psum(metric.stat(values_from_planes(Pn)), "pf")
    m = Pn.shape[-1]
    rect = executor.pair_partial(Po, Pn)
    first = jnp.logical_and(
        jax.lax.axis_index("pv") == 0, jax.lax.axis_index("pr") == 0
    )
    tri = jax.lax.cond(
        first,
        lambda: executor.pair_partial(Pn, Pn),
        lambda: jnp.zeros((m, m), jnp.float32),
    )
    return rect, tri[None], so, sn[None]


def twoway_delta(
    V, n_old: int, mesh, cfg: CometConfig, metric: MetricSpec = None,
) -> tuple:
    """Compute the border blocks of an appended cohort on the mesh.

    ``V`` is the FULL grown payload (values or ``PackedPlanes``) whose
    first ``n_old`` columns the prior result already covers.  Returns
    ``(rect, tri, cfg, info)``: the assembled ``(n_op * n_pv * n_pr, m)``
    rectangle (row ``i`` = old vector ``i``; padding rows past ``n_old``
    are inert), the ``(m, m)`` strict-upper new-vs-new triangle, the
    resolved config, and the ``delta_accounting`` dict.  Merge with a
    prior via ``merge_delta``."""
    metric = metric or CZEKANOWSKI
    cfg, args, in_specs, planes, n_op, m = _prep_delta_payload(
        V, n_old, cfg, metric
    )
    out_dtype = jnp.dtype(cfg.out_dtype)
    fn = _cached_jit(
        ("delta", mesh, cfg, metric.name, str(out_dtype), planes),
        lambda: shard_map(
            partial(_twoway_delta_program, cfg=cfg, out_dtype=out_dtype,
                    metric=metric, planes=planes),
            mesh=mesh,
            in_specs=in_specs,
            out_specs=(P(("pv", "pr"), None), P(("pv", "pr"), None, None)),
            check=False,
        ),
    )
    with obs.span("delta-border") as sp:
        rect, tri = obs.fence(fn(*args))
        sp.add(n_old=int(n_old), n_new=int(m),
               payload_bytes=sum(int(a.nbytes) for a in args))
    obs.roofline_event(fn, args, int(mesh.devices.size))
    info = delta_accounting(
        cfg, n_old=n_old, n_new=m, n_op=n_op,
        payload_bytes=sum(int(a.nbytes) for a in args),
    )
    return np.asarray(rect), np.asarray(tri)[0], cfg, info


def merge_delta(
    prior: TwoWayOutput, rect: np.ndarray, tri: np.ndarray,
    n_old: int, n_new: int, out_dtype,
) -> TwoWayOutput:
    """Merge a prior result and its border blocks into packed storage.

    ``prior`` may be ANY ``TwoWayOutput`` covering vectors ``[0, n_old)``
    — dense or packed, any plan (including a previous ``merge_delta``
    output, so deltas chain across appends).  The merged output is a
    single-rank ``TwoWayPlan(1, 1)`` packed upper triangle over
    ``N = n_old + n_new`` vectors whose entries — and therefore checksum —
    are bit-identical to a full recompute."""
    if prior.n_v != n_old:
        raise ValueError(
            f"prior covers n_v={prior.n_v} vectors, delta says n_old={n_old}"
        )
    N = n_old + n_new
    with obs.span("merge") as sp:
        flat = np.zeros((1, 1, N * (N - 1) // 2), np.dtype(out_dtype))
        buf = flat[0, 0]
        for I, J, vals in prior.entries():
            lo, hi = np.minimum(I, J), np.maximum(I, J)
            buf[packed_upper_index(lo, hi, N)] = vals
        i = np.arange(n_old)[:, None]
        j = n_old + np.arange(n_new)[None, :]
        buf[packed_upper_index(i, j, N).ravel()] = (
            rect[:n_old].astype(buf.dtype).ravel()
        )
        a, b = np.triu_indices(n_new, 1)
        buf[packed_upper_index(n_old + a, n_old + b, N)] = tri[a, b]
        sp.add(entries=int(buf.size), n_old=int(n_old), n_new=int(n_new))
    return TwoWayOutput(
        blocks=flat, plan=TwoWayPlan(1, 1), n_v=N, n_vp=N, storage="packed",
    )
