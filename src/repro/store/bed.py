"""PLINK 1 binary genotype ingest (.bed/.bim/.fam) -> leveled matrix.

PLINK's ``.bed`` (Chang et al., arXiv:1410.4803) is ALREADY a 2-bit packed
genotype format: after a 3-byte header (magic ``0x6c 0x1b`` + mode ``0x01``
for SNP-major) each variant is ``ceil(n_samples / 4)`` bytes, two bits per
sample, LSB-first pairs — sample ``s`` lives in byte ``s // 4`` at bit
offset ``2 * (s % 4)``.  The 2-bit codes map to A1-allele dosage:

    | code  | genotype          | dosage |
    |-------|-------------------|--------|
    | ``00``| homozygous A1     | 2      |
    | ``01``| missing           | policy |
    | ``10``| heterozygous      | 1      |
    | ``11``| homozygous A2     | 0      |

Dosages are exactly the ``{0, 1, 2}`` / ``levels=2`` SNP encoding the plane
campaigns run on, so ``.bed`` ingest is a bit-level transcode, never a
float round-trip.

Missing-genotype policy (explicit, never silent):

* ``"error"`` (default) — raise, naming the count and first offending SNP.
* ``"zero"``  — code missing as dosage 0 (absence of evidence; keeps every
  SNP, biases denominators down).
* ``"drop"``  — drop every SNP (field/vector) containing a missing call.

Orientation: CoMet campaigns compare genetic markers, so the default
``vectors="snps"`` returns ``(n_f=n_samples, n_v=n_snps)`` — SNPs are the
compared vectors, samples the contraction fields; ``vectors="samples"``
keeps the SNP-major layout ``(n_f=n_snps, n_v=n_samples)`` instead.
"""
from __future__ import annotations

import os

import numpy as np

__all__ = ["read_bed", "bed_paths", "BED_MAGIC"]

BED_MAGIC = b"\x6c\x1b"
_MODE_SNP_MAJOR = 0x01
#: 2-bit code -> A1 dosage; 255 is the internal missing sentinel
_DOSAGE = np.array([2, 255, 1, 0], np.uint8)
MISSING_POLICIES = ("error", "zero", "drop")


def bed_paths(path: str) -> tuple:
    """Accept a fileset prefix or any of its member paths -> (bed, bim, fam)."""
    prefix = path[:-4] if path.endswith((".bed", ".bim", ".fam")) else path
    triple = tuple(prefix + ext for ext in (".bed", ".bim", ".fam"))
    missing = [p for p in triple if not os.path.exists(p)]
    if missing:
        raise ValueError(f"PLINK fileset {prefix!r} incomplete: missing {missing}")
    return triple


def _count_lines(path: str) -> int:
    with open(path, "rb") as f:
        return sum(1 for line in f if line.strip())


def read_bed(
    path: str, *, missing: str = "error", vectors: str = "snps"
) -> tuple:
    """Decode a PLINK fileset into a leveled dosage matrix.

    Returns ``(V, info)``: ``V`` is ``(n_f, n_v)`` uint8 with values in
    ``{0, 1, 2}`` (orientation per ``vectors``), ``info`` records
    ``n_snps`` / ``n_samples`` / ``n_missing`` / ``dropped_snps`` for the
    dataset manifest's provenance block.
    """
    if missing not in MISSING_POLICIES:
        raise ValueError(f"missing policy {missing!r} not in {MISSING_POLICIES}")
    if vectors not in ("snps", "samples"):
        raise ValueError(f"vectors must be 'snps' or 'samples', got {vectors!r}")
    bed, bim, fam = bed_paths(path)
    n_snps = _count_lines(bim)
    n_samples = _count_lines(fam)
    if not n_snps or not n_samples:
        raise ValueError(f"empty fileset: {n_snps} SNPs x {n_samples} samples")

    with open(bed, "rb") as f:
        header = f.read(3)
        if len(header) < 3:
            raise ValueError(f"{bed}: truncated header ({len(header)} bytes)")
        if header[:2] != BED_MAGIC:
            raise ValueError(f"{bed}: bad magic {header[:2]!r} (not a .bed file)")
        if header[2] != _MODE_SNP_MAJOR:
            raise ValueError(
                f"{bed}: individual-major mode (0x00) is unsupported — "
                f"re-export SNP-major (PLINK default since 1.07)"
            )
        raw = np.frombuffer(f.read(), np.uint8)
    nb = (n_samples + 3) // 4
    if raw.size != n_snps * nb:
        raise ValueError(
            f"{bed}: {raw.size} payload bytes, expected {n_snps} SNPs x "
            f"{nb} bytes (from {bim} / {fam} line counts)"
        )
    codes = (raw.reshape(n_snps, nb)[:, :, None] >> np.array([0, 2, 4, 6], np.uint8)) & 3
    G = _DOSAGE[codes.reshape(n_snps, 4 * nb)[:, :n_samples]]  # (n_snps, n_samples)

    miss = G == 255
    n_missing = int(miss.sum())
    dropped = 0
    if n_missing:
        if missing == "error":
            snp = int(np.argmax(miss.any(axis=1)))
            raise ValueError(
                f"{bed}: {n_missing} missing genotype(s), first at SNP row "
                f"{snp} — pass an explicit policy (missing='zero'|'drop')"
            )
        if missing == "zero":
            G = np.where(miss, np.uint8(0), G)
        else:  # drop SNPs containing any missing call
            keep = ~miss.any(axis=1)
            dropped = int((~keep).sum())
            G = G[keep]
    info = {
        "kind": "bed",
        "path": os.path.abspath(bed),
        "n_snps": n_snps,
        "n_samples": n_samples,
        "n_missing": n_missing,
        "dropped_snps": dropped,
        "missing_policy": missing,
        "vectors": vectors,
    }
    V = G.T if vectors == "snps" else G
    return np.ascontiguousarray(V), info
