"""Serving engines.

Two request families share this module:

* ``ServeEngine`` — batched LM generation: prefill + greedy/temperature
  decode with KV (or SSM-state) caches and per-sequence stopping.  The
  decode loop is a single jit'd step over the full batch (static shapes);
  finished sequences keep decoding into a scratch slot but their outputs
  are frozen — the standard static-batch serving pattern.

* ``SimilarityService`` — similarity campaigns as a service: frozen
  ``SimilarityRequest``s go through the SAME ``repro.api.SimilarityEngine``
  the CLI and benchmarks use (one code path to validate), with engine reuse
  across requests sharing a device pool and an LRU result cache keyed by
  (request, input fingerprint) so repeated campaigns are free.
"""
from __future__ import annotations

import contextvars
import hashlib
import queue
import threading
import time
from collections import OrderedDict
from concurrent.futures import Future
from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.models.common import ModelConfig
from repro.obs import trace as obs
from repro.obs.metrics import MetricsRegistry
from repro.parallel.sharding import use_mesh

#: Virtual Chrome-trace lane for queue-wait intervals: a wait often
#: overlaps the worker thread's own spans (it began while the previous
#: request was still computing), so it gets its own tid to keep per-lane
#: B/E nesting well-formed.
_QUEUE_LANE_TID = 0


@dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 -> greedy
    eos_id: int = 2
    seed: int = 0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig | None = None,
                 mesh=None, registry: MetricsRegistry | None = None):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg or ServeConfig()
        self.mesh = mesh
        self.registry = registry if registry is not None else MetricsRegistry()
        self._decode = jax.jit(
            lambda p, c, t, i: api.decode_step(cfg, p, c, t, i)
        )

    def _prefill(self, tokens):
        """Feed the prompt one block at a time through decode steps.

        For attention archs this fills the KV cache; a production prefill
        would batch the whole prompt (see launch/dryrun.py's prefill_step —
        the serving engine here favors simplicity on CPU)."""
        B, P = tokens.shape
        cache = api.init_cache(
            self.cfg, self.params, B, P + self.scfg.max_new_tokens
        )
        logits = None
        for i in range(P):
            logits, cache = self._decode(
                self.params, cache, tokens[:, i : i + 1], i
            )
        return logits, cache, P

    def generate(self, prompts: np.ndarray) -> np.ndarray:
        """prompts (B, P) int32 -> (B, max_new_tokens) int32.

        Records per-request metrics in ``self.registry``: ``requests`` /
        ``tokens_generated`` counters and ``prefill_seconds`` /
        ``decode_step_seconds`` latency histograms (``serve
        --metrics-json`` dumps the snapshot)."""
        scfg = self.scfg
        reg = self.registry
        with use_mesh(self.mesh):
            t0 = time.perf_counter()
            logits, cache, pos = self._prefill(jnp.asarray(prompts))
            B = prompts.shape[0]
            out = np.zeros((B, scfg.max_new_tokens), np.int32)
            done = np.zeros((B,), bool)
            key = jax.random.PRNGKey(scfg.seed)
            tok = self._sample(logits, key)
            reg.histogram("prefill_seconds").observe(time.perf_counter() - t0)
            steps = 0
            for t in range(scfg.max_new_tokens):
                ts = time.perf_counter()
                out[:, t] = np.where(done, 0, np.asarray(tok[:, 0]))
                done |= np.asarray(tok[:, 0]) == scfg.eos_id
                steps += 1
                if done.all():
                    reg.histogram("decode_step_seconds").observe(
                        time.perf_counter() - ts
                    )
                    break
                logits, cache = self._decode(self.params, cache, tok, pos + t)
                key, sub = jax.random.split(key)
                tok = self._sample(logits, sub)
                reg.histogram("decode_step_seconds").observe(
                    time.perf_counter() - ts
                )
        with reg.locked():
            reg.counter("requests").inc()
            reg.counter("tokens_generated").inc(B * steps)
        return out

    def _sample(self, logits, key):
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        scaled = logits[:, -1, :] / self.scfg.temperature
        return jax.random.categorical(key, scaled)[:, None].astype(jnp.int32)


def _payload_hash(V) -> str:
    """sha256 over a dense payload's bytes (module-level so tests can stub
    it to prove store-backed fingerprinting never reads the payload)."""
    from repro.kernels.mgemm_levels.planes import PackedPlanes

    h = hashlib.sha256()
    if isinstance(V, PackedPlanes):
        # pre-encoded payload without store provenance: key on the plane
        # bytes + true n_f (np.ascontiguousarray on the dataclass would
        # hash object pointers — unstable across materializations)
        h.update(f"planes:{V.n_f}".encode())
        V = V.planes
    a = np.ascontiguousarray(V)
    h.update(str(a.shape).encode())
    h.update(str(a.dtype).encode())
    h.update(a.tobytes())
    return "payload:" + h.hexdigest()


_STOP = object()


class SimilarityService:
    """Similarity campaigns behind an async serving front-end.

    Every request is executed by ``repro.api.SimilarityEngine`` — the exact
    code path of the CLI and benchmarks — so serving never drifts from the
    validated engines.  ``submit_async`` enqueues the campaign to a worker
    thread pool and returns a ``concurrent.futures.Future``; ``submit`` is
    the blocking wrapper.  Results are LRU-cached by (normalized request,
    payload identity): duplicate submissions — cached OR still in flight —
    share one compute and one result object.

    Payload identity never touches payload bytes for store-backed inputs:
    a ``source="planes"`` request (or a handle carrying store provenance)
    is keyed by the manifest's dataset checksum + ``campaign_key()``, so
    fingerprinting a terabyte mmap'd dataset costs one JSON read.  Raw
    arrays fall back to hashing via ``_payload_hash``.

    Delta awareness: when a store-backed 2-way request arrives for a
    dataset whose manifest records a ``parent`` block, and the parent's
    result is still cached under the same request identity, the service
    schedules ONLY the border blocks (``SimilarityEngine.run_delta``) and
    merges into the cached prior — bit-identical to the full recompute,
    counted in ``delta_hits``.

    ``warmup`` compiles a request's programs on an all-zeros payload of
    identical geometry (manifest dims only for store inputs — no shard
    read) without polluting the cache or hit/miss counters; the
    compiled-program cache in ``repro.core`` then serves the real
    submission.

    Counters live in a private ``repro.obs`` ``MetricsRegistry`` and
    update atomically per transition, so ``stats()``/``metrics()``
    snapshots taken at ANY instant satisfy

        hits + misses + in_flight == submitted

    (``submitted``/``hits`` count at submission; a fresh request sits in
    ``in_flight`` until its worker finishes, and only then becomes a
    ``miss`` — success or error alike, errors also counted in
    ``errors``).  ``metrics()`` adds queue depth and the wait-vs-compute
    latency split.
    """

    def __init__(self, max_cached_results: int = 16, devices=None,
                 workers: int = 1):
        from repro.api import SimilarityEngine

        self.engine = SimilarityEngine(devices=devices)
        self.max_cached_results = max_cached_results
        self._results = OrderedDict()
        self._inflight = {}
        self._lock = threading.Lock()
        self._queue = queue.Queue()
        self._closed = False
        self.registry = MetricsRegistry()
        self._c_submitted = self.registry.counter("submitted")
        self._c_hits = self.registry.counter("hits")
        self._c_misses = self.registry.counter("misses")
        self._c_delta_hits = self.registry.counter("delta_hits")
        self._c_warmups = self.registry.counter("warmups")
        self._c_errors = self.registry.counter("errors")
        self._g_in_flight = self.registry.gauge("in_flight")
        self._g_queue_depth = self.registry.gauge("queue_depth")
        self._h_wait = self.registry.histogram("queue_wait_seconds")
        self._h_compute = self.registry.histogram("compute_seconds")
        if not (isinstance(workers, int) and workers >= 1):
            raise ValueError(f"workers must be a positive int, got {workers!r}")
        self._threads = [
            threading.Thread(
                target=self._worker, name=f"similarity-worker-{i}", daemon=True
            )
            for i in range(workers)
        ]
        for t in self._threads:
            t.start()

    # Counter attributes kept as read-only views onto the registry, so
    # existing callers (`svc.hits` etc.) keep working.
    @property
    def hits(self) -> int:
        return self._c_hits.value

    @property
    def misses(self) -> int:
        return self._c_misses.value

    @property
    def delta_hits(self) -> int:
        return self._c_delta_hits.value

    @property
    def warmups(self) -> int:
        return self._c_warmups.value

    # -- identity ----------------------------------------------------------

    @staticmethod
    def _request_key(request) -> tuple:
        """Hashable campaign identity of the request itself.

        The campaign key — metric name(s) + subset (name, indices) pairs —
        is part of the identity: two requests over the same payload and
        decomposition that differ only in which campaigns they batch are
        DIFFERENT answers.  ``subsets`` is normalized first so equivalent
        spellings (list indices, numpy ints) are cache-equal.  ``input``
        and ``delta_from`` are excluded — the payload is keyed separately
        (below), which is what lets a parent dataset's cached result be
        found when an appended child arrives."""
        if request.subsets:
            request = replace(request, subsets=request.campaign_subsets())
        request = replace(request, input=None, delta_from="")
        return (request, request.campaign_key())

    def _fingerprint(self, request, V) -> tuple:
        """-> ((request key, payload key), V) — V materialized only when
        payload bytes are genuinely needed for identity."""
        rkey = self._request_key(request)
        if V is None and request.input is not None:
            if request.input.source == "planes":
                from repro.store.format import read_manifest

                # manifest-only read: V stays None so the engine opens the
                # dataset itself (and can stream / record provenance)
                ck = read_manifest(request.input.path)["checksum"]
                return (rkey, ("dataset", ck)), None
            V = request.input.materialize()
        if V is None:
            return (rkey, None), None
        ck = (getattr(V, "origin", None) or {}).get("checksum")
        if ck:
            # store-provenance handle (PackedPlanes / ShardedPlanes): the
            # dataset checksum IS the payload identity — no byte hashing
            return (rkey, ("dataset", ck)), V
        return (rkey, ("payload", _payload_hash(V))), V

    # -- submission --------------------------------------------------------

    def submit_async(self, request, V=None) -> Future:
        """Enqueue one campaign; -> Future resolving to the result (a
        ``SimilarityResult``, or ``BatchedSimilarityResult`` for batched
        requests).  Duplicate submissions — cached or in flight — share one
        compute; engine errors propagate through the future."""
        key, V = self._fingerprint(request, V)
        with self._lock:
            if self._closed:
                raise RuntimeError("SimilarityService is shut down")
            cached = self._results.get(key)
            if cached is not None:
                with self.registry.locked():
                    self._c_submitted.inc()
                    self._c_hits.inc()
                self._results.move_to_end(key)
                fut = Future()
                fut.set_result(cached)
                return fut
            fut = self._inflight.get(key)
            if fut is not None:
                with self.registry.locked():
                    self._c_submitted.inc()
                    self._c_hits.inc()
                return fut
            fut = Future()
            self._inflight[key] = fut
            with self.registry.locked():
                self._c_submitted.inc()
                self._g_in_flight.inc()
                self._g_queue_depth.inc()
        # Carry the submitter's open-span stack to the worker (tracing
        # only) so the campaign's serve-compute span nests under it.
        ctx = contextvars.copy_context() if obs.enabled() else None
        self._queue.put((key, request, V, fut, time.perf_counter(), ctx))
        return fut

    def submit(self, request, V=None):
        """Blocking wrapper: run (or serve from cache) one campaign."""
        return self.submit_async(request, V).result()

    def _worker(self):
        while True:
            item = self._queue.get()
            if item is _STOP:
                break
            key, request, V, fut, t_enq, ctx = item
            t_start = time.perf_counter()
            wait = t_start - t_enq
            self._g_queue_depth.dec()
            self._h_wait.observe(wait)
            tracer = obs.get_tracer()
            if tracer is not None:
                # perf_counter and perf_counter_ns share a clock base, so
                # the enqueue timestamp converts directly
                now = tracer._clock()
                tracer.complete(
                    "serve-queue-wait", now - int(wait * 1e9), now,
                    {"wait_seconds": wait}, tid=_QUEUE_LANE_TID,
                )
            try:
                if ctx is not None:
                    result = ctx.run(self._traced_execute, key, request, V)
                else:
                    result = self._traced_execute(key, request, V)
            except BaseException as e:
                with self._lock:
                    self._inflight.pop(key, None)
                    with self.registry.locked():
                        self._g_in_flight.dec()
                        self._c_misses.inc()
                        self._c_errors.inc()
                self._h_compute.observe(time.perf_counter() - t_start)
                fut.set_exception(e)
                continue
            with self._lock:
                self._results[key] = result
                self._results.move_to_end(key)
                while len(self._results) > self.max_cached_results:
                    self._results.popitem(last=False)
                self._inflight.pop(key, None)
                with self.registry.locked():
                    self._g_in_flight.dec()
                    self._c_misses.inc()
            self._h_compute.observe(time.perf_counter() - t_start)
            fut.set_result(result)

    def _traced_execute(self, key, request, V):
        with obs.span("serve-compute"):
            return self._execute(key, request, V)

    def _execute(self, key, request, V):
        rkey, pkey = key
        if (
            request.way == 2
            and not request.is_batched
            and not request.delta_from
            and isinstance(pkey, tuple)
            and pkey[0] == "dataset"
        ):
            prior = None
            parent_ck = self._parent_checksum(request, V)
            if parent_ck:
                with self._lock:
                    prior = self._results.get((rkey, ("dataset", parent_ck)))
            if prior is not None:
                self._c_delta_hits.inc()
                return self.engine.run_delta(request, prior, V)
        return self.engine.run(request, V)

    @staticmethod
    def _parent_checksum(request, V):
        parent = (getattr(V, "origin", None) or {}).get("parent")
        if parent is None and request.input is not None \
                and request.input.source == "planes":
            from repro.store.format import read_manifest

            parent = read_manifest(request.input.path).get("parent")
        return parent["checksum"] if parent else None

    # -- warmup ------------------------------------------------------------

    def warmup(self, request, V=None) -> float:
        """Compile the request's programs on an all-zeros payload of
        identical geometry; -> seconds spent.  Nothing is cached and
        hit/miss counters are untouched.  Store-backed requests build the
        zeros payload from manifest dims alone (no shard read); zero
        denominators are safe (``safe_denom``)."""
        from repro.kernels.mgemm_levels.planes import PackedPlanes

        request = replace(request, delta_from="")
        if V is None and request.input is not None:
            if request.input.source == "planes":
                from repro.store.format import read_manifest

                m = read_manifest(request.input.path)
                V = PackedPlanes(
                    np.zeros((m["levels"], m["kb"], m["n_v"]), np.uint8),
                    n_f=m["n_f"],
                )
            else:
                V = request.input.materialize()
        if V is None:
            raise ValueError("warmup needs a payload or request.input")
        if isinstance(V, PackedPlanes):
            V = PackedPlanes(np.zeros_like(V.planes), n_f=V.n_f)
        else:
            V = np.zeros_like(np.asarray(V))
        t0 = time.perf_counter()
        self.engine.run(replace(request, input=None, streaming="off"), V)
        self._c_warmups.inc()
        return time.perf_counter() - t0

    # -- lifecycle ---------------------------------------------------------

    def shutdown(self, wait: bool = True):
        """Stop accepting submissions and stop the workers.  Campaigns
        already queued still run (their futures resolve) — the stop
        sentinels sit behind them in the queue."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        for _ in self._threads:
            self._queue.put(_STOP)
        if wait:
            for t in self._threads:
                t.join()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown(wait=True)
        return False

    def stats(self) -> dict:
        """One consistent counter snapshot (every value read under the
        same locks, so ``hits + misses + in_flight == submitted`` holds in
        any snapshot, even mid-flight)."""
        with self._lock, self.registry.locked():
            return {
                "hits": self._c_hits.snapshot(),
                "misses": self._c_misses.snapshot(),
                "cached_results": len(self._results),
                "delta_hits": self._c_delta_hits.snapshot(),
                "in_flight": int(self._g_in_flight.snapshot()),
                "submitted": self._c_submitted.snapshot(),
                "warmups": self._c_warmups.snapshot(),
                "errors": self._c_errors.snapshot(),
            }

    def metrics(self) -> dict:
        """Full registry snapshot — ``stats()``'s counters plus queue
        depth and the wait-vs-compute latency histograms — taken under one
        lock."""
        with self._lock, self.registry.locked():
            snap = self.registry.snapshot()
            snap["in_flight"] = int(snap["in_flight"])
            snap["queue_depth"] = int(snap["queue_depth"])
            snap["cached_results"] = len(self._results)
            return snap
