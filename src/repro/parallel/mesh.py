"""Mesh construction helpers.

Two mesh families:

* **CoMet meshes** — axes ("pf", "pv", "pr") matching the paper's three
  parallelism axes (vector elements / vector number / round-robin).  The ring
  runs over "pv"; devices are ordered so that consecutive "pv" coordinates are
  ICI neighbours on a TPU torus (the paper needed a *random* rank permutation
  to dodge Cray Gemini throttling — on a torus the ring maps natively).

* **Production LM meshes** — built in ``repro.launch.mesh`` per the dry-run
  contract: (16, 16) -> ("data", "model") and (2, 16, 16) ->
  ("pod", "data", "model").

``comet_mesh_from_production`` reinterprets a production mesh's device array
for the similarity engine so the same launcher serves both workload families:
"pv" <- data (x pod), and "model" splits into pf x pr.
"""
from __future__ import annotations

import numpy as np
import jax
from jax.sharding import Mesh

__all__ = ["make_comet_mesh", "comet_mesh_from_production"]

COMET_AXES = ("pf", "pv", "pr")


def make_comet_mesh(n_pf: int = 1, n_pv: int = 1, n_pr: int = 1, devices=None) -> Mesh:
    devices = list(jax.devices()) if devices is None else list(devices)
    need = n_pf * n_pv * n_pr
    if len(devices) < need:
        raise ValueError(f"need {need} devices, have {len(devices)}")
    arr = np.array(devices[:need]).reshape(n_pf, n_pv, n_pr)
    return Mesh(arr, COMET_AXES)


def comet_mesh_from_production(mesh: Mesh, n_pf: int = 1) -> Mesh:
    """Reshape a ("data","model") or ("pod","data","model") mesh into the
    comet ("pf","pv","pr") axes: pv <- (pod x) data, model splits pf x pr."""
    devs = mesh.devices  # (data, model) or (pod, data, model)
    if devs.ndim == 3:
        devs = devs.reshape(-1, devs.shape[-1])  # fold pod into data
    n_pv, n_model = devs.shape
    assert n_model % n_pf == 0, (n_model, n_pf)
    n_pr = n_model // n_pf
    arr = devs.reshape(n_pv, n_pf, n_pr).transpose(1, 0, 2)
    return Mesh(arr, COMET_AXES)
