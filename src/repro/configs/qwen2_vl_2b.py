"""qwen2-vl-2b [vlm] — arXiv:2409.12191 (hf-verified).

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936 — M-RoPE, dynamic
resolution.  The vision frontend is a STUB per the assignment: input_specs()
provides precomputed patch embeddings; the backbone consumes them through
``embeds=`` with 3-component M-RoPE position ids.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1e6,
    mrope_sections=(16, 24, 24),
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="qwen2-vl-2b-smoke",
    n_layers=2,
    d_model=48,
    n_heads=3,
    n_kv_heads=1,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    mrope_sections=(2, 3, 3),
)
