"""jit'd wrappers + impl registration for the MXU level-decomposition path."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.mgemm import register_impl

from .kernel import (
    metric2_levels_pallas,
    metric2_levels_tri_pallas,
    mgemm_levels_pallas,
)
from .planes import decode_bitplanes


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def mgemm_levels(A, B, *, levels: int = 2, **kw):
    kw.setdefault("interpret", not _on_tpu())
    return mgemm_levels_pallas(A, B, levels=levels, **kw)


def mgemm_levels_xla(A, B, *, levels: int = 2, out_dtype=jnp.float32):
    """XLA (non-Pallas) realization — what the distributed engines call on
    CPU, and what the dry-run lowers on the v5e mesh (plain dots partition
    cleanly under GSPMD)."""
    acc = jnp.zeros((A.shape[0], B.shape[1]), jnp.float32)
    for t in range(1, levels + 1):
        at = (A >= t).astype(jnp.bfloat16)
        bt = (B >= t).astype(jnp.bfloat16)
        acc += jnp.dot(at, bt, preferred_element_type=jnp.float32)
    return acc.astype(out_dtype)


# -- packed bit-plane entry points (planes built once, not per call) --------


def metric2_levels(Pa, Pb, sa, sb, *, epilogue, **kw):
    """Fused metric kernel on pre-encoded packed planes (rectangular grid)."""
    kw.setdefault("interpret", not _on_tpu())
    return metric2_levels_pallas(Pa, Pb, sa, sb, epilogue=epilogue, **kw)


def metric2_levels_tri(P, s, *, epilogue, **kw):
    """Fused diagonal-block plane kernel (triangular tile schedule)."""
    kw.setdefault("interpret", not _on_tpu())
    return metric2_levels_tri_pallas(P, s, epilogue=epilogue, **kw)


def mgemm_levels_planes(Pa, Pb, **kw):
    """Plane-contraction-only MXU kernel: the unfused numerator when the
    reduction is split over ranks (``n_pf > 1``) and the epilogue must wait
    for the psum."""
    kw.setdefault("interpret", not _on_tpu())
    za = jnp.zeros((Pa.shape[2],), jnp.float32)
    zb = jnp.zeros((Pb.shape[2],), jnp.float32)
    return metric2_levels_pallas(Pa, Pb, za, zb, epilogue=None, **kw)


@functools.partial(jax.jit, static_argnames=("out_dtype",))
def mgemm_levels_planes_xla(Pa, Pb, *, out_dtype=jnp.float32):
    """XLA plane contraction: unpack once, then ``levels`` plain MXU/CPU
    dots.  The hoisted form of ``mgemm_levels_xla`` — comparisons against
    fp32 data are gone from the hot loop entirely.  The A-side planes are
    transposed to row-major before the dots (a one-off (L, K, m) shuffle);
    contracting the leading axis directly lowers ~4x slower on CPU."""
    at = decode_bitplanes(Pa).astype(jnp.bfloat16).transpose(0, 2, 1)
    bt = decode_bitplanes(Pb).astype(jnp.bfloat16)  # (levels, K, n)
    acc = jnp.zeros((Pa.shape[2], Pb.shape[2]), jnp.float32)
    for t in range(Pa.shape[0]):
        acc += jnp.dot(at[t], bt[t], preferred_element_type=jnp.float32)
    return acc.astype(out_dtype)


register_impl("levels", mgemm_levels)
register_impl("levels_xla", mgemm_levels_xla)
