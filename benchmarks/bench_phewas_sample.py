"""Paper Table 5: realistic PheWAS sample problem.

The paper's real dataset: n_v=189,625 poplar SNP profile vectors of length
n_f=385 — short vectors make the mGEMM much less efficient than the
synthetic n_f=20,000 case (125e9 vs 415e9 cmp/s/node).  Scaled-down
reproduction: same n_f contrast at CPU-sized n_v, plus the 1-byte metric
output mode (paper §6.8 writes u8 metrics).
"""
from __future__ import annotations


from benchmarks.util import row, time_fn
from repro.core.mgemm import mgemm_xla
from repro.core.synthetic import random_integer_vectors

N_V = 1536


def main():
    import jax.numpy as jnp

    rows = []
    rates = {}
    for n_f in (385, 20000 // 4):
        V = jnp.asarray(random_integer_vectors(n_f, N_V, max_value=2, seed=0))
        t = time_fn(lambda v: mgemm_xla(v.T, v), V)
        comps = n_f * N_V * N_V
        rates[n_f] = comps / t
        rows.append(row(f"table5/2way_nf{n_f}", t, f"{comps / t:.3e}_cmp/s"))
    rows.append(
        row("table5/short_vector_penalty", 0.0,
            f"rate_ratio={rates[20000 // 4] / rates[385]:.2f}x_long_vs_short")
    )
    # u8 metric output (paper stores ~2.5 significant figures per metric)
    V = jnp.asarray(random_integer_vectors(385, N_V, max_value=2, seed=1))

    def with_u8_output(v):
        n2 = mgemm_xla(v.T, v)
        s = v.sum(axis=0)
        c2 = 2.0 * n2 / (s[:, None] + s[None, :])
        return (c2 * 255.0 + 0.5).astype(jnp.uint8)

    t = time_fn(with_u8_output, V)
    rows.append(row("table5/u8_metric_output", t,
                    f"bytes_per_metric=1_vs_4_fp32"))
    return rows


if __name__ == "__main__":
    from benchmarks.util import print_rows

    print_rows(main())
