"""Similarity-campaign launcher: the paper's workload as a CLI over the
unified ``repro.api`` engine.

    python -m repro.launch.similarity --way 2 --n-f 1000 --n-v 512 \
        --n-pv 4 --n-pr 2 --devices 8 --metric czekanowski --out /tmp/metrics

Builds a ``SimilarityRequest`` (any registered metric; 2-way or staged
3-way), runs it through ``SimilarityEngine``, writes the result's block
manifest with the exact checksum (paper §5), and prints throughput in
elementwise comparisons/second (the paper's headline metric).
"""
import argparse
import os
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--metric", default="czekanowski",
                    help="registered metric name (see --list-metrics)")
    ap.add_argument("--list-metrics", action="store_true")
    ap.add_argument("--way", type=int, default=2, choices=(2, 3))
    ap.add_argument("--n-f", type=int, default=512)
    ap.add_argument("--n-v", type=int, default=240)
    ap.add_argument("--n-pf", type=int, default=1)
    ap.add_argument("--n-pv", type=int, default=1)
    ap.add_argument("--n-pr", type=int, default=1)
    ap.add_argument("--n-st", type=int, default=1)
    ap.add_argument("--stage", type=int, default=0,
                    help="3-way stage to run; -1 runs all n_st stages")
    ap.add_argument("--devices", type=int, default=0,
                    help="force host device count (set before jax init)")
    ap.add_argument("--impl", default=None,
                    help="mgemm implementation (default: xla, or levels "
                         "when --dataset is given)")
    ap.add_argument("--levels", type=int, default=None,
                    help="level count for impl='levels*' (default: 2, or "
                         "the dataset's encoded levels with --dataset)")
    ap.add_argument("--out-dtype", default="float32",
                    help="metric output dtype (e.g. float32, bfloat16)")
    ap.add_argument("--ring-dtype", default="auto",
                    help="ring payload dtype; 'auto' picks int8 for "
                         "small-integer data (4x less ICI traffic), "
                         "'float32' opts out")
    ap.add_argument("--encoding", default="auto",
                    choices=("auto", "bitplane", "none"),
                    help="bit-plane pre-encoding for the levels path: "
                         "encode V once into packed uint8 planes and "
                         "ring-carry those (up to 16x less wire for SNP "
                         "{0,1,2} data)")
    ap.add_argument("--streaming", default="auto",
                    choices=("auto", "on", "off"),
                    help="out-of-core streaming over a --dataset: 'auto' "
                         "streams multi-shard (or --max-host-bytes budgeted) "
                         "datasets chunk by chunk with double-buffered "
                         "prefetch, 'on' requires a dataset, 'off' always "
                         "materializes in memory; results are bit-identical "
                         "either way")
    ap.add_argument("--max-host-bytes", type=int, default=0,
                    help="staging-buffer budget in bytes for the streamed "
                         "pipeline (0 = one disk shard per chunk)")
    ap.add_argument("--dry-run", action="store_true",
                    help="print the resolved execution path (fused-popcount "
                         "/ fused-levels / streamed-fused-* / fused-vpu / "
                         "unfused + reason), encoding, ring dtype and the "
                         "streaming decision, then exit without running the "
                         "campaign")
    ap.add_argument("--chunk", type=int, default=128,
                    help="XLA mgemm contraction-chunk size")
    ap.add_argument("--input", default="", help=".npy (n_f, n_v) input")
    ap.add_argument("--dataset", default="",
                    help="packed bit-plane dataset directory (repro.store): "
                         "the campaign loads pre-encoded planes and never "
                         "runs the host encoder")
    ap.add_argument("--max-value", type=int, default=15)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", "")
        )
    from repro.api import (
        InputSpec,
        SimilarityEngine,
        SimilarityRequest,
        available_metrics,
    )

    if args.list_metrics:
        for name in available_metrics():
            print(name)
        return 0

    if args.dataset and args.input:
        print("error: --input and --dataset are mutually exclusive",
              file=sys.stderr)
        return 2
    impl = args.impl or ("levels" if args.dataset else "xla")
    levels = args.levels
    if args.dataset:
        # pre-encoded campaign: the store's planes feed the engines directly
        from repro.store import read_manifest

        try:
            manifest = read_manifest(args.dataset)
        except ValueError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
        if levels is None:
            levels = manifest["levels"]
        input_spec = InputSpec(source="planes", path=args.dataset)
    elif args.input:
        input_spec = InputSpec(source="npy", path=args.input)
    else:
        input_spec = InputSpec(
            source="synthetic", n_f=args.n_f, n_v=args.n_v,
            max_value=args.max_value, seed=args.seed,
        )
    if levels is None:
        levels = 2
    stages = None if (args.way == 3 and args.stage < 0) else (
        (args.stage,) if args.way == 3 else None
    )
    request = SimilarityRequest(
        metric=args.metric, way=args.way,
        n_pf=args.n_pf, n_pv=args.n_pv, n_pr=args.n_pr, n_st=args.n_st,
        stages=stages, impl=impl, levels=levels,
        out_dtype=args.out_dtype, ring_dtype=args.ring_dtype,
        encoding=args.encoding, chunk=args.chunk,
        streaming=args.streaming, max_host_bytes=args.max_host_bytes,
        input=input_spec,
    )
    from repro.api import UnknownMetricError

    if args.dry_run:
        # surface the executor's chosen path so silent fallbacks (e.g. a
        # fused request declined because n_pf > 1) become visible
        import jax.numpy as jnp

        from repro.api.registry import get_metric
        from repro.core.tile_executor import TileExecutor
        from repro.core.twoway import resolve_config

        try:
            spec = get_metric(args.metric)
            request.validate(metric_spec=spec)
            if (request.input.source == "planes"
                    and request.streaming != "off"):
                # lazy handle: the streaming decision resolves without
                # reading a payload byte
                from repro.store import DatasetReader

                probe = DatasetReader(request.input.path).sharded()
            else:
                probe = request.input.materialize()
            cfg = resolve_config(request.to_comet_config(), probe, spec)
        except (UnknownMetricError, ValueError) as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
        ex = TileExecutor(cfg=cfg, metric=spec,
                          out_dtype=jnp.dtype(args.out_dtype), axis=None,
                          deferred=(cfg.streaming == "on"))
        path, why = ((ex.path, ex.path_reason) if args.way == 2
                     else (ex.path3, ex.path3_reason))
        reason = f" ({why})" if why else ""
        # with encoding=bitplane BOTH engines pre-encode once and ring-carry
        # the packed planes (3-way: path3 == "fused-levels-ring"); with
        # streaming=on the streamed-* chunk paths + merge epilogue run
        print(f"path={path}{reason}")
        print(f"encoding={cfg.encoding} ring_dtype={cfg.ring_dtype} "
              f"impl={cfg.impl} levels={cfg.levels}")
        print(f"streaming={cfg.streaming} "
              f"max_host_bytes={cfg.max_host_bytes}")
        return 0

    try:
        result = SimilarityEngine().run(request)
    except (UnknownMetricError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    n_results = result.num_results()
    comparisons = n_results * result.n_f
    checksum = result.checksum()
    print(f"metric={result.metric} way={result.way} "
          f"n_f={result.n_f} n_v={result.n_v} "
          f"decomp=({args.n_pf},{args.n_pv},{args.n_pr}) "
          f"stages={list(result.stages)}")
    print(f"results={n_results} time={result.seconds:.3f}s "
          f"rate={comparisons / max(result.seconds, 1e-12):.3e} comparisons/s")
    stream = result.meta.get("stream")
    if stream:
        print(f"streamed chunks={stream['chunks']} "
              f"chunk_bytes={stream['chunk_bytes']} "
              f"peak_host_bytes={stream['peak_host_bytes']} "
              f"n_shards={stream['n_shards']}")
    print(f"checksum={hex(checksum)}")
    if args.out:
        result.save(args.out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
