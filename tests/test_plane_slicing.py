"""Byte-aligned plane slicing: the contract the 3-way plane ring rests on.

The packed plane layout (docs/BITPLANE_FORMAT.md) packs bits along the
FIELD axis only, so two slicing operations are exact by construction and
the distributed engines rely on both:

1. vector-axis slices commute with encoding —
   ``encode(V)[:, :, a:b] == encode(V[:, a:b])`` — which is why 3-way
   pipeline slices are plain byte-range views of the ring payload
   (``slice_planes_vectors``), with no per-slice re-encode;
2. whole-byte slices along the byte axis select the corresponding field
   range — which is why the ring payload's byte axis can shard over "pf"
   (``shard_planes_fields``): shard r's plane GEMM partials equal those of
   fields ``[8*r*kb/n_pf, 8*(r+1)*kb/n_pf)``.

Covered with deterministic cases everywhere and hypothesis when installed
(CI installs it; the container may not), including non-multiple-of-8 field
counts and pf > 1 shard counts.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.mgemm_levels import (
    encode_bitplanes_np,
    mgemm_levels_planes_xla,
    shard_planes_fields,
    slice_planes_vectors,
    values_from_planes,
)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


def _vectors(k, n, levels, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, levels + 1, (k, n)).astype(np.float32)


# -- 1. vector-axis slicing == encode-of-slice ------------------------------


def _check_vector_slice(k, n, levels, a, count, seed):
    V = _vectors(k, n, levels, seed)
    P = encode_bitplanes_np(V, levels)
    # numpy view slice
    assert (P[:, :, a:a + count] == encode_bitplanes_np(V[:, a:a + count],
                                                        levels)).all()
    # the jit-composable helper the 3-way engine slices pipelines with
    got = np.asarray(slice_planes_vectors(jnp.asarray(P), a, count))
    assert (got == encode_bitplanes_np(V[:, a:a + count], levels)).all()


@pytest.mark.parametrize(
    "k,n,levels,a,count,seed",
    [
        (8, 6, 2, 0, 6, 0),     # full width
        (7, 9, 2, 2, 4, 1),     # non-multiple-of-8 fields
        (13, 12, 3, 5, 3, 2),
        (1, 4, 1, 1, 2, 3),     # single field
        (40, 24, 5, 17, 6, 4),  # interior slice, many levels
        (33, 10, 4, 9, 1, 5),   # single-column slice (L=1 pipeline)
    ],
)
def test_vector_slice_equals_encode_of_slice_cases(k, n, levels, a, count, seed):
    _check_vector_slice(k, n, levels, a, count, seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(
        k=st.integers(1, 40),
        n=st.integers(2, 16),
        levels=st.integers(1, 5),
        data=st.data(),
    )
    def test_vector_slice_equals_encode_of_slice_property(k, n, levels, data):
        a = data.draw(st.integers(0, n - 1))
        count = data.draw(st.integers(1, n - a))
        seed = data.draw(st.integers(0, 2**31 - 1))
        _check_vector_slice(k, n, levels, a, count, seed)


# -- 2. byte-axis shards == encode of the field range -----------------------


def _check_field_shards(k, n, levels, n_pf, seed):
    V = _vectors(k, n, levels, seed)
    P = encode_bitplanes_np(V, levels, field_align=n_pf)
    kb = P.shape[1]
    assert kb % n_pf == 0
    fields_per_shard = 8 * kb // n_pf
    Vpad = np.pad(V, ((0, 8 * kb - k), (0, 0)))
    for r in range(n_pf):
        shard = np.asarray(shard_planes_fields(P, r, n_pf))
        fr = Vpad[r * fields_per_shard:(r + 1) * fields_per_shard]
        assert (shard == encode_bitplanes_np(fr, levels)).all(), r
    # the sharded plane-GEMM partials sum to the unsharded numerator —
    # the "pf" psum contract of the distributed engines
    full = np.asarray(mgemm_levels_planes_xla(jnp.asarray(P), jnp.asarray(P)))
    parts = sum(
        np.asarray(mgemm_levels_planes_xla(
            jnp.asarray(shard_planes_fields(P, r, n_pf)),
            jnp.asarray(shard_planes_fields(P, r, n_pf)),
        ))
        for r in range(n_pf)
    )
    assert (parts == full).all()


@pytest.mark.parametrize(
    "k,n,levels,n_pf,seed",
    [
        (16, 5, 2, 2, 0),   # bytes split exactly
        (13, 6, 2, 2, 1),   # non-multiple-of-8 fields, pad bytes in shard 1
        (7, 4, 3, 4, 2),    # fewer fields than 8*n_pf: pad-only shards
        (40, 8, 2, 4, 3),
        (21, 3, 1, 3, 4),   # odd shard count
    ],
)
def test_field_shards_equal_encode_of_field_ranges_cases(k, n, levels, n_pf, seed):
    _check_field_shards(k, n, levels, n_pf, seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(
        k=st.integers(1, 40),
        n=st.integers(1, 10),
        levels=st.integers(1, 4),
        n_pf=st.integers(1, 4),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_field_shards_equal_encode_of_field_ranges_property(
        k, n, levels, n_pf, seed
    ):
        _check_field_shards(k, n, levels, n_pf, seed)


def test_shard_planes_fields_rejects_uneven_split():
    P = encode_bitplanes_np(np.ones((8, 2)), 1)  # kb=1
    with pytest.raises(ValueError, match="field_align"):
        shard_planes_fields(P, 0, 2)


def test_sliced_stats_match_value_slice():
    """Stats computed from a plane slice equal stats of the sliced values
    (what the 3-way engine's per-slice denominators depend on)."""
    V = _vectors(19, 10, 2, seed=6)
    P = jnp.asarray(encode_bitplanes_np(V, 2))
    sub = slice_planes_vectors(P, 3, 4)
    got = np.asarray(values_from_planes(sub)).sum(axis=0)
    assert (got == V[:, 3:7].sum(axis=0)).all()
