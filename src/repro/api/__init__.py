"""repro.api — the unified similarity API.

One entry point for every similarity campaign (CLI, benchmarks, examples,
serving): build a frozen ``SimilarityRequest``, hand it to a
``SimilarityEngine``, stream the ``SimilarityResult``.  New metrics plug in
through ``register_metric`` without touching engine code.
"""
from repro.api.batch import BatchedSimilarityResult  # noqa: F401
from repro.api.engine import SimilarityEngine  # noqa: F401
from repro.api.registry import (  # noqa: F401
    CCC,
    SORENSON,
    MetricSpec,
    UnknownMetricError,
    available_metrics,
    batch_lead,
    family_key,
    get_metric,
    group_families,
    plane_native,
    register_metric,
)
from repro.api.request import InputSpec, SimilarityRequest  # noqa: F401
from repro.api.result import SimilarityResult, Tile  # noqa: F401

__all__ = [
    "SimilarityEngine",
    "SimilarityRequest",
    "InputSpec",
    "SimilarityResult",
    "BatchedSimilarityResult",
    "Tile",
    "MetricSpec",
    "UnknownMetricError",
    "register_metric",
    "get_metric",
    "available_metrics",
    "family_key",
    "group_families",
    "plane_native",
    "batch_lead",
    "CCC",
    "SORENSON",
]
