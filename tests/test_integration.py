"""End-to-end integration tests: launchers, dedup stage, elastic restore."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

HERE = os.path.dirname(os.path.abspath(__file__))
SRC = os.path.join(os.path.dirname(HERE), "src")


def _run(args, timeout=300):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, "-m", *args], capture_output=True,
                          text=True, env=env, timeout=timeout)


@pytest.mark.slow
def test_similarity_cli_roundtrip(tmp_path):
    """The campaign launcher writes blocks + manifest with an exact checksum
    that is invariant to the decomposition (run twice, different decomps)."""
    out1 = str(tmp_path / "a")
    out2 = str(tmp_path / "b")
    r1 = _run(["repro.launch.similarity", "--way", "2", "--n-f", "64",
               "--n-v", "48", "--out", out1])
    assert r1.returncode == 0, r1.stderr[-1500:]
    r2 = _run(["repro.launch.similarity", "--way", "2", "--n-f", "64",
               "--n-v", "48", "--n-pv", "4", "--devices", "4", "--out", out2])
    assert r2.returncode == 0, r2.stderr[-1500:]
    m1 = json.load(open(os.path.join(out1, "manifest.json")))
    m2 = json.load(open(os.path.join(out2, "manifest.json")))
    assert m1["checksum"] == m2["checksum"]
    assert m1["results"] == 48 * 47 // 2 == m2["results"]


@pytest.mark.slow
def test_train_launcher_resumes(tmp_path):
    ckpt = str(tmp_path / "ck")
    r1 = _run(["repro.launch.train", "--arch", "qwen1.5-0.5b", "--smoke",
               "--steps", "4", "--batch", "2", "--seq-len", "16",
               "--ckpt-every", "2", "--ckpt-dir", ckpt])
    assert r1.returncode == 0, r1.stderr[-1500:]
    r2 = _run(["repro.launch.train", "--arch", "qwen1.5-0.5b", "--smoke",
               "--steps", "6", "--batch", "2", "--seq-len", "16",
               "--ckpt-every", "2", "--ckpt-dir", ckpt])
    assert r2.returncode == 0, r2.stderr[-1500:]
    assert "resume_step=4" in r2.stdout


def test_dedup_finds_planted_duplicates():
    from repro.data.dedup import find_near_duplicates

    rng = np.random.default_rng(1)
    docs = [rng.integers(0, 5000, 300) for _ in range(20)]
    dup = docs[3].copy()
    dup[:20] = rng.integers(0, 5000, 20)
    docs.append(dup)
    hits = find_near_duplicates(docs, 5000, threshold=0.85)
    assert any({i, j} == {3, 20} for i, j, _ in hits)


def test_elastic_restore_with_shardings(tmp_path):
    """Checkpoint saved without a mesh restores onto an explicit sharding
    (the elastic/topology-change path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.checkpoint.ckpt import CheckpointManager

    m = CheckpointManager(str(tmp_path), keep=1)
    tree = {"w": jnp.arange(16.0).reshape(4, 4), "b": jnp.ones(4)}
    m.save(1, tree, blocking=True)
    from repro.parallel.compat import make_mesh

    mesh = make_mesh((1,), ("data",), devices=jax.devices()[:1])
    sh = {
        "w": NamedSharding(mesh, P("data", None)),
        "b": NamedSharding(mesh, P()),
    }
    got, step = m.restore(tree, shardings=sh)
    assert step == 1
    assert np.array_equal(np.asarray(got["w"]), np.arange(16.0).reshape(4, 4))
    assert got["w"].sharding == sh["w"]


def test_registry_covers_all_assigned_archs_and_paper():
    from repro.configs.registry import get_config, get_smoke_config, list_archs

    archs = list_archs()
    assert len([a for a in archs if not a.startswith("comet")]) == 10
    assert {"comet_2way", "comet_3way", "comet_2way_mxu",
            "comet_3way_mxu"} <= set(archs)
    for a in archs:
        cfg = get_config(a)
        smoke = get_smoke_config(a)
        assert cfg.name and smoke.name


def test_dryrun_cells_enumeration():
    from repro.launch.specs import applicable, cells

    cs = cells(include_comet=False)
    assert len(cs) == 32  # 40 - 8 long_500k skips
    ok, why = applicable("llama3-8b", "long_500k")
    assert not ok and "attention" in why
    ok, _ = applicable("mamba2-1.3b", "long_500k")
    assert ok
