"""Feed-forward blocks: SwiGLU MLP (dense archs) and top-k MoE.

MoE dispatch is sort-based and capacity-bounded (dropless up to the capacity
factor): token->expert assignments are argsorted by expert id and scattered
into an (E, C, D) buffer, giving dense per-expert GEMMs with static shapes —
no (T, E, C) one-hot dispatch tensor (which is O(T^2) at LM batch sizes).
Experts are sharded over "model" through their hidden dim ("expert-internal
TP"), exact for any expert count (grok: 8, granite: 40) on a 16-wide axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init
from repro.parallel.sharding import DATA_AXES, shard


def init_mlp(cfg: ModelConfig, key, d_ff: int | None = None):
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, (cfg.d_model, d_ff), cfg.pdt),
        "wg": dense_init(k2, (cfg.d_model, d_ff), cfg.pdt),
        "wo": dense_init(k3, (d_ff, cfg.d_model), cfg.pdt),
    }


def mlp(cfg: ModelConfig, p, x):
    cdt = cfg.cdt
    h = x @ p["wi"].astype(cdt)
    g = x @ p["wg"].astype(cdt)
    h = shard(h, DATA_AXES, None, "model")
    g = shard(g, DATA_AXES, None, "model")
    y = (jax.nn.silu(g) * h) @ p["wo"].astype(cdt)
    return shard(y, DATA_AXES, None, None)


def init_moe(cfg: ModelConfig, key):
    d_ff = cfg.moe_d_ff or cfg.d_ff
    kr, k1, k2, k3 = jax.random.split(key, 4)
    E = cfg.n_experts
    return {
        "router": dense_init(kr, (cfg.d_model, E), jnp.float32, scale=0.02),
        "wi": dense_init(k1, (E, cfg.d_model, d_ff), cfg.pdt),
        "wg": dense_init(k2, (E, cfg.d_model, d_ff), cfg.pdt),
        "wo": dense_init(k3, (E, d_ff, cfg.d_model), cfg.pdt),
    }


def _dispatch(cfg: ModelConfig, xt, probs, C: int):
    """Sort-based capacity dispatch for one token chunk.

    xt (T, D), probs (T, E) -> (buf (E, C, D), st, slot, keep, gates)."""
    cdt = cfg.cdt
    T, D = xt.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    gate_vals, expert_ids = jax.lax.top_k(probs, K)  # (T, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    flat_e = expert_ids.reshape(T * K)
    flat_g = gate_vals.reshape(T * K)
    flat_t = jnp.repeat(jnp.arange(T), K)
    order = jnp.argsort(flat_e)  # stable
    se, sg, st = flat_e[order], flat_g[order], flat_t[order]
    # position within expert segment
    pos = jnp.arange(T * K) - jnp.searchsorted(se, se, side="left")
    keep = pos < C
    slot = se * C + jnp.where(keep, pos, 0)  # (T*K,)
    buf = jnp.zeros((E * C, D), cdt)
    buf = buf.at[slot].add(jnp.where(keep[:, None], xt[st], 0).astype(cdt))
    return buf.reshape(E, C, D), st, slot, keep, sg


def _combine(cfg: ModelConfig, o, st, slot, keep, sg, T: int):
    """Scatter expert outputs (E*C, D) back to (T, D)."""
    cdt = cfg.cdt
    y = o[slot] * jnp.where(keep, sg, 0)[:, None].astype(cdt)
    return jnp.zeros((T, o.shape[-1]), cdt).at[st].add(y)


def moe(cfg: ModelConfig, p, x):
    """Top-k MoE with sort-based capacity dispatch.

    ``moe_dispatch_chunks > 0`` (§Perf): the sort/scatter dispatch runs
    independently per token chunk — GSPMD keeps each chunk's sort local to
    its data shard instead of a global cross-device sort (the dominant
    collective in the MoE baseline).  Capacity is per-chunk, so routing is
    slightly stricter; expert GEMMs see the concatenated chunk buffers and
    keep their full size.

    Returns (y, aux_loss) — aux is the standard load-balancing loss."""
    cdt = cfg.cdt
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    T = B * S
    xt = x.reshape(T, D)

    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    frac = jnp.mean(
        jax.nn.one_hot(jnp.argmax(probs, -1), E, dtype=jnp.float32), axis=0
    )
    aux = E * jnp.sum(frac * probs.mean(axis=0))

    nc = cfg.moe_dispatch_chunks
    if nc and T % nc == 0 and T // nc >= K:
        Tc = T // nc
        C = int(cfg.capacity_factor * Tc * K / E) + 1
        xc = xt.reshape(nc, Tc, D)
        pc = probs.reshape(nc, Tc, E)
        buf, st, slot, keep, sg = jax.vmap(
            lambda xi, pi: _dispatch(cfg, xi, pi, C)
        )(xc, pc)
        # (nc, E, C, D) -> (E, nc*C, D): chunk buffers concatenated per expert
        bufm = buf.transpose(1, 0, 2, 3).reshape(E, nc * C, D)
    else:
        nc = 0
        C = int(cfg.capacity_factor * T * K / E) + 1
        bufm, st, slot, keep, sg = _dispatch(cfg, xt, probs, C)

    bufm = shard(bufm, None, DATA_AXES, None)
    h = jnp.einsum("ecd,edf->ecf", bufm, p["wi"].astype(cdt))
    g = jnp.einsum("ecd,edf->ecf", bufm, p["wg"].astype(cdt))
    h = shard(h, None, DATA_AXES, "model")
    g = shard(g, None, DATA_AXES, "model")
    o = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, p["wo"].astype(cdt))

    if nc:
        oc = o.reshape(E, nc, C, D).transpose(1, 0, 2, 3).reshape(nc, E * C, D)
        yc = jax.vmap(lambda oi, sti, sli, ki, sgi: _combine(cfg, oi, sti, sli, ki, sgi, T // nc))(
            oc, st, slot, keep, sg
        )
        yt = yc.reshape(T, D)
    else:
        yt = _combine(cfg, o.reshape(E * C, D), st, slot, keep, sg, T)
    return yt.reshape(B, S, D), aux
