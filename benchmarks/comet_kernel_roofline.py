"""§Perf iteration A1: roofline of the *faithful Pallas VPU kernel* derived
structurally from its BlockSpec tiling (no wall-clock — per the dry-run
methodology: VMEM footprint and HBM traffic are claims the BlockSpec makes).

The XLA-fallback baseline materializes broadcast-minimum chunks in HBM
(memory-bound, measured 139.6 s).  The Pallas kernel (kernels/mgemm) streams
A/B tiles HBM->VMEM with fp32 VMEM accumulation:

  HBM traffic / block-GEMM  = (N/bn) * bytes(A) + (M/bm) * bytes(B) + bytes(C)
  VMEM working set          = (bm*bk + bk*bn) * 4 B * 2 (double buffer)
                              + bm*bn*4 B accumulator
  compute                   = 2*M*N*K VPU ops (min+add per element pair)

Emits a dry-run-style JSON artifact tagged `pallas_model` so the §Perf table
can cite it alongside HLO-derived cells.
"""
from __future__ import annotations

import json
import os

from repro.roofline.analysis import HW_V5E

HERE = os.path.dirname(os.path.abspath(__file__))
# comet artifacts are committed under results/comet (see results/README.md)
OUT = os.path.join(HERE, "..", "results", "comet")

# comet_2way single-pod decomposition (configs/comet.py): n_pv=64, n_pr=4
N_F = 10000
N_VP = 12288
N_PV = 64
N_PR = 4
LOAD = 9  # blocks per rank: ceil((n_pv/2 + 1) / n_pr)


def kernel_roofline(bm: int, bn: int, bk: int, hw=HW_V5E) -> dict:
    M = N = N_VP
    K = N_F
    a_bytes = M * K * 4
    b_bytes = K * N * 4
    c_bytes = M * N * 4
    traffic = (N // bn) * a_bytes + (M // bm) * b_bytes + c_bytes
    vmem = (bm * bk + bk * bn) * 4 * 2 + bm * bn * 4
    ops = 2 * M * N * K  # min + add per element pair
    t_compute = LOAD * ops / hw.vpu_ops
    t_memory = LOAD * traffic / hw.hbm_bw
    # ring collective identical to the measured baseline (V block per step)
    t_collective = 0.3146
    return {
        "block": (bm, bn, bk),
        "vmem_bytes": vmem,
        "hbm_traffic_per_block": traffic,
        "t_compute": t_compute,
        "t_memory": t_memory,
        "t_collective": t_collective,
        "bottleneck": max(
            ("compute", t_compute), ("memory", t_memory),
            ("collective", t_collective), key=lambda kv: kv[1],
        )[0],
    }


def main():
    rows = []
    os.makedirs(OUT, exist_ok=True)
    for bm, bn, bk in [(128, 128, 512), (256, 256, 512), (512, 512, 512)]:
        r = kernel_roofline(bm, bn, bk)
        assert r["vmem_bytes"] < 16 * 2**20, "tile must fit VMEM"
        rows.append(
            (f"perfA1/pallas_vpu_{bm}x{bn}x{bk}", r["t_memory"] * 1e6,
             f"comp={r['t_compute']:.2f}s_mem={r['t_memory']:.3f}s_"
             f"vmem={r['vmem_bytes'] / 2**20:.1f}MiB_bound={r['bottleneck']}")
        )
    best = kernel_roofline(512, 512, 512)
    artifact = {
        "arch": "comet_2way", "shape": "paper", "mesh": "16x16",
        "kind": "comet2way", "analytic": "pallas BlockSpec model (A1)",
        "roofline": {
            "t_compute": best["t_compute"],
            "t_memory": best["t_memory"],
            "t_collective": best["t_collective"],
            "bottleneck": best["bottleneck"],
            "vpu_fraction": 1.0,
            "n_devices": 256,
        },
    }
    with open(os.path.join(OUT, "comet_2way__paper__pod_16x16__pallas_model.json"),
              "w") as f:
        json.dump(artifact, f, indent=2)
    return rows


if __name__ == "__main__":
    from benchmarks.util import print_rows

    print_rows(main())
