"""API-level campaign throughput: one row per (registered metric, way).

Times full ``SimilarityEngine.run`` campaigns — request validation, mesh
lookup, dispatch, device compute and host readback — so the numbers reflect
what a caller of the unified API actually gets, not just kernel time.
Derived column: elementwise comparisons/second (the paper's headline
metric).
"""
from __future__ import annotations

import time

from benchmarks.util import row
from repro.api import SimilarityEngine, SimilarityRequest, available_metrics
from repro.core.synthetic import random_integer_vectors

N_F2, N_V2 = 512, 256  # 2-way campaign shape
N_F3, N_V3 = 64, 48  # 3-way campaign shape (O(n^3) results)


def main():
    engine = SimilarityEngine()
    V2 = random_integer_vectors(N_F2, N_V2, seed=0)
    V3 = random_integer_vectors(N_F3, N_V3, seed=0)
    rows = []
    for name in available_metrics():
        for way, V in ((2, V2), (3, V3)):
            req = SimilarityRequest(metric=name, way=way)
            engine.run(req, V)  # warmup/compile
            t0 = time.perf_counter()
            result = engine.run(req, V)
            dt = time.perf_counter() - t0
            comparisons = result.num_results() * V.shape[0]
            rows.append(row(
                f"api/{name}/{way}way", dt,
                f"{comparisons / dt:.3e}_cmp/s_results={result.num_results()}",
            ))
    return rows


if __name__ == "__main__":
    from benchmarks.util import print_rows

    print_rows(main())
