"""Decoupled AdamW with global-norm clipping — pure JAX, pytree-native.

Master weights and moments are fp32 regardless of compute dtype (mixed
precision: bf16 forward/backward, fp32 update).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    schedule: Callable | None = None  # step -> lr multiplier


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def adamw_update(cfg: AdamWConfig, grads, state, params):
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = cfg.lr * (cfg.schedule(count) if cfg.schedule is not None else 1.0)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / (1 - cfg.b1**count.astype(jnp.float32))
        vhat = v / (1 - cfg.b2**count.astype(jnp.float32))
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        new_p = p32 - lr * (step + cfg.weight_decay * p32)
        return new_p.astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["mu"])
    flat_v = treedef.flatten_up_to(state["nu"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"mu": new_m, "nu": new_v, "count": count}, metrics
