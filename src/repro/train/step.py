"""jit'd train/serve step factories (shared by trainer, launcher, dry-run)."""
from __future__ import annotations


import jax

from repro.models import api
from repro.models.common import ModelConfig
from repro.optim.adamw import AdamWConfig, adamw_update


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: api.model_loss(cfg, p, batch)
        )(params)
        params, opt_state, m = adamw_update(opt_cfg, grads, opt_state, params)
        m = dict(m, loss=loss)
        return params, opt_state, m

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        return api.model_loss(cfg, params, batch)

    return eval_step


def make_serve_step(cfg: ModelConfig):
    """One batched decode step: (params, cache, tokens, idx) -> (logits, cache)."""

    def serve_step(params, cache, tokens, cache_index):
        return api.decode_step(cfg, params, cache, tokens, cache_index)

    return serve_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        logits, _ = api.model_forward(cfg, params, batch)
        return logits

    return prefill_step
