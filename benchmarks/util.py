"""Shared benchmark helpers."""
from __future__ import annotations

import time

import jax


def time_fn(fn, *args, warmup: int = 1, iters: int = 3,
            reduce: str = "median") -> float:
    """Wall seconds per call (blocks on jax outputs).

    ``reduce="median"`` (default) suits one-off table rows; ``"min"`` is the
    low-noise estimator used for the BENCH_kernels.json trajectory entries,
    where scheduler interference must not read as a kernel regression."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[0] if reduce == "min" else times[len(times) // 2]


def row(name: str, seconds: float, derived: str = "") -> tuple[str, float, str]:
    return (name, seconds * 1e6, derived)


def print_rows(rows):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
