"""Architecture registry: --arch <id> -> config (+ reduced smoke variant)."""
from __future__ import annotations

import importlib

_MODULES = {
    "qwen1.5-0.5b": "repro.configs.qwen15_0_5b",
    "llama3-8b": "repro.configs.llama3_8b",
    "command-r-plus-104b": "repro.configs.command_r_plus_104b",
    "deepseek-67b": "repro.configs.deepseek_67b",
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
    "grok-1-314b": "repro.configs.grok1_314b",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b",
    "zamba2-1.2b": "repro.configs.zamba2_1_2b",
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
}

_COMET = {"comet_2way", "comet_3way", "comet_2way_mxu", "comet_3way_mxu"}


def list_archs(include_comet: bool = True) -> list[str]:
    names = list(_MODULES)
    if include_comet:
        names += sorted(_COMET)
    return names


def get_config(name: str):
    if name in _COMET:
        from repro.configs import comet

        return {
            "comet_2way": comet.CONFIG_2WAY,
            "comet_3way": comet.CONFIG_3WAY,
            "comet_2way_mxu": comet.CONFIG_2WAY_MXU,
            "comet_3way_mxu": comet.CONFIG_3WAY_MXU,
        }[name]
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {list_archs()}")
    return importlib.import_module(_MODULES[name]).CONFIG


def get_smoke_config(name: str):
    if name in _COMET:
        from repro.configs import comet

        return comet.SMOKE_2WAY if "2way" in name else comet.SMOKE_3WAY
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}")
    return importlib.import_module(_MODULES[name]).SMOKE
