"""Fused-epilogue and triangular-schedule parity (interpret mode).

The TileExecutor contract: for every registered metric, the generated
fused Pallas kernel (contraction + in-kernel ``assemble_tile`` epilogue)
must be BIT-identical to the unfused path (mGEMM-style contraction + out-of-
kernel ``assemble2``), for rectangular tiles and for the triangular
diagonal-block schedule, across out_dtypes.  Integer inputs make every
numerator fp-exact, so both paths perform literally the same divisions.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.registry import available_metrics, get_metric
from repro.core.synthetic import random_integer_vectors
from repro.core.tile_executor import TileExecutor
from repro.core.twoway import CometConfig, czek2_distributed
from repro.kernels.mgemm import (
    czek2_metric,
    metric2_tri,
    tri_tile_coords,
    unpack_tri_tiles,
)
from repro.kernels.mgemm.kernel import _tri_decode
from repro.parallel.mesh import make_comet_mesh

OUT_DTYPES = ["float32", "bfloat16"]


def _executors(metric_name, out_dtype):
    spec = get_metric(metric_name)
    dt = jnp.dtype(out_dtype)
    fused = TileExecutor(cfg=CometConfig(impl="pallas"), metric=spec,
                         out_dtype=dt, axis=None)
    unfused = TileExecutor(cfg=CometConfig(impl="xla"), metric=spec,
                           out_dtype=dt, axis=None)
    return spec, fused, unfused


@pytest.mark.parametrize("out_dtype", OUT_DTYPES)
@pytest.mark.parametrize("metric_name", sorted(available_metrics()))
def test_rectangular_fused_parity(metric_name, out_dtype):
    """Off-diagonal (rectangular) block: fused == contraction + assembly."""
    spec, fused, unfused = _executors(metric_name, out_dtype)
    if spec.assemble_tile is None:
        pytest.skip("metric has no Pallas-composable epilogue")
    assert fused.fused and not unfused.fused
    V = random_integer_vectors(40, 23, max_value=15, seed=11)
    Va = jnp.asarray(V[:, :11])
    Vb = jnp.asarray(V[:, 11:])
    sa = jnp.asarray(np.asarray(spec.stat(Va)))
    sb = jnp.asarray(np.asarray(spec.stat(Vb)))
    got = fused.pair_block(Va, sa, Vb, sb, diagonal=False)
    want = unfused.pair_block(Va, sa, Vb, sb, diagonal=False)
    assert got.dtype == want.dtype == jnp.dtype(out_dtype)
    assert (np.asarray(got) == np.asarray(want)).all()


@pytest.mark.parametrize("out_dtype", OUT_DTYPES)
@pytest.mark.parametrize("metric_name", sorted(available_metrics()))
# one-tile, ragged, one-tile-exact, multi-tile T>1 through the executor's
# auto tile (200 > DEFAULT_BM=128 -> T=2, exercising _tri_decode + unpack)
@pytest.mark.parametrize("m", [8, 11, 24, 200])
def test_triangular_fused_parity(metric_name, out_dtype, m):
    """Diagonal block on the triangular schedule == compute-then-mask."""
    spec, fused, unfused = _executors(metric_name, out_dtype)
    if spec.assemble_tile is None:
        pytest.skip("metric has no Pallas-composable epilogue")
    V = jnp.asarray(random_integer_vectors(32, m, max_value=15, seed=m))
    s = jnp.asarray(np.asarray(spec.stat(V)))
    got = fused.pair_block(V, s, V, s, diagonal=True)
    want = unfused.pair_block(V, s, V, s, diagonal=True)
    assert (np.asarray(got) == np.asarray(want)).all()
    # strict upper triangle only — the lower half was never computed
    assert (np.asarray(got)[np.tril_indices(m)] == 0).all()


@pytest.mark.parametrize("metric_name", sorted(available_metrics()))
def test_threeway_slice_fused_parity(metric_name):
    """Fused per-column X_j kernels == batched XLA contraction (3-way)."""
    spec, fused, unfused = _executors(metric_name, "float32")
    if not spec.contract_is_combine_sum:
        pytest.skip("metric contraction is not a combine-sum")
    rng = np.random.default_rng(9)
    n_f, m, L = 24, 10, 3
    pipe = jnp.asarray(rng.integers(0, 8, (n_f, m)).astype(np.float32))
    left = jnp.asarray(rng.integers(0, 8, (n_f, m)).astype(np.float32))
    right = jnp.asarray(rng.integers(0, 8, (n_f, m)).astype(np.float32))
    ps = pipe[:, :L]
    got = fused.threeway_slice(ps, left, right)
    want = unfused.threeway_slice(ps, left, right)
    assert got.shape == want.shape == (L, m, m)
    assert (np.asarray(got) == np.asarray(want)).all()


def test_triangular_schedule_enumerates_half():
    """The grid visits exactly T(T+1)/2 tiles, each unordered pair once."""
    for T in [1, 2, 3, 7, 64, 513]:
        ti, tj = tri_tile_coords(T)
        assert len(ti) == T * (T + 1) // 2
        assert (tj >= ti).all()
        assert len({(a, b) for a, b in zip(ti, tj)}) == len(ti)
        # the in-kernel arithmetic decode matches the host schedule exactly
        di, dj = _tri_decode(jnp.arange(len(ti)), T)
        assert (np.asarray(di) == ti).all() and (np.asarray(dj) == tj).all()


def test_packed_tri_kernel_storage_is_half():
    """Packed (P, bt, bt) output holds ~half the dense block's tiles."""
    spec = get_metric("czekanowski")
    V = jnp.asarray(random_integer_vectors(16, 32, max_value=7, seed=2))
    s = jnp.asarray(np.asarray(spec.stat(V)))
    bt = 8
    packed = metric2_tri(V.T, V, s, s, combine=spec.combine,
                         epilogue=spec.assemble_tile, bt=bt, bk=16)
    T = 32 // bt
    assert packed.shape == (T * (T + 1) // 2, bt, bt)  # 10 tiles, not 16
    dense = unpack_tri_tiles(packed, 32, bt)
    want = np.asarray(spec.assemble2(
        jnp.minimum(V[:, :, None], V[:, None, :]).astype(jnp.float32).sum(0),
        s[:, None], s[None, :],
    ))
    want = np.where(np.triu(np.ones((32, 32), bool), 1), want, 0)
    assert (np.asarray(dense) == want.astype(np.float32)).all()


def test_fused_kernel_zero_denominator_guarded():
    """All-zero vectors: kernel path must yield 0 (safe_denom), not NaN.

    Regression for the pre-refactor czek2_metric_pallas, which padded
    row-sums with 1.0 and divided raw — real all-zero columns hit 0/0."""
    V = np.zeros((16, 4), np.float32)
    V[:, 0] = 1.0  # one live column, three all-zero
    Vj = jnp.asarray(V)
    s = Vj.sum(axis=0)
    got = np.asarray(czek2_metric(Vj.T, Vj, s, s, bm=8, bn=8, bk=8))
    assert np.isfinite(got).all(), "0/0 leaked through the kernel epilogue"
    # all-zero x all-zero and all-zero x live pairs are 0, live diag is 1
    assert got[0, 0] == 1.0
    assert (got[1:, :] == 0).all() and (got[:, 1:] == 0).all()


def test_packed_output_roundtrip_and_memory():
    """pack(): identical entries + checksum, ~half slot-buffer memory."""
    V = random_integer_vectors(32, 20, max_value=15, seed=6)
    out = czek2_distributed(V, make_comet_mesh(1, 1, 1), CometConfig())
    packed = out.pack()
    assert packed.storage == "packed"
    assert packed.checksum() == out.checksum()
    assert (packed.dense() == out.dense()).all()
    m = out.n_vp
    assert packed.nbytes == out.nbytes * (m - 1) // (2 * m)  # tri/full ratio
    assert packed.pack() is packed  # idempotent


def test_custom_contract_metric_never_silently_fused():
    """A metric with a custom (non-combine-sum) contraction must stay off
    the fused kernels unless it opts in explicitly — impl='pallas' would
    otherwise silently compute the wrong numerators."""
    from repro.core.metric_spec import MetricSpec

    custom = MetricSpec(name="weird", combine=jnp.minimum,
                        contract=lambda A, B: A @ B + 1.0)
    assert not custom.contract_is_combine_sum
    ex = TileExecutor(cfg=CometConfig(impl="pallas"), metric=custom)
    assert not ex.fused and not ex.fused3
    # mgemm-dispatch and generic-fallback metrics auto-qualify; explicit
    # opt-in (CCC's dot) is honored
    assert get_metric("czekanowski").contract_is_combine_sum
    assert get_metric("ccc").contract_is_combine_sum
    assert MetricSpec(name="generic", combine=jnp.minimum).contract_is_combine_sum


def test_executor_fusion_predicate():
    """The fused epilogue needs the complete numerator: n_pf splits the
    contraction over ranks, so fusion must disengage."""
    spec = get_metric("czekanowski")
    assert TileExecutor(cfg=CometConfig(impl="pallas"), metric=spec).fused
    assert not TileExecutor(cfg=CometConfig(impl="pallas", n_pf=2),
                            metric=spec).fused
    assert not TileExecutor(cfg=CometConfig(impl="xla"), metric=spec).fused
    assert not TileExecutor(cfg=CometConfig(impl="levels_xla"),
                            metric=spec).fused
