"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Prints one line per (arch x shape x mesh) cell with the three terms,
the dominant bottleneck, MODEL_FLOPS ratio and modeled step time.
"""
from __future__ import annotations

import glob
import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))
DRYRUN = os.path.join(HERE, "..", "results", "dryrun")


def load_cells(pattern: str = "*.json"):
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN, pattern))):
        with open(path) as f:
            cells.append((os.path.basename(path), json.load(f)))
    return cells


def fmt_cell(name, r):
    t = r["roofline"]
    ratio = r.get("useful_flops_ratio", 0.0)
    t_bound = max(t["t_compute"], t["t_memory"], t["t_collective"])
    frac = t["t_compute"] / t_bound if t_bound else 0.0
    return (
        f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
        f"comp={t['t_compute']:.3e} mem={t['t_memory']:.3e} "
        f"coll={t['t_collective']:.3e} bound={t['bottleneck']:10s} "
        f"roofline_frac={frac:.3f} useful={ratio:.2f}"
    )


def main():
    rows = []
    for name, r in load_cells():
        if "roofline" not in r:
            continue
        print(fmt_cell(name, r))
        t = r["roofline"]
        t_bound = max(t["t_compute"], t["t_memory"], t["t_collective"])
        rows.append((f"roofline/{r['arch']}_{r['shape']}_{r['mesh']}",
                     t_bound * 1e6,
                     f"bound={t['bottleneck']}"))
    return rows


if __name__ == "__main__":
    main()
