"""MoE router analysis with the paper's engine: which experts see similar
token populations?

Routes a synthetic batch through a smoke-scale MoE, builds per-expert
token-histogram profile vectors, and runs all-pairs Czekanowski similarity
over experts — high c2 means two experts serve near-identical token
distributions (a sign of redundancy / collapsed routing).

    PYTHONPATH=src python examples/moe_affinity.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.core.twoway import CometConfig, czek2_distributed
from repro.models import api
from repro.parallel.mesh import make_comet_mesh


def main():
    cfg = get_smoke_config("granite-moe-3b-a800m")
    params = api.init_model(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab_size, (8, 64)), jnp.int32)

    # router logits of layer 0
    x = params["embed"][tokens]
    router = params["layers"]["moe"]["router"][0]
    logits = x.astype(jnp.float32) @ router
    _, expert_ids = jax.lax.top_k(jax.nn.softmax(logits), cfg.experts_per_token)
    expert_ids = np.asarray(expert_ids).reshape(-1, cfg.experts_per_token)
    flat_tokens = np.asarray(tokens).reshape(-1)

    # per-expert token histogram profiles (hashed)
    H = 256
    V = np.zeros((H, cfg.n_experts), np.float32)
    for t, row in zip(flat_tokens, expert_ids):
        for e in row:
            V[t % H, e] += 1.0

    out = czek2_distributed(V, make_comet_mesh(1, 1, 1),
                            CometConfig(out_dtype="float32"))
    pairs = [(i, j, w) for I, J, W in out.entries() for i, j, w in zip(I, J, W)]
    pairs.sort(key=lambda t: -t[2])
    print(f"{cfg.n_experts} experts, top-{cfg.experts_per_token} routing")
    print("most similar expert pairs (token-population overlap):")
    for i, j, w in pairs[:5]:
        print(f"  expert{i} ~ expert{j}: c2={w:.3f}")
    loads = V.sum(axis=0)
    print("expert loads:", loads.astype(int).tolist())


if __name__ == "__main__":
    main()
