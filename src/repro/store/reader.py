"""Dataset reader: memory-mapped plane views + zero-encode campaign loading.

``DatasetReader`` serves the on-disk payloads four ways:

* ``shard(r)``  — one field shard ``(levels, kbs, n_v)``, an ``np.memmap``
  byte-range view by default (no copy, no decode): disk shard ``r`` IS the
  ``shard_planes_fields(planes, r, n_shards)`` range.
* ``iter_shards()`` — the shard views in rank order, one at a time; the
  streaming pipeline's unit of I/O (nothing is ever concatenated).
* ``planes()``  — the full ``(levels, kb, n_v)`` payload; zero-copy mmap
  for single-shard datasets, one preallocated gather otherwise.  This
  MATERIALIZES multi-shard payloads — streamed campaigns never call it.
* ``packed()`` / ``sharded()`` — the engine-facing handles: ``packed()``
  materializes a ``PackedPlanes`` (mmap -> ring with NO host-side encode,
  asserted via an encoder-call counter in tests/test_store.py);
  ``sharded()`` returns a LAZY ``ShardedPlanes`` that carries only the
  manifest geometry + provenance, so ``repro.stream`` can plan a
  bounded-memory campaign without touching payload bytes.

``validate()`` recomputes the sha256 payload checksum, the stats sidecar
and every shape against the manifest.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field

import numpy as np

from repro.kernels.mgemm_levels import POPCOUNT, PackedPlanes
from repro.store.format import payload_checksum, read_manifest

__all__ = ["DatasetReader", "ShardedPlanes"]


class DatasetReader:
    """Read-side handle on one dataset directory (manifest parsed eagerly,
    payloads mapped lazily)."""

    def __init__(self, path: str):
        self.path = path
        self.manifest = read_manifest(path)

    # -- manifest accessors -------------------------------------------------

    @property
    def levels(self) -> int:
        return self.manifest["levels"]

    @property
    def n_f(self) -> int:
        return self.manifest["n_f"]

    @property
    def n_v(self) -> int:
        return self.manifest["n_v"]

    @property
    def kb(self) -> int:
        return self.manifest["kb"]

    @property
    def n_shards(self) -> int:
        return self.manifest["n_shards"]

    # -- payload views ------------------------------------------------------

    def shard(self, rank: int, *, mmap: bool = True) -> np.ndarray:
        """(levels, kb/n_shards, n_v) uint8 — field shard ``rank``."""
        if not 0 <= rank < self.n_shards:
            raise ValueError(f"shard {rank} out of range [0, {self.n_shards})")
        target = os.path.join(self.path, self.manifest["shard_files"][rank])
        arr = np.load(target, mmap_mode="r" if mmap else None)
        want = (self.levels, self.kb // self.n_shards, self.n_v)
        if arr.shape != want or arr.dtype != np.uint8:
            raise ValueError(
                f"{target}: payload is {arr.dtype}{arr.shape}, manifest says "
                f"uint8{want}"
            )
        return arr

    def iter_shards(self, *, mmap: bool = True):
        """Yield the ``(levels, kbs, n_v)`` shard views in rank order.

        Each view is independent (one open mmap at a time when the caller
        drops its reference), so a full-payload pass — checksum, stats,
        streaming — holds one shard of address space, not the dataset.
        """
        for r in range(self.n_shards):
            yield self.shard(r, mmap=mmap)

    def shard_range(self, rank: int, lo: int, hi: int, *,
                    mmap: bool = True) -> np.ndarray:
        """Byte sub-range view ``[lo, hi)`` of shard ``rank`` —
        ``(levels, hi - lo, n_v)``.  The streaming chunk loader reads these
        (a chunk may cover only part of a shard file, or span two)."""
        if not 0 <= lo <= hi <= self.kb // self.n_shards:
            raise ValueError(
                f"byte range [{lo}, {hi}) outside shard of "
                f"{self.kb // self.n_shards} bytes"
            )
        return self.shard(rank, mmap=mmap)[:, lo:hi, :]

    def planes(self, *, mmap: bool = True) -> np.ndarray:
        """Full (levels, kb, n_v) payload (mmap view when single-shard).

        Multi-shard payloads are gathered shard-by-shard into ONE
        preallocated array (the old ``np.concatenate`` built a full list
        of materialized shards first — twice the dataset in host RAM at
        peak).  For a bounded-memory pass use ``iter_shards()`` or the
        ``repro.stream`` pipeline instead.
        """
        if self.n_shards == 1:
            return self.shard(0, mmap=mmap)
        kbs = self.kb // self.n_shards
        out = np.empty((self.levels, self.kb, self.n_v), np.uint8)
        for r, shard in enumerate(self.iter_shards(mmap=True)):
            np.copyto(out[:, r * kbs:(r + 1) * kbs, :], shard)
        return out

    def origin(self) -> dict:
        """Provenance block result manifests record (path + exact bytes).

        Appended datasets also carry ``dataset_version`` and the ``parent``
        lineage block, so a result manifest proves which ancestor a delta
        campaign's prior belongs to."""
        o = {
            "path": self.path,
            "checksum": self.manifest["checksum"],
            "levels": self.levels,
            "source": self.manifest.get("source", {}),
            "dataset_version": self.manifest.get("dataset_version", 1),
        }
        if self.manifest.get("parent") is not None:
            o["parent"] = self.manifest["parent"]
        return o

    def sharded(self) -> "ShardedPlanes":
        """Lazy engine-facing handle: geometry + provenance, NO payload.

        ``resolve_config`` accepts it wherever ``PackedPlanes`` is accepted
        (same eligibility rules); the streaming pipeline iterates its
        shards without ever materializing the concatenated payload, and
        ``materialize()`` converts to an eager ``PackedPlanes`` for the
        in-memory engines."""
        return ShardedPlanes(reader=self, origin=self.origin())

    def packed(self, *, mmap: bool = True) -> PackedPlanes:
        """The engine-facing handle: planes + true field count + origin.

        The origin block carries the manifest's path/checksum/provenance
        with the payload, so result manifests can record the exact dataset
        bytes a campaign ran on without re-reading ``dataset.json``."""
        return PackedPlanes(
            planes=self.planes(mmap=mmap),
            n_f=self.n_f,
            origin=self.origin(),
        )

    def stats(self) -> np.ndarray:
        """(levels, n_v) int64 per-plane popcounts (exact-stats sidecar).

        ``stats().sum(axis=0)`` is the per-vector column sum of the encoded
        matrix — the Czekanowski denominator stat.
        """
        target = os.path.join(self.path, self.manifest["stats_file"])
        arr = np.load(target)
        want = (self.levels, self.n_v)
        if arr.shape != want:
            raise ValueError(
                f"{target}: stats shape {arr.shape}, manifest says {want}"
            )
        return arr

    # -- integrity ----------------------------------------------------------

    def validate(self) -> dict:
        """Recompute checksum + stats from the payloads; raise on mismatch.

        One pass over the shards feeds both the sha256 and the popcount
        accumulator (mirroring the writer), so validation reads each shard
        from disk once.  Returns the manifest on success.
        """
        stats = np.zeros((self.levels, self.n_v), np.int64)

        def scan():
            for r in range(self.n_shards):
                shard = self.shard(r)
                np.add(stats, POPCOUNT[shard].sum(axis=1, dtype=np.int64),
                       out=stats)
                yield shard

        got = payload_checksum(scan())
        want = self.manifest["checksum"]
        if got != want:
            raise ValueError(
                f"{self.path}: payload checksum {got} != manifest {want}"
            )
        if not np.array_equal(stats, self.stats()):
            raise ValueError(f"{self.path}: stats sidecar does not match payload")
        return self.manifest


@dataclass(frozen=True, eq=False)
class ShardedPlanes:
    """Lazy multi-shard payload handle (geometry + provenance, no bytes).

    The streaming twin of ``PackedPlanes``: it quacks the same for
    ``resolve_config`` (``levels`` / ``n_f`` / ``n_v`` / ``origin``) but
    holds no plane array — ``repro.stream`` iterates the reader's shard
    views chunk by chunk instead.  ``PackedPlanes.__post_init__`` requires
    a real 3-D uint8 ndarray, which is exactly what a lazy handle must not
    have, hence a sibling class rather than a subclass.
    """

    reader: DatasetReader
    origin: dict = field(default_factory=dict)

    @property
    def levels(self) -> int:
        return self.reader.levels

    @property
    def kb(self) -> int:
        return self.reader.kb

    @property
    def n_f(self) -> int:
        return self.reader.n_f

    @property
    def n_v(self) -> int:
        return self.reader.n_v

    @property
    def n_shards(self) -> int:
        return self.reader.n_shards

    @property
    def nbytes(self) -> int:
        """Full payload size IF materialized (what streaming avoids)."""
        return self.levels * self.kb * self.n_v

    def materialize(self, *, mmap: bool = True) -> PackedPlanes:
        """Eager conversion for the in-memory engines (streaming=off)."""
        return self.reader.packed(mmap=mmap)
