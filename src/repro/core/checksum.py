"""Exact result checksums — paper §5.

The paper validates its parallel decompositions with "a checksum feature using
extended precision integer arithmetic [that] computes a bit-for-bit exact
checksum of computed results".  We reproduce that contract:

* every computed metric value is identified by its *global* index tuple
  ``(i, j)`` or ``(i, j, k)`` (canonicalized: sorted ascending) plus the IEEE
  bit pattern of its value;
* the checksum is a multiset hash — an order-independent sum over entries of
  ``mix(index) * bits(value)`` in unbounded python integers, reduced modulo
  2**192 — so any parallel decomposition that computes exactly the unique
  result set, with bit-identical values, yields the identical checksum;
* duplicated or missing results change the checksum with overwhelming
  probability; so does any single-ULP numerical difference.

This is the primary cross-decomposition validation used by the tests.
"""
from __future__ import annotations

import numpy as np

__all__ = ["checksum_pairs", "checksum_triples", "combine", "MOD"]

MOD = 1 << 192
_GOLD = 0x9E3779B97F4A7C15


def _mix(x: int) -> int:
    """splitmix64 finalizer — deterministic index mixing."""
    x = (x + _GOLD) & 0xFFFFFFFFFFFFFFFF
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    return x ^ (x >> 31)


def _value_bits(values: np.ndarray) -> np.ndarray:
    v = np.asarray(values)
    if v.dtype == np.float64:
        return v.view(np.uint64).astype(object)
    if v.dtype == np.float32:
        return v.view(np.uint32).astype(object)
    if v.dtype.itemsize == 2:  # float16 / bfloat16 (ml_dtypes) metric outputs
        return v.view(np.uint16).astype(object)
    raise TypeError(f"unsupported dtype {v.dtype}")


def checksum_pairs(i, j, values) -> int:
    """Checksum of 2-way results. (i, j) canonicalized to i < j."""
    i = np.asarray(i, np.int64)
    j = np.asarray(j, np.int64)
    lo = np.minimum(i, j)
    hi = np.maximum(i, j)
    keys = (lo.astype(object) << 32) | hi.astype(object)
    bits = _value_bits(values)
    total = 0
    count = keys.size
    for k, b in zip(keys.ravel(), bits.ravel()):
        total = (total + _mix(int(k)) * (int(b) + 1)) % MOD
    return (total + _mix(count)) % MOD


def checksum_triples(i, j, k, values) -> int:
    """Checksum of 3-way results. (i, j, k) canonicalized ascending."""
    idx = np.sort(np.stack([np.asarray(i), np.asarray(j), np.asarray(k)], -1), -1)
    keys = (
        (idx[..., 0].astype(object) << 42)
        | (idx[..., 1].astype(object) << 21)
        | idx[..., 2].astype(object)
    )
    bits = _value_bits(values)
    total = 0
    count = keys.size
    for key, b in zip(keys.ravel(), bits.ravel()):
        total = (total + _mix(int(key)) * (int(b) + 1)) % MOD
    return (total + _mix(count)) % MOD


def combine(parts) -> int:
    """Combine per-rank checksums.  Sums are order-independent by design, but
    each part already includes its own count term, so combine by summing the
    *raw* totals is wrong; instead parts must be raw (count-free).  To keep
    the API simple, per-rank code passes raw entry sums via this helper:
    combine() adds them and appends the global count mix."""
    total = 0
    count = 0
    for t, c in parts:
        total = (total + t) % MOD
        count += c
    return (total + _mix(count)) % MOD


def raw_pairs(i, j, values) -> tuple[int, int]:
    """Count-free partial checksum for combine()."""
    i = np.asarray(i, np.int64)
    j = np.asarray(j, np.int64)
    lo = np.minimum(i, j)
    hi = np.maximum(i, j)
    keys = (lo.astype(object) << 32) | hi.astype(object)
    bits = _value_bits(values)
    total = 0
    for k, b in zip(keys.ravel(), bits.ravel()):
        total = (total + _mix(int(k)) * (int(b) + 1)) % MOD
    return total, keys.size


def raw_triples(i, j, k, values) -> tuple[int, int]:
    idx = np.sort(np.stack([np.asarray(i), np.asarray(j), np.asarray(k)], -1), -1)
    keys = (
        (idx[..., 0].astype(object) << 42)
        | (idx[..., 1].astype(object) << 21)
        | idx[..., 2].astype(object)
    )
    bits = _value_bits(values)
    total = 0
    for key, b in zip(keys.ravel(), bits.ravel()):
        total = (total + _mix(int(key)) * (int(b) + 1)) % MOD
    return total, keys.size
