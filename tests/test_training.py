"""Training substrate tests: optimizer, data determinism, checkpoint/restart
(bit-exact), failure injection, straggler watchdog, serving."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.data.tokens import PrefetchIterator, SyntheticTokens
from repro.models import api
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from repro.optim.schedule import warmup_cosine
from repro.optim import compression
from repro.serve.engine import ServeConfig, ServeEngine
from repro.train.trainer import SimulatedFailure, Trainer, TrainerConfig


def _tree_equal(a, b):
    return all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def test_adamw_reduces_loss_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(cfg, g, state, params)
    assert float(loss(params)) < 1e-3


def test_grad_clipping():
    cfg = AdamWConfig(lr=0.0, clip_norm=1.0)
    params = {"w": jnp.ones(4)}
    state = adamw_init(params)
    g = {"w": jnp.full(4, 100.0)}
    _, _, m = adamw_update(cfg, g, state, params)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_schedule_shape():
    s = warmup_cosine(10, 100)
    assert float(s(jnp.array(0))) == 0.0
    assert float(s(jnp.array(10))) == pytest.approx(1.0)
    assert float(s(jnp.array(100))) == pytest.approx(0.1, abs=1e-3)


def test_data_determinism_and_sharding():
    src = SyntheticTokens(vocab_size=100, batch=8, seq_len=16, seed=3)
    a = src.batch_at(5)
    b = src.batch_at(5)
    assert np.array_equal(a["tokens"], b["tokens"])
    s0 = SyntheticTokens(vocab_size=100, batch=8, seq_len=16, seed=3,
                         shard=0, num_shards=2)
    s1 = SyntheticTokens(vocab_size=100, batch=8, seq_len=16, seed=3,
                         shard=1, num_shards=2)
    assert s0.local_batch == 4
    assert not np.array_equal(s0.batch_at(0)["tokens"], s1.batch_at(0)["tokens"])


def test_prefetch_iterator_order():
    src = SyntheticTokens(vocab_size=50, batch=2, seq_len=8)
    it = PrefetchIterator(src, start_step=7)
    for want in (7, 8, 9):
        step, batch = next(it)
        assert step == want
        assert np.array_equal(batch["tokens"], src.batch_at(want)["tokens"])
    it.close()


def test_compression_roundtrip_error_feedback():
    g = {"w": jnp.array([0.5, -0.25, 1.0, 3.0])}
    err = compression.ef_init(g)
    q, s, new_err = compression.compress_tree(g, err)
    deq = compression.dequantize(q["w"], s["w"])
    np.testing.assert_allclose(deq + new_err["w"], g["w"], rtol=1e-6)
    assert q["w"].dtype == jnp.int8


def test_trainer_checkpoint_restart_bit_exact(tmp_path):
    cfg = get_smoke_config("qwen1.5-0.5b")
    tdir = str(tmp_path / "ck")
    # uninterrupted run: 8 steps
    t1 = Trainer(cfg, TrainerConfig(steps=8, ckpt_every=4, ckpt_dir=tdir + "a",
                                    batch=2, seq_len=16))
    s1 = t1.train()
    # interrupted run: fail at 5, restart from ckpt @4, finish
    tc = TrainerConfig(steps=8, ckpt_every=4, ckpt_dir=tdir + "b",
                       batch=2, seq_len=16, fail_at_step=5)
    t2 = Trainer(cfg, tc)
    with pytest.raises(SimulatedFailure):
        t2.train()
    tc2 = TrainerConfig(steps=8, ckpt_every=4, ckpt_dir=tdir + "b",
                        batch=2, seq_len=16)
    t3 = Trainer(cfg, tc2)
    s3 = t3.train()  # resumes from step 4
    assert s3.step == 8
    assert _tree_equal(s1.params, s3.params), "restart must be bit-exact"


def test_checkpoint_retention_and_atomicity(tmp_path):
    from repro.checkpoint.ckpt import CheckpointManager

    m = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(5), "b": {"c": jnp.ones((2, 2))}}
    for s in (1, 2, 3):
        m.save(s, tree, blocking=True)
    assert m.available_steps() == [2, 3]
    got, step = m.restore(tree)
    assert step == 3
    assert _tree_equal(got, tree)
    assert not any(p.endswith(".tmp") for p in os.listdir(tmp_path))


def test_straggler_watchdog():
    from repro.train.straggler import StragglerWatchdog

    w = StragglerWatchdog(min_samples=3, threshold=2.0)
    for i in range(5):
        assert w.observe(i, 0.1) is None
    ev = w.observe(5, 1.0)
    assert ev is not None and ev.step == 5
    assert len(w.events) == 1


def test_trainer_straggler_integration():
    cfg = get_smoke_config("qwen1.5-0.5b")
    tc = TrainerConfig(steps=10, ckpt_every=100, ckpt_dir="/tmp/repro_strag",
                       batch=2, seq_len=8,
                       inject_delay=lambda s: 0.3 if s == 8 else 0.0)
    t = Trainer(cfg, tc)
    t.watchdog.min_samples = 3
    t.watchdog.threshold = 2.0
    t.train(t.init_state())
    assert any(e.step == 8 for e in t.watchdog.events)


def test_serve_engine_greedy_deterministic():
    cfg = get_smoke_config("llama3-8b")
    params = api.init_model(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, ServeConfig(max_new_tokens=8))
    prompts = np.array([[5, 6, 7], [9, 10, 11]], np.int32)
    a = eng.generate(prompts)
    b = eng.generate(prompts)
    assert a.shape == (2, 8)
    assert np.array_equal(a, b)


def test_serve_engine_ssm():
    cfg = get_smoke_config("mamba2-1.3b")
    params = api.init_model(cfg, jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, ServeConfig(max_new_tokens=5))
    out = eng.generate(np.array([[3, 4]], np.int32))
    assert out.shape == (1, 5)
