"""Production mesh construction (dry-run contract).

Single pod: (16, 16) -> ("data", "model") — one v5e pod, 256 chips.
Multi-pod:  (2, 16, 16) -> ("pod", "data", "model") — 512 chips.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax

from repro.parallel.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 1
    for s in shape:
        need *= s
    devices = jax.devices()[:need]
    if len(devices) < need:
        raise RuntimeError(
            f"need {need} devices for the production mesh, have {len(devices)}"
            " (dry-run sets --xla_force_host_platform_device_count=512)"
        )
    return make_mesh(shape, axes, devices=devices)
