"""Pure-jnp / numpy oracles for the level-decomposition mGEMM."""
import jax.numpy as jnp
import numpy as np


def mgemm_levels_ref(A, B, *, levels: int, out_dtype=jnp.float32):
    """sum_t 1[A>=t] @ 1[B>=t] — exact min-plus GEMM for ints in [0, levels]."""
    acc = jnp.zeros((A.shape[0], B.shape[1]), jnp.float32)
    for t in range(1, levels + 1):
        acc += (A >= t).astype(jnp.float32) @ (B >= t).astype(jnp.float32)
    return acc.astype(out_dtype)


def metric2_levels_planes_ref(Pa, Pb):
    """Numpy oracle for the field-major packed-plane contraction.

    Pa (levels, kb, m), Pb (levels, kb, n) uint8 -> (m, n) float64 numerator.
    Unpacks LSB-first along the byte axis, like ``planes.decode_bitplanes``.
    """
    Pa, Pb = np.asarray(Pa), np.asarray(Pb)
    at = np.unpackbits(Pa, axis=1, bitorder="little").astype(np.float64)
    bt = np.unpackbits(Pb, axis=1, bitorder="little").astype(np.float64)
    return np.einsum("tqm,tqn->mn", at, bt)
