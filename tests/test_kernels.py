"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles.

Sweeps shapes (block-aligned, ragged, smaller-than-block) and dtypes per the
deliverable contract.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mgemm import mgemm_xla
from repro.core.synthetic import random_integer_vectors
from repro.kernels.czek3 import czek3_step, czek3_step_ref
from repro.kernels.mgemm import czek2_metric, czek2_metric_ref, mgemm, mgemm_ref
from repro.kernels.mgemm_levels import (
    mgemm_levels,
    mgemm_levels_ref,
    mgemm_levels_xla,
)

# small blocks so CPU interpret mode exercises multi-block grids
BLK = dict(bm=8, bn=16, bk=32)
SHAPES = [
    (8, 32, 16),     # exactly one block
    (16, 64, 32),    # multi-block all dims
    (8, 32, 16 + 5), # ragged n
    (11, 45, 7),     # ragged everything, k < bk
    (24, 96, 33),
]


def _rand(m, k, n, dtype, seed=0):
    rng = np.random.default_rng(seed)
    A = rng.random((m, k)).astype(dtype) * 4
    B = rng.random((k, n)).astype(dtype) * 4
    return A, B


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("dtype", [np.float32, np.float16, jnp.bfloat16])
def test_mgemm_pallas_vs_ref(m, k, n, dtype):
    A, B = _rand(m, k, n, np.float32, seed=m * k + n)
    A = jnp.asarray(A, dtype)
    B = jnp.asarray(B, dtype)
    got = mgemm(A, B, interpret=True, **BLK)
    want = mgemm_ref(A, B)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-6, atol=2e-6)


@pytest.mark.parametrize("k_chunk", [1, 4, 8])
def test_mgemm_k_chunk_sweep(k_chunk):
    A, B = _rand(16, 64, 24, np.float32)
    got = mgemm(jnp.asarray(A), jnp.asarray(B), interpret=True, k_chunk=k_chunk, **BLK)
    want = mgemm_ref(jnp.asarray(A), jnp.asarray(B))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_mgemm_pallas_vs_xla_impl():
    A, B = _rand(13, 50, 21, np.float32, seed=3)
    a, b = jnp.asarray(A), jnp.asarray(B)
    np.testing.assert_allclose(
        np.asarray(mgemm(a, b, interpret=True, **BLK)),
        np.asarray(mgemm_xla(a, b)),
        rtol=1e-6,
    )


def test_mgemm_exact_on_integers():
    """Integer inputs: the kernel must be bit-exact vs the oracle."""
    V = random_integer_vectors(64, 24, max_value=7, seed=1)
    A = jnp.asarray(V.T)
    B = jnp.asarray(V)
    got = np.asarray(mgemm(A, B, interpret=True, **BLK))
    want = np.asarray(mgemm_ref(A, B))
    assert (got == want).all()


@pytest.mark.parametrize("m,k,n", SHAPES[:3])
def test_czek2_fused_metric(m, k, n):
    A, B = _rand(m, k, n, np.float32, seed=7)
    A, B = jnp.asarray(A), jnp.asarray(B)
    sa = A.sum(axis=1)
    sb = B.sum(axis=0)
    got = czek2_metric(A, B, sa, sb, interpret=True, **BLK)
    want = czek2_metric_ref(A, B, sa, sb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-6)


# ----------------------------------------------------------- levels (MXU) --


@pytest.mark.parametrize("levels", [1, 2, 3, 7])
@pytest.mark.parametrize("m,k,n", [(8, 32, 16), (13, 40, 21)])
def test_levels_exact_for_quantized(levels, m, k, n):
    rng = np.random.default_rng(levels)
    A = rng.integers(0, levels + 1, (m, k)).astype(np.float32)
    B = rng.integers(0, levels + 1, (k, n)).astype(np.float32)
    a, b = jnp.asarray(A), jnp.asarray(B)
    want = np.asarray(mgemm_ref(a, b))  # true min-plus
    got = np.asarray(mgemm_levels(a, b, levels=levels, interpret=True, bm=8, bn=16, bk=32))
    assert (got == want).all(), "level decomposition must be EXACT for ints <= L"
    got_ref = np.asarray(mgemm_levels_ref(a, b, levels=levels))
    assert (got_ref == want).all()
    got_xla = np.asarray(mgemm_levels_xla(a, b, levels=levels))
    assert (got_xla == want).all()


def test_levels_sorenson_binary_case():
    """L=1 is the paper's §2.3 Sorenson fast path: min == AND == product."""
    rng = np.random.default_rng(0)
    A = (rng.random((16, 64)) < 0.3).astype(np.float32)
    B = (rng.random((64, 8)) < 0.3).astype(np.float32)
    got = np.asarray(mgemm_levels(jnp.asarray(A), jnp.asarray(B), levels=1,
                                  interpret=True, bm=8, bn=8, bk=32))
    want = A @ B
    assert (got == want).all()


# ------------------------------------------------------------- czek3 step --


@pytest.mark.parametrize("nf,m,n", [(32, 8, 16), (45, 11, 7), (64, 24, 24)])
def test_czek3_fused_step(nf, m, n):
    rng = np.random.default_rng(nf)
    own = jnp.asarray(rng.random((nf, m)).astype(np.float32) * 3)
    x = jnp.asarray(rng.random((nf,)).astype(np.float32) * 3)
    right = jnp.asarray(rng.random((nf, n)).astype(np.float32) * 3)
    got = czek3_step(own, x, right, interpret=True, **BLK)
    want = czek3_step_ref(own, x, right)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_czek3_equals_unfused_composition():
    """Fused kernel == materialize X_j then 2-way mGEMM (paper's formulation)."""
    rng = np.random.default_rng(5)
    nf, m, n = 40, 12, 9
    own = jnp.asarray(rng.integers(0, 8, (nf, m)).astype(np.float32))
    x = jnp.asarray(rng.integers(0, 8, (nf,)).astype(np.float32))
    right = jnp.asarray(rng.integers(0, 8, (nf, n)).astype(np.float32))
    X = jnp.minimum(own, x[:, None])
    want = np.asarray(mgemm_ref(X.T, right))
    got = np.asarray(czek3_step(own, x, right, interpret=True, **BLK))
    assert (got == want).all()
