"""Synthetic LM token pipeline: deterministic, shard-aware, prefetched.

Every batch is a pure function of (seed, step, shard), so restarts resume
bit-identically from a checkpointed step with no data-state to persist, and
each data-parallel host generates only its own slice — the property a real
distributed loader must have, realized here with a synthetic source.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np


@dataclass
class SyntheticTokens:
    vocab_size: int
    batch: int  # global batch
    seq_len: int
    seed: int = 0
    shard: int = 0  # this host's data shard
    num_shards: int = 1
    family: str = "dense"  # encdec/vlm need extra fields
    d_model: int = 0

    @property
    def local_batch(self) -> int:
        assert self.batch % self.num_shards == 0
        return self.batch // self.num_shards

    def batch_at(self, step: int) -> dict:
        """Deterministic batch for a global step (this shard's slice)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard])
        )
        B, S = self.local_batch, self.seq_len
        # zipf-flavored token distribution, avoiding id 0 (pad)
        z = rng.zipf(1.3, size=(B, S + 1))
        toks = (z % (self.vocab_size - 1)) + 1
        out = {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
        if self.family == "encdec":
            out["src_embeds"] = rng.standard_normal(
                (B, S, self.d_model), dtype=np.float32
            ) * 0.02
        if self.family == "vlm":
            out["embeds"] = rng.standard_normal(
                (B, S, self.d_model), dtype=np.float32
            ) * 0.02
            out.pop("tokens")
        return out

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PrefetchIterator:
    """Background-thread prefetch (depth-k queue) over a batch source."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self._source = source
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        step, batch = self._q.get()
        return step, batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
