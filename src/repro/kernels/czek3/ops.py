"""jit'd wrappers for the fused 3-way slice kernels.

These are the entry points the ``TileExecutor`` dispatches 3-way pipeline
slices to (``TileExecutor.threeway_slice``) — they select interpret mode
off-TPU and forward to the Pallas kernels in ``kernel.py``.  The
``*_levels`` variant consumes packed bit-planes in the documented
(levels, kb, w) uint8 layout (docs/BITPLANE_FORMAT.md); on the plane-ring
campaign path those planes are byte-range views of the ring payload.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import (
    threeway_batch_levels_pallas,
    threeway_batch_pallas,
    threeway_step_pallas,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def threeway_step(own, x, right, *, combine, **kw):
    """Metric-generic fused 3-way pipeline step (X_j never touches HBM).

    own (n_f, m), x (n_f,) single pipeline column, right (n_f, n) ->
    (m, n).  Single-column form kept for benchmarks/oracles; the executor
    runs the batched variants below."""
    kw.setdefault("interpret", not _on_tpu())
    return threeway_step_pallas(own, x, right, combine=combine, **kw)


def threeway_batch(own, X, right, *, combine, **kw):
    """All L pipeline columns of one slice in a single fused launch.

    own (n_f, m), X (n_f, L), right (n_f, n) -> (L, m, n) value-operand
    form (``path3 == "fused-vpu"``)."""
    kw.setdefault("interpret", not _on_tpu())
    return threeway_batch_pallas(own, X, right, combine=combine, **kw)


def threeway_batch_levels(Pown, PX, Pright, **kw):
    """Level-decomposed batched slice on packed bit-planes (min combine).

    Pown (levels, kb, m), PX (levels, kb, L), Pright (levels, kb, n) ->
    (L, m, n).  The X_j plane is a packed AND in VMEM (one VPU op per 8
    fields), the contraction runs on the MXU; operands arrive pre-encoded
    (ring payload or ``encode_bitplanes``), never re-encoded here."""
    kw.setdefault("interpret", not _on_tpu())
    return threeway_batch_levels_pallas(Pown, PX, Pright, **kw)


def czek3_step(own, x, right, **kw):
    kw.setdefault("combine", jnp.minimum)
    return threeway_step(own, x, right, **kw)
