"""Per-architecture smoke tests: reduced config, one forward + one train-grad
step + one decode step on CPU; asserts output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, get_smoke_config, list_archs
from repro.models import api
from repro.models.common import param_count

LM_ARCHS = [a for a in list_archs(include_comet=False)]


def _batch_for(cfg, B=2, S=32, key=None):
    key = key or jax.random.PRNGKey(0)
    kt, kl, ke = jax.random.split(key, 3)
    batch = {
        "labels": jax.random.randint(kl, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "encdec":
        batch["src_embeds"] = jax.random.normal(ke, (B, S, cfg.d_model)) * 0.02
        batch["tokens"] = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
    elif cfg.family == "vlm":
        # stub frontend: precomputed patch embeddings
        batch["embeds"] = jax.random.normal(ke, (B, S, cfg.d_model)) * 0.02
    else:
        batch["tokens"] = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_forward_and_grad(arch):
    cfg = get_smoke_config(arch)
    params = api.init_model(cfg, jax.random.PRNGKey(1))
    assert param_count(params) > 0
    batch = _batch_for(cfg)
    logits, _ = api.model_forward(cfg, params, batch)
    B = batch["labels"].shape[0]
    S = batch["labels"].shape[1]
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: non-finite logits"

    loss, grads = jax.value_and_grad(lambda p: api.model_loss(cfg, p, batch))(params)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    leaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in leaves), f"{arch}: NaN grads"
    # a train step must move the loss: a small-enough SGD step along -grad
    # decreases it (backtracking: a fixed lr can overshoot on some inits)
    for lr in (0.5, 0.1, 0.02):
        params2 = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        loss2 = float(api.model_loss(cfg, params2, batch))
        if loss2 < float(loss) + 1e-3:
            break
    assert loss2 < float(loss) + 1e-3, f"{arch}: SGD step did not reduce loss"


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    params = api.init_model(cfg, jax.random.PRNGKey(2))
    B, max_len = 2, 16
    src = None
    if cfg.family == "encdec":
        src = jax.random.normal(jax.random.PRNGKey(3), (B, 8, cfg.d_model)) * 0.02
    cache = api.init_cache(cfg, params, B, max_len, src_embeds=src)
    tok = jnp.zeros((B, 1), jnp.int32)
    for step in range(3):
        logits, cache = api.decode_step(cfg, params, cache, tok, step)
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all(), f"{arch} step {step}"
        tok = logits.argmax(-1).astype(jnp.int32)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_full_config_instantiable(arch):
    """The exact assigned config must build (metadata only, no allocation)."""
    cfg = get_config(arch)
    spec = {
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab_size)
    assert got == spec, f"{arch}: {got} != {spec}"


def test_ssm_hybrid_extras():
    assert get_config("mamba2-1.3b").ssm_state == 128
    assert get_config("zamba2-1.2b").ssm_state == 64
    assert get_config("zamba2-1.2b").hybrid_attn_every == 6
    assert get_config("grok-1-314b").n_experts == 8
    assert get_config("grok-1-314b").experts_per_token == 2
    assert get_config("granite-moe-3b-a800m").n_experts == 40
    assert get_config("granite-moe-3b-a800m").experts_per_token == 8
    assert get_config("qwen2-vl-2b").mrope_sections == (16, 24, 24)
    assert get_config("seamless-m4t-large-v2").n_enc_layers == 24
