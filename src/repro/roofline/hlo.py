"""Loop-aware HLO cost model: flops, bytes and collective traffic.

XLA's ``compiled.cost_analysis()`` counts every computation ONCE — a
``while`` body (the layer scan, flash-attention chunk scans, the mGEMM
K-chunk scan) is priced as a single iteration (verified empirically), which
undercounts a 95-layer model by ~95x.  This module re-derives the three
roofline inputs from the compiled (post-SPMD) HLO text with loop awareness:

* computation multipliers — product of enclosing ``while`` trip counts
  (recovered from the loop condition's ``compare(iv, constant(N))``) along
  the call graph (fusion ``calls=``, ``to_apply``, while ``body=``);
* flops — dots: 2 * prod(result) * prod(contracting dims); elementwise
  arithmetic (incl. inside fusion bodies): prod(result); reduces:
  prod(operand);
* bytes — per *materializing* op (fusion calls, dots, copies, converts,
  reduces, collectives): result bytes + named-operand bytes via a symbol
  table; ops inside fusion bodies are register traffic and not counted;
* collectives — operand bytes per the assignment ("sum operand sizes") plus
  modeled ring wire traffic: all-reduce 2s(n-1)/n, all-gather s(n-1),
  reduce-scatter s(n-1)/n, all-to-all s(n-1)/n, collective-permute s.

All numbers are per-device (the SPMD-partitioned module).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# first operand of `op(...)`: newer XLA prints `op(%name, ...)` / `op(name,
# ...)`, older versions inline the operand type first: `op(f32[8,8]{1,0}
# %name, ...)` — skip the optional type prefix, capture the name.
_OPERAND = r"(?:[a-z0-9]+\[[0-9,]*\](?:\{[0-9,]*\})?\s+)?%?([\w.\-]+)"
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^=]*?\)|[a-z0-9]+\[[0-9,]*\][^\s]*))\s+([\w\-]+)\("
)
_ELEMWISE = {
    "add", "subtract", "multiply", "divide", "minimum", "maximum", "power",
    "exponential", "log", "rsqrt", "sqrt", "tanh", "negate", "abs", "sign",
    "floor", "ceil", "round-nearest-afz", "logistic", "cosine", "sine",
    "expm1", "log1p", "atan2", "remainder", "cbrt", "erf",
}
# ops priced as HBM traffic (operands + result).  broadcast/iota/reshape/
# slice/pad are layout ops XLA almost always fuses — excluded to avoid
# phantom traffic.
_MATERIALIZING = {
    "fusion", "copy", "convert", "transpose", "reduce", "dot",
    "concatenate", "dynamic-slice", "dynamic-update-slice",
    "gather", "scatter", "select", "compare", "sort", "rng",
    "select-and-scatter", "reduce-window", "convolution",
} | _ELEMWISE
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_elems(shape_str: str) -> int:
    total = 0
    for _, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _group_size(line: str, total_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    if "source_target_pairs=" in line:
        return 2
    return total_devices


def _wire_factor(op: str, n: int) -> float:
    if n <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (n - 1) / n
    if op == "all-gather":
        return float(n - 1)
    if op in ("reduce-scatter", "all-to-all"):
        return (n - 1) / n
    return 1.0


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0  # fused model: each materialized tensor written + read once
    bytes_upper: float = 0.0  # per-consumer operand counting (no fusion credit)
    operand_bytes: dict = field(default_factory=lambda: defaultdict(float))
    wire_bytes: dict = field(default_factory=lambda: defaultdict(float))
    counts: dict = field(default_factory=lambda: defaultdict(int))
    static_counts: dict = field(default_factory=lambda: defaultdict(int))

    @property
    def total_operand_bytes(self) -> float:
        return float(sum(self.operand_bytes.values()))

    @property
    def total_wire_bytes(self) -> float:
        return float(sum(self.wire_bytes.values()))

    def collectives_dict(self):
        return {
            "operand_bytes": {k: float(v) for k, v in self.operand_bytes.items()},
            "wire_bytes": {k: float(v) for k, v in self.wire_bytes.items()},
            "counts": dict(self.counts),
            "static_counts": dict(self.static_counts),
            "total_operand_bytes": self.total_operand_bytes,
            "total_wire_bytes": self.total_wire_bytes,
        }


def _split_computations(hlo_text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    current = None
    for line in hlo_text.splitlines():
        stripped = line.rstrip()
        if stripped.endswith("{") and not line.startswith(" " * 4):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", stripped.strip())
            if m:
                current = m.group(1)
                comps[current] = []
                continue
        if current is not None:
            comps[current].append(line)
    return comps


def _trip_count(cond_lines: list[str]) -> int:
    consts = {}
    for l in cond_lines:
        m = re.match(r"\s*%?([\w.\-]+)\s*=\s*[a-z0-9]+\[\]\s*constant\((\d+)\)", l)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for l in cond_lines:
        if "compare(" in l:
            for a in re.findall(r"%([\w.\-]+)", l[l.index("compare(") :]):
                if a in consts:
                    return max(1, consts[a])
    return max([1] + list(consts.values()))


def analyze_hlo(hlo_text: str, total_devices: int) -> HloCost:
    comps = _split_computations(hlo_text)
    m_entry = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo_text, re.MULTILINE)
    entry = m_entry.group(1) if m_entry else None

    # call graph + while trip counts
    body_trip: dict[str, int] = {}
    calls: dict[str, set[str]] = defaultdict(set)
    fusion_bodies: set[str] = set()
    reduce_bodies: set[str] = set()
    for name, lines in comps.items():
        for l in lines:
            if "while(" in l:
                mb = re.search(r"body=%?([\w.\-]+)", l)
                mc = re.search(r"condition=%?([\w.\-]+)", l)
                if mb and mc:
                    body_trip[mb.group(1)] = _trip_count(comps.get(mc.group(1), []))
                    calls[name].add(mb.group(1))
                    calls[name].add(mc.group(1))
            for m in re.finditer(r"calls=%?([\w.\-]+)", l):
                calls[name].add(m.group(1))
                fusion_bodies.add(m.group(1))
            for m in re.finditer(r"to_apply=%?([\w.\-]+)", l):
                calls[name].add(m.group(1))
                reduce_bodies.add(m.group(1))
            m = re.search(r"branch_computations=\{([^}]*)\}", l)
            if m:
                for b in m.group(1).split(","):
                    calls[name].add(b.strip().lstrip("%"))

    # fusions whose root is a dynamic-update-slice write only the update
    # region in-place (stacked grad accumulators, remat stashes, KV caches):
    # price them at 2x the update operand, not the full carried buffer.
    fusion_dus_bytes: dict[str, int] = {}
    for fname in fusion_bodies:
        lines = comps.get(fname, [])
        shapes_local = {}
        for l in lines:
            mi = _INSTR_RE.match(l)
            if mi:
                shapes_local[mi.group(1)] = mi.group(2)
        for l in lines:
            if "ROOT" not in l:
                continue
            mi = _INSTR_RE.match(l)
            if not mi:
                continue
            if mi.group(3) == "dynamic-update-slice":
                paren = l[mi.end():].split("),")[0]
                on = re.findall(r"%([\w.\-]+)", paren)
                upd = shapes_local.get(on[1]) if len(on) > 1 else None
                if upd:
                    fusion_dus_bytes[fname] = 2 * shape_bytes(upd)

    mult: dict[str, float] = {}

    def resolve(name: str, seen=()) -> float:
        if name in mult:
            return mult[name]
        if name in seen:
            return 1.0
        callers = [c for c, callees in calls.items() if name in callees]
        m = 1.0 if not callers else max(resolve(c, seen + (name,)) for c in callers)
        if name in body_trip:
            m *= body_trip[name]
        mult[name] = m
        return m

    cost = HloCost()
    for name, lines in comps.items():
        if name in reduce_bodies:
            continue  # scalar combiner bodies: negligible
        factor = resolve(name)
        fused = name in fusion_bodies
        # symbol table for operand lookup
        shapes: dict[str, str] = {}
        for l in lines:
            mi = _INSTR_RE.match(l)
            if mi:
                shapes[mi.group(1)] = mi.group(2)

        for l in lines:
            mi = _INSTR_RE.match(l)
            if not mi:
                continue
            iname, rshape, op = mi.group(1), mi.group(2), mi.group(3)
            base_op = re.sub(r"-(start|done)$", "", op)

            # ENTRY parameters are module inputs living in HBM: their first
            # read is real traffic no consumer op accounts for under the
            # fusion-credit `bytes` model (consumers only price their own
            # results).  `bytes_upper` already charges consumers for every
            # named-operand read, parameters included — no extra term there.
            if op == "parameter":
                if name == entry:
                    cost.bytes += shape_bytes(rshape)
                continue

            # ---- collectives ----
            if base_op in _COLLECTIVES:
                if op.endswith("-done"):
                    continue
                n = _group_size(l, total_devices)
                rbytes = shape_bytes(rshape)
                if op.endswith("-start"):
                    rbytes //= 2
                if base_op == "all-gather":
                    abytes = rbytes // max(n, 1)
                elif base_op == "reduce-scatter":
                    abytes = rbytes * n
                else:
                    abytes = rbytes
                cost.operand_bytes[base_op] += abytes * factor
                cost.wire_bytes[base_op] += abytes * _wire_factor(base_op, n) * factor
                cost.counts[base_op] += int(factor)
                cost.static_counts[base_op] += 1
                cost.bytes += (abytes + rbytes) * factor
                continue

            # ---- flops ----
            if op == "dot":
                k = 1
                mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", l)
                lhs = re.search(r"dot\(" + _OPERAND, l)
                if mc and lhs and lhs.group(1) in shapes:
                    dims_str = _SHAPE_RE.search(shapes[lhs.group(1)])
                    if dims_str:
                        dims = [int(d) for d in dims_str.group(2).split(",") if d]
                        for ci in mc.group(1).split(","):
                            if ci:
                                k *= dims[int(ci)]
                cost.flops += 2.0 * shape_elems(rshape) * k * factor
            elif op in _ELEMWISE or op in ("compare", "select", "clamp", "and",
                                           "or", "xor", "not"):
                cost.flops += shape_elems(rshape) * factor
            elif op == "reduce" or op == "reduce-window":
                ml = re.search(r"reduce(?:-window)?\(" + _OPERAND, l)
                if ml and ml.group(1) in shapes:
                    cost.flops += shape_elems(shapes[ml.group(1)]) * factor
                else:
                    cost.flops += shape_elems(rshape) * factor
            elif op == "convolution":
                cost.flops += 2.0 * shape_elems(rshape) * factor  # lower bound

            # ---- bytes (HBM traffic models) ------------------------------
            # `bytes`: each materialized tensor is written once and read
            #   once by its consumers (TPU-fusion-credit model);
            # `bytes_upper`: every op re-reads all named operands (the
            #   CPU-compiled fusion granularity — no producer fusion).
            # DUS/DS/gather/scatter price only the touched region, never
            # the full (possibly stacked-weights/cache) buffer.
            if not fused and op in _MATERIALIZING:
                if op == "fusion":
                    mcall = re.search(r"calls=%?([\w.\-]+)", l)
                    if mcall and mcall.group(1) in fusion_dus_bytes:
                        b2 = fusion_dus_bytes[mcall.group(1)]
                        cost.bytes += b2 * factor
                        cost.bytes_upper += b2 * factor
                        continue
                if op in ("dynamic-slice", "gather"):
                    b2 = 2 * shape_bytes(rshape)
                    bu = b2
                elif op in ("dynamic-update-slice", "scatter"):
                    paren = l[mi.end():].split("),")[0]
                    onames = re.findall(r"%([\w.\-]+)", paren)
                    upd = shapes.get(onames[1]) if len(onames) > 1 else None
                    b2 = 2 * shape_bytes(upd) if upd else shape_bytes(rshape)
                    bu = b2
                else:
                    b2 = 2 * shape_bytes(rshape)
                    bu = shape_bytes(rshape)
                    paren = l[mi.end():].split("),")[0]
                    for oname in re.findall(r"%([\w.\-]+)", paren):
                        if oname in shapes:
                            bu += shape_bytes(shapes[oname])
                cost.bytes += b2 * factor
                cost.bytes_upper += bu * factor
    return cost
