"""Family-dispatching model API used by smoke tests, the trainer, the
serving engine, and the dry-run."""
from __future__ import annotations


from repro.models import encdec as encdec_mod
from repro.models import transformer as tf_mod
from repro.models.common import ModelConfig


def init_model(cfg: ModelConfig, key):
    if cfg.family == "encdec":
        return encdec_mod.init_encdec(cfg, key)
    return tf_mod.init_lm(cfg, key)


def model_forward(cfg: ModelConfig, params, batch):
    if cfg.family == "encdec":
        return encdec_mod.encdec_forward(cfg, params, batch["src_embeds"], batch["tokens"])
    return tf_mod.lm_forward(
        cfg, params, batch.get("tokens"), embeds=batch.get("embeds")
    )


def model_loss(cfg: ModelConfig, params, batch):
    if cfg.family == "encdec":
        return encdec_mod.encdec_loss(cfg, params, batch)
    return tf_mod.lm_loss(cfg, params, batch)


def init_cache(cfg: ModelConfig, params, batch_size: int, max_len: int, src_embeds=None):
    if cfg.family == "encdec":
        return encdec_mod.init_encdec_cache(cfg, params, src_embeds, batch_size, max_len)
    return tf_mod.init_decode_cache(cfg, batch_size, max_len)


def decode_step(cfg: ModelConfig, params, cache, tokens, cache_index):
    if cfg.family == "encdec":
        return encdec_mod.encdec_decode_step(cfg, params, cache, tokens, cache_index)
    return tf_mod.lm_decode_step(cfg, params, cache, tokens, cache_index)


def param_sharding_rules(cfg: ModelConfig):
    if cfg.family == "encdec":
        return encdec_param_rules(cfg)
    return tf_mod.param_sharding_rules(cfg)


def encdec_param_rules(cfg: ModelConfig):
    from jax.sharding import PartitionSpec as P

    F = ("pod", "data")  # FSDP axes (filtered to the active mesh)
    attn = {
        "wq": P(None, F, "model"),
        "wk": P(None, F, "model"),
        "wv": P(None, F, "model"),
        "wo": P(None, "model", F),
    }
    mlp = {"wi": P(None, F, "model"), "wg": P(None, F, "model"),
           "wo": P(None, "model", F)}
    return {
        "embed": P("model", F),
        "enc_layers": {"ln1": P(None), "attn": attn, "ln2": P(None), "mlp": mlp},
        "enc_ln": P(None),
        "dec_layers": {
            "ln1": P(None), "attn": attn, "lnx": P(None), "xattn": attn,
            "ln2": P(None), "mlp": mlp,
        },
        "final_ln": P(None),
        "lm_head": P(F, "model"),
    }
