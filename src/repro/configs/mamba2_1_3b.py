"""mamba2-1.3b [ssm] — arXiv:2405.21060 (unverified).

48L d_model=2048 (attention-free) vocab=50280, ssm_state=128 — SSD.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="mamba2-1.3b-smoke",
    n_layers=3,
    d_model=64,
    vocab_size=256,
    ssm_state=16,
    ssm_head_dim=16,
)
