"""ShardPrefetcher — background double-buffered staging loader.

The streaming pipeline's I/O half: a single worker thread fills reusable
staging buffers one chunk ahead of the consumer, so chunk ``s+1``'s disk
reads (mmap page faults + the copy into the staging buffer) overlap chunk
``s``'s device compute.  The consumer spends its wait inside XLA with the
GIL released, which is what lets the worker's numpy copies make progress —
the classic CPU-side realization of the double-buffered HDD->accelerator
tile pipeline (arXiv 1302.4332).

Buffer discipline is a free-queue / ready-queue pair (no modulo-index
races): the worker takes an empty buffer from the free queue, fills it,
and posts it on the ready queue; the consumer iterates ``(index, buffer)``
pairs and MUST hand each buffer back via ``release()`` once the device
owns the data.  With two buffers the worker is therefore never more than
one chunk ahead — bounding peak host bytes at exactly
``StreamPlan.peak_host_bytes``.

Overlap accounting (always on — two clock reads per chunk, negligible at
chunk granularity): ``stage_seconds`` is worker time spent inside
``fill`` and ``stall_seconds`` is consumer time blocked on the ready
queue; their gap is the staging-vs-compute overlap the pipeline exists to
create, surfaced in ``meta["stream"]``.  When ``repro.obs`` tracing is
enabled the worker additionally runs under the creating thread's copied
context — so its ``prefetch-stage`` spans carry the campaign span as
parent — at zero cost when disabled.

Error handling is symmetrical and leak-free (pinned by tests/test_stream.py):

* a ``fill`` exception is captured, posted on the ready queue, and
  re-raised in the consumer thread on its next iteration;
* consumer-side exceptions unwind through ``__exit__``, which unblocks and
  joins the worker — no leaked threads either way.
"""
from __future__ import annotations

import contextvars
import queue
import threading
import time

from repro.obs import trace as obs

__all__ = ["ShardPrefetcher"]

_DONE = object()  # worker finished every item
_STOP = object()  # consumer shut down; unblocks a worker waiting on free_q


class _WorkerError:
    def __init__(self, exc):
        self.exc = exc


class ShardPrefetcher:
    """Iterate ``(index, buffer)`` with the fills running one item ahead.

    ``fill(index, buffer)`` stages item ``index`` into ``buffer`` in place;
    ``buffers`` is the reusable staging pool (usually two arrays of one
    chunk each).  Use as a context manager::

        with ShardPrefetcher(fill, n_items, buffers) as pf:
            for idx, buf in pf:
                consume(buf)
                pf.release(buf)

    After (or during) iteration, ``pf.stage_seconds`` / ``pf.stall_seconds``
    report worker fill time and consumer ready-queue wait time.
    """

    def __init__(self, fill, n_items: int, buffers):
        if not buffers:
            raise ValueError("need at least one staging buffer")
        self._fill = fill
        self._n_items = n_items
        self._free = queue.Queue()
        for buf in buffers:
            self._free.put(buf)
        self._ready = queue.Queue()
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._run, name="repro-stream-prefetch", daemon=True
        )
        self._started = False
        self.stage_seconds = 0.0  # written by the worker thread only
        self.stall_seconds = 0.0  # written by the consumer thread only
        # Carry the creating context's open-span stack into the worker so
        # staging spans nest under the campaign span (tracing only).
        self._ctx = contextvars.copy_context() if obs.enabled() else None

    # -- worker -------------------------------------------------------------

    def _run(self):
        if self._ctx is not None:
            self._ctx.run(self._run_inner)
        else:
            self._run_inner()

    def _run_inner(self):
        try:
            for idx in range(self._n_items):
                buf = self._free.get()
                if buf is _STOP or self._stop.is_set():
                    return
                t0 = time.perf_counter()
                with obs.span("prefetch-stage") as sp:
                    self._fill(idx, buf)
                    sp.add(chunk=idx)
                self.stage_seconds += time.perf_counter() - t0
                self._ready.put((idx, buf))
        except BaseException as exc:  # propagated to the consumer
            self._ready.put(_WorkerError(exc))
        else:
            self._ready.put(_DONE)

    # -- consumer -----------------------------------------------------------

    def __enter__(self):
        self._thread.start()
        self._started = True
        return self

    def __iter__(self):
        while True:
            t0 = time.perf_counter()
            item = self._ready.get()
            self.stall_seconds += time.perf_counter() - t0
            if item is _DONE:
                return
            if isinstance(item, _WorkerError):
                raise item.exc
            yield item

    def release(self, buf) -> None:
        """Return a consumed buffer to the pool (the worker may refill it)."""
        self._free.put(buf)

    def close(self) -> None:
        """Stop the worker and join it (idempotent; never leaks a thread)."""
        self._stop.set()
        self._free.put(_STOP)  # unblock a worker waiting for a buffer
        if self._started:
            self._thread.join()

    def __exit__(self, *exc_info):
        self.close()
        return False
