from repro.models.common import ModelConfig  # noqa: F401
