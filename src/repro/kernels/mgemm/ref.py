"""Pure-jnp oracle for the mGEMM kernel."""
import jax.numpy as jnp


def mgemm_ref(A, B, out_dtype=jnp.float32):
    """out[i, j] = sum_k min(A[i, k], B[k, j]) — dense broadcast (small only)."""
    m = jnp.minimum(A[:, :, None], B[None, :, :]).astype(jnp.float32)
    return m.sum(axis=1).astype(out_dtype)


def czek2_metric_ref(A, B, sa, sb, out_dtype=jnp.float32):
    n = mgemm_ref(A, B, jnp.float32)
    return (2.0 * n / (sa[:, None] + sb[None, :])).astype(out_dtype)
