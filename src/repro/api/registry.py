"""Metric registry: names -> MetricSpec, the extension point of the API.

``register_metric`` is how a new similarity metric plugs into the whole
distributed machinery (2-way ring, 3-way tetrahedral schedule, round-robin,
staging, checksums) without touching any engine code.  The built-in entries:

* ``czekanowski`` — the paper's Proportional Similarity (min-plus combine),
  dispatching through the mgemm impl registry (XLA / Pallas / levels).
* ``ccc`` — the Custom Correlation Coefficient family of the companion paper
  (Joubert et al., arXiv:1705.08213): dot-product combine with per-vector
  normalization.  Its registration below is the reference example of adding
  a metric: an elementwise combine, a per-vector statistic, and the
  numerator/denominator assemblies — ~50 lines all told.
* ``sorenson`` — Sørensen–Dice for binary (presence/absence) data (paper
  §2.3): ``2|A∩B| / (|A|+|B|)``.  On {0,1} data this is exactly the
  Czekanowski arithmetic (min == AND, sums == popcounts), so it reuses the
  same assembly functions — identical fp ops, bit-identical checksums on
  every shared path — while its oracles are an *independent* boolean
  AND-dot formulation.  Under ``impl="levels"``, ``levels=1`` it rides the
  popcount bit-GEMM fast path (``path == "fused-popcount"``).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.metric_spec import (  # noqa: F401  (family_* re-exported)
    CZEKANOWSKI,
    MetricSpec,
    batch_lead,
    family_key,
    group_families,
    plane_native,
)
from repro.core.metrics import safe_denom

__all__ = [
    "MetricSpec",
    "UnknownMetricError",
    "register_metric",
    "get_metric",
    "available_metrics",
    "family_key",
    "group_families",
    "plane_native",
    "batch_lead",
    "CCC",
    "SORENSON",
]


class UnknownMetricError(KeyError):
    """Requested metric name is not registered."""


_METRICS: dict[str, MetricSpec] = {}


def register_metric(spec: MetricSpec, *, overwrite: bool = False) -> MetricSpec:
    """Add a MetricSpec to the registry (returns it, so usable inline)."""
    if spec.name in _METRICS and not overwrite:
        raise ValueError(f"metric {spec.name!r} already registered")
    _METRICS[spec.name] = spec
    return spec


def get_metric(name: str) -> MetricSpec:
    try:
        return _METRICS[name]
    except KeyError:
        raise UnknownMetricError(
            f"unknown metric {name!r}; available: {available_metrics()}"
        ) from None


def available_metrics() -> list[str]:
    return sorted(_METRICS)


register_metric(CZEKANOWSKI)


# ----------------------------------------------------------------------------
# Custom Correlation Coefficient (arXiv:1705.08213 family): dot-product
# combine, per-vector 2-norm normalization.  Everything below is what a new
# metric costs — the engines, plans, ring, staging and checksums are shared.
# ----------------------------------------------------------------------------

def _ccc_stat(Vl):
    Vf = Vl.astype(jnp.float32)
    return (Vf * Vf).sum(axis=0)  # per-vector sum of squares


def _ccc_combine(a, b):
    # cast BEFORE multiplying: int8 ring payloads would overflow in products
    return a.astype(jnp.float32) * b.astype(jnp.float32)


def _ccc_contract(A, B):
    return jnp.dot(A.astype(jnp.float32), B.astype(jnp.float32))


def _ccc_assemble2(n2, si, sj):
    return n2 / safe_denom(jnp.sqrt(si * sj))


# Pallas-composable: elementwise sqrt/divide on the accumulator tile, same
# fp ops as _ccc_assemble2 so fused and out-of-kernel paths agree bitwise.
_ccc_assemble_tile = _ccc_assemble2


def _ccc_assemble3(b3, n2_pl, n2_pr, n2_lr, sp, sl, sr):
    d3 = jnp.sqrt(sp[:, None, None] * sl[None, :, None] * sr[None, None, :])
    return b3 / safe_denom(d3)


def _ccc_oracle2(V):
    V = np.asarray(V, np.float64)
    s = (V * V).sum(axis=0)
    return (V.T @ V) / safe_denom(np.sqrt(np.outer(s, s)))


def _ccc_oracle3(V):
    V = np.asarray(V, np.float64)
    s = (V * V).sum(axis=0)
    n3 = np.einsum("qi,qj,qk->ijk", V, V, V)
    d3 = np.sqrt(s[:, None, None] * s[None, :, None] * s[None, None, :])
    return n3 / safe_denom(d3)


# ----------------------------------------------------------------------------
# Sørensen–Dice (paper §2.3, binary presence/absence data).  For a, b in
# {0, 1}: min(a, b) == a AND b and the column sum IS the popcount, so the
# numerator/denominator arithmetic coincides with Czekanowski restricted to
# binary input — the spec deliberately REUSES the czek assembly callables
# (same fp ops object-for-object), which keeps sorenson bit-identical to
# czekanowski on every engine path while the oracles below are derived
# independently (boolean AND-dot, never min-plus).
# ----------------------------------------------------------------------------

def _sorenson_oracle2(V):
    B = np.asarray(V) != 0  # boolean presence/absence view
    inter = B.T.astype(np.float64) @ B.astype(np.float64)  # |A ∩ B| AND-dot
    s = B.sum(axis=0).astype(np.float64)
    return 2.0 * inter / safe_denom(s[:, None] + s[None, :])


def _sorenson_oracle3(V):
    B = np.asarray(V) != 0
    Bf = B.astype(np.float64)
    n2 = Bf.T @ Bf
    b3 = np.einsum("qi,qj,qk->ijk", Bf, Bf, Bf)
    n3 = n2[:, :, None] + n2[:, None, :] + n2[None, :, :] - b3
    s = Bf.sum(axis=0)
    d3 = s[:, None, None] + s[None, :, None] + s[None, None, :]
    return 1.5 * n3 / safe_denom(d3)


SORENSON = register_metric(MetricSpec(
    name="sorenson",
    description="Sørensen–Dice for binary data (paper §2.3): "
                "2 |A∩B| / (|A|+|B|) — Czekanowski restricted to {0,1}",
    ways=(2, 3),
    combine=jnp.minimum,
    stat=CZEKANOWSKI.stat,
    assemble2=CZEKANOWSKI.assemble2,
    assemble3=CZEKANOWSKI.assemble3,
    assemble_tile=CZEKANOWSKI.assemble_tile,
    uses_mgemm=True,
    needs_pair_terms=True,
    oracle2=_sorenson_oracle2,
    oracle3=_sorenson_oracle3,
))


CCC = register_metric(MetricSpec(
    name="ccc",
    description="Custom Correlation Coefficient (arXiv:1705.08213): "
                "Σ products / geometric-mean vector norms",
    ways=(2, 3),
    combine=_ccc_combine,
    stat=_ccc_stat,
    contract=_ccc_contract,
    assemble2=_ccc_assemble2,
    assemble3=_ccc_assemble3,
    assemble_tile=_ccc_assemble_tile,
    combine_sum_contract=True,  # jnp.dot == Σ products, the combine-sum
    uses_mgemm=False,
    needs_pair_terms=False,
    oracle2=_ccc_oracle2,
    oracle3=_ccc_oracle3,
))
