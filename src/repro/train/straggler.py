"""Straggler detection + mitigation hooks.

On a real pod, slow hosts show up as step-time outliers (bad HBM, thermal
throttle, a failing ICI link).  The watchdog keeps a rolling step-time
window; a step above ``threshold x median`` raises a StragglerEvent which
the trainer logs and counts.  Mitigation at scale (documented, simulated in
tests): (1) if a host is persistently slow, checkpoint + elastic restart
without it (the checkpoint layer already supports topology changes);
(2) within a run, the data pipeline's deterministic (seed, step, shard)
batches make it safe for a replacement host to take over a shard mid-run.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field


@dataclass
class StragglerEvent:
    step: int
    duration: float
    median: float


@dataclass
class StragglerWatchdog:
    window: int = 32
    threshold: float = 3.0
    min_samples: int = 5
    durations: deque = field(default_factory=lambda: deque(maxlen=128))
    events: list = field(default_factory=list)

    def observe(self, step: int, duration: float) -> StragglerEvent | None:
        med = self.median()
        self.durations.append(duration)
        if med is not None and duration > self.threshold * med:
            ev = StragglerEvent(step=step, duration=duration, median=med)
            self.events.append(ev)
            return ev
        return None

    def median(self) -> float | None:
        if len(self.durations) < self.min_samples:
            return None
        vals = sorted(self.durations)
        return vals[len(vals) // 2]
