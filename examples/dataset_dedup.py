"""The paper's technique inside the LM data pipeline: near-duplicate
detection over documents via all-pairs Czekanowski similarity of token
count-profiles (DESIGN.md §5).

    PYTHONPATH=src python examples/dataset_dedup.py
"""
import numpy as np

from repro.data.dedup import find_near_duplicates


def main():
    rng = np.random.default_rng(0)
    vocab = 50000
    docs = []
    # 60 random docs + 6 planted near-duplicates (90% token overlap)
    for _ in range(60):
        docs.append(rng.integers(0, vocab, rng.integers(200, 400)))
    for i in range(6):
        base = docs[i]
        mutated = base.copy()
        idx = rng.choice(len(base), len(base) // 10, replace=False)
        mutated[idx] = rng.integers(0, vocab, len(idx))
        docs.append(mutated)

    hits = find_near_duplicates(docs, vocab, threshold=0.85)
    print(f"{len(docs)} docs -> {len(hits)} near-duplicate pairs (c2 >= 0.85)")
    for i, j, sim in hits[:10]:
        print(f"  doc{i} ~ doc{j}: c2={sim:.3f}")
    planted = {(i, 60 + i) for i in range(6)}
    found = {(min(i, j), max(i, j)) for i, j, _ in hits}
    print(f"planted duplicates found: {len(planted & found)}/6")


if __name__ == "__main__":
    main()
