"""Pallas TPU kernel: blocked min-plus GEMM (the paper's mGEMM, §3.1).

TPU adaptation of the paper's modified-MAGMA GEMM.  The MXU cannot evaluate
``min`` inside its systolic array, so the contraction runs on the VPU:
HBM -> VMEM tiles via BlockSpec, fp32 accumulation in a VMEM scratch
accumulator, K-chunked broadcast-minimum + reduce inside the block.

Grid: (M/bm, N/bn, K/bk), K innermost so the accumulator tile stays resident
in VMEM across the contraction (standard Pallas matmul pattern).

Default tile (bm, bn, bk) = (128, 128, 512):
  VMEM working set = A tile 128*512*4 B + B tile 512*128*4 B + acc 128*128*4 B
                   = 256 KiB + 256 KiB + 64 KiB ≈ 0.6 MiB  « 16 MiB VMEM,
leaving room for double buffering of the input streams.  The inner k-chunk
(8) bounds the broadcast intermediate to 128*8*128*4 = 512 KiB of VREG/VMEM
traffic, aligned to the (8, 128) VPU vector register shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512
K_CHUNK = 8


def _mgemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k_steps: int, k_chunk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]  # (bm, bk)
    b = b_ref[...]  # (bk, bn)
    bm, bk = a.shape
    bn = b.shape[1]

    def body(t, acc):
        a_sub = jax.lax.dynamic_slice(a, (0, t * k_chunk), (bm, k_chunk))
        b_sub = jax.lax.dynamic_slice(b, (t * k_chunk, 0), (k_chunk, bn))
        m = jnp.minimum(a_sub[:, :, None], b_sub[None, :, :]).astype(jnp.float32)
        return acc + m.sum(axis=1)

    acc_ref[...] += jax.lax.fori_loop(
        0, bk // k_chunk, body, jnp.zeros((bm, bn), jnp.float32)
    )

    @pl.when(pl.program_id(2) == n_k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _metric_kernel(a_ref, b_ref, sa_ref, sb_ref, o_ref, acc_ref, *, n_k_steps, k_chunk):
    """mGEMM with fused Czekanowski epilogue: o = 2*acc / (sa_i + sb_j).

    Saves an HBM round-trip of the numerator matrix (bandwidth win recorded in
    EXPERIMENTS.md §Perf)."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    bm, bk = a.shape
    bn = b.shape[1]

    def body(t, acc):
        a_sub = jax.lax.dynamic_slice(a, (0, t * k_chunk), (bm, k_chunk))
        b_sub = jax.lax.dynamic_slice(b, (t * k_chunk, 0), (k_chunk, bn))
        m = jnp.minimum(a_sub[:, :, None], b_sub[None, :, :]).astype(jnp.float32)
        return acc + m.sum(axis=1)

    acc_ref[...] += jax.lax.fori_loop(
        0, bk // k_chunk, body, jnp.zeros((bm, bn), jnp.float32)
    )

    @pl.when(pl.program_id(2) == n_k_steps - 1)
    def _flush():
        sa = sa_ref[...]  # (bm, 1)
        sb = sb_ref[...]  # (1, bn)
        o_ref[...] = (2.0 * acc_ref[...] / (sa + sb)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "k_chunk", "interpret", "out_dtype"),
)
def mgemm_pallas(
    A,
    B,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    k_chunk: int = K_CHUNK,
    interpret: bool = False,
    out_dtype=jnp.float32,
):
    """out[i, j] = sum_k min(A[i, k], B[k, j]).  A (m, k), B (k, n)."""
    m, k = A.shape
    k2, n = B.shape
    assert k == k2
    # pad every dim to its block multiple; k pads with zeros on both operands
    # => min(0, 0) = 0 contributes nothing.
    mp, np_, kp = (-m) % bm, (-n) % bn, (-k) % bk
    if mp or kp:
        A = jnp.pad(A, ((0, mp), (0, kp)))
    if np_ or kp:
        B = jnp.pad(B, ((0, kp), (0, np_)))
    M, K = A.shape
    N = B.shape[1]
    n_k_steps = K // bk
    grid = (M // bm, N // bn, n_k_steps)
    out = pl.pallas_call(
        functools.partial(_mgemm_kernel, n_k_steps=n_k_steps, k_chunk=k_chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, t: (i, t)),
            pl.BlockSpec((bk, bn), lambda i, j, t: (t, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(A, B)
    return out[:m, :n]


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "k_chunk", "interpret", "out_dtype"),
)
def czek2_metric_pallas(
    A,
    B,
    sa,
    sb,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    k_chunk: int = K_CHUNK,
    interpret: bool = False,
    out_dtype=jnp.float32,
):
    """Fused 2-way metric: out[i,j] = 2*sum_k min(A[i,k],B[k,j]) / (sa_i+sb_j)."""
    m, k = A.shape
    n = B.shape[1]
    mp, np_, kp = (-m) % bm, (-n) % bn, (-k) % bk
    if mp or kp:
        A = jnp.pad(A, ((0, mp), (0, kp)))
    if np_ or kp:
        B = jnp.pad(B, ((0, kp), (0, np_)))
    # pad sums with 1 to avoid 0/0 in the padded epilogue region
    sa = jnp.pad(jnp.asarray(sa, jnp.float32), (0, mp), constant_values=1.0)[:, None]
    sb = jnp.pad(jnp.asarray(sb, jnp.float32), (0, np_), constant_values=1.0)[None, :]
    M, K = A.shape
    N = B.shape[1]
    n_k_steps = K // bk
    grid = (M // bm, N // bn, n_k_steps)
    out = pl.pallas_call(
        functools.partial(_metric_kernel, n_k_steps=n_k_steps, k_chunk=k_chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, t: (i, t)),
            pl.BlockSpec((bk, bn), lambda i, j, t: (t, j)),
            pl.BlockSpec((bm, 1), lambda i, j, t: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, t: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(A, B, sa, sb)
    return out[:m, :n]
