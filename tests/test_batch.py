"""Batched multi-metric / multi-subset campaigns.

Pins the batching acceptance contract (ISSUE / docs/ARCHITECTURE.md
"Batched campaigns"):

* every batched campaign result is BIT-IDENTICAL (checksum) to its
  sequential single-campaign run — across every registered metric, every
  mgemm impl (xla / levels / levels_xla / popcount), both ways, in-memory
  and store-backed/streamed payloads;
* ``meta["batch"]`` proves the ring payload bytes moved are a function of
  payload shape and plan ONLY — independent of how many metrics/subsets
  ride the traversal (the whole point of batching);
* named-subset campaigns equal encode-of-subset: running the batch over a
  subset view of the shared planes gives the same result as encoding the
  subset columns from scratch (hypothesis property — slicing commutes
  with encoding);
* family grouping: czekanowski + sorenson share one numerator family,
  ccc keeps its own; ``group_families`` drives one contraction per family;
* the serving cache keys on campaign identity (metric names + subset
  indices), so batched and differently-batched requests never collide.
"""
import numpy as np
import pytest

from repro.api import (
    BatchedSimilarityResult,
    SimilarityEngine,
    SimilarityRequest,
    batch_lead,
    family_key,
    get_metric,
    group_families,
    plane_native,
)
from repro.core.synthetic import random_integer_vectors

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

ALL_METRICS = ("czekanowski", "sorenson", "ccc")
SUBSETS = (("caseA", (4, 1, 9, 13)), ("caseB", (0, 9, 2, 15, 5)))


@pytest.fixture(scope="module")
def engine():
    return SimilarityEngine()


@pytest.fixture(scope="module")
def V():
    # {0, 1, 2} SNP-like data: valid for every registered metric (sorenson
    # shares czekanowski's arithmetic) and exercises the levels planes
    return random_integer_vectors(40, 18, max_value=2, seed=3)


@pytest.fixture(scope="module")
def Vbin():
    return random_integer_vectors(40, 18, max_value=1, seed=4)


def _sequential(engine, V, metric, way, **kw):
    return engine.run(SimilarityRequest(metric=metric, way=way, **kw), V)


# ------------------------------------------------------------- family math --

def test_family_grouping():
    czek, sor, ccc = (get_metric(n) for n in ALL_METRICS)
    assert family_key(czek) == family_key(sor) != family_key(ccc)
    groups = group_families([czek, ccc, sor])
    assert [[s.name for s in g] for g in groups] == [
        ["czekanowski", "sorenson"], ["ccc"],
    ]
    assert plane_native(czek) and plane_native(sor)
    assert not plane_native(ccc)
    # the plane-native member leads config resolution even when not first
    assert batch_lead([ccc, czek]).name == "czekanowski"
    assert batch_lead([ccc]).name == "ccc"


# ----------------------------------------------- batched == sequential -----

@pytest.mark.parametrize("impl", ["xla", "levels", "levels_xla", "pallas"])
def test_twoway_batched_matches_sequential(engine, V, impl):
    req = SimilarityRequest(
        metric="czekanowski", metrics=("sorenson", "ccc"), way=2, impl=impl,
    )
    br = engine.run(req, V)
    assert isinstance(br, BatchedSimilarityResult) and len(br) == 3
    for name in ALL_METRICS:
        seq = _sequential(engine, V, name, 2)  # impl=xla reference
        assert br.get(name).checksum() == seq.checksum(), name


def test_twoway_batched_popcount_matches_sequential(engine, Vbin):
    """levels=1 binary data routes the batch through the popcount bit-GEMM."""
    req = SimilarityRequest(
        metric="sorenson", metrics=("czekanowski", "ccc"), way=2,
        impl="levels", levels=1,
    )
    br = engine.run(req, Vbin)
    for name in ALL_METRICS:
        seq = _sequential(engine, Vbin, name, 2)
        assert br.get(name).checksum() == seq.checksum(), name


@pytest.mark.parametrize("impl", ["xla", "levels"])
def test_threeway_batched_matches_sequential(engine, V, impl):
    req = SimilarityRequest(
        metric="czekanowski", metrics=("sorenson", "ccc"), way=3, impl=impl,
    )
    br = engine.run(req, V)
    for name in ALL_METRICS:
        seq = _sequential(engine, V, name, 3)
        assert br.get(name).checksum() == seq.checksum(), name


def test_threeway_batched_staged_matches_sequential(engine, V):
    req = SimilarityRequest(
        metric="czekanowski", metrics=("ccc",), way=3, n_st=2, impl="levels",
    )
    br = engine.run(req, V)
    for name in ("czekanowski", "ccc"):
        seq = _sequential(engine, V, name, 3)  # n_st=1, all triples
        assert br.get(name).checksum() == seq.checksum(), name


# ---------------------------------------------------------- named subsets --

@pytest.mark.parametrize("way", [2, 3])
def test_subset_campaigns_match_sequential_slices(engine, V, way):
    """Each (metric, subset) campaign == the sequential run over exactly
    those columns — byte-slice plane views never re-encode, unsorted and
    overlapping index lists included."""
    req = SimilarityRequest(
        metric="czekanowski", metrics=("ccc",), subsets=SUBSETS, way=way,
        impl="levels",
    )
    br = engine.run(req, V)
    assert br.meta["batch"]["campaigns"] == 4
    for name in ("czekanowski", "ccc"):
        for sname, idx in SUBSETS:
            seq = _sequential(engine, V[:, list(idx)], name, way)
            got = br.get(name, sname)
            assert got.n_v == len(idx)
            assert got.checksum() == seq.checksum(), (name, sname)


def test_subset_result_dense_matches_slice(engine, V):
    """Beyond checksums: the dense subset matrix equals the dense slice."""
    idx = [7, 3, 11]
    req = SimilarityRequest(metric="czekanowski", subsets=(("s", tuple(idx)),))
    br = engine.run(req, V)
    seq = _sequential(engine, V[:, idx], "czekanowski", 2)
    np.testing.assert_array_equal(br.get("czekanowski", "s").dense(),
                                  seq.dense())


if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        data=st.data(),
    )
    def test_subset_equals_encode_of_subset(seed, data):
        """Property: a subset campaign over the shared payload is bit-
        identical to encoding the subset's columns from scratch — the
        vector axis commutes with plane encoding and with the metric."""
        n_v = data.draw(st.integers(4, 14), label="n_v")
        k = data.draw(st.integers(2, n_v), label="k")
        idx = tuple(data.draw(
            st.permutations(range(n_v)), label="perm"
        )[:k])
        V = random_integer_vectors(24, n_v, max_value=2, seed=seed)
        engine = SimilarityEngine()
        br = engine.run(SimilarityRequest(
            metric="czekanowski", subsets=(("s", idx),), impl="levels",
        ), V)
        seq = engine.run(
            SimilarityRequest(metric="czekanowski", way=2),
            V[:, list(idx)],
        )
        assert br.get("czekanowski", "s").checksum() == seq.checksum()


# ----------------------------------------------------- ring-byte invariance --

def test_ring_bytes_independent_of_campaign_count(engine, V):
    """The tentpole's accounting claim: a batched campaign with M metrics
    and S subsets moves the SAME ring payload bytes as a single campaign —
    only the (negligible) per-family stat rows scale with the batch."""
    base = dict(way=2, n_pv=1, impl="levels")
    b1 = engine.run(SimilarityRequest(metric="czekanowski",
                                      metrics=("sorenson",), **base), V)
    b3 = engine.run(SimilarityRequest(metric="czekanowski",
                                      metrics=("sorenson", "ccc"),
                                      subsets=SUBSETS, **base), V)
    m1, m3 = b1.meta["batch"], b3.meta["batch"]
    assert m1["encodes"] == m3["encodes"] == 1
    assert m1["traversals"] == m3["traversals"] == 1
    # single-rank: nothing moves; the per-rank payload is the whole payload
    assert m1["ring_payload_bytes"] == m3["ring_payload_bytes"] == 0
    assert m3["campaigns"] == 6 and m1["campaigns"] == 2


def test_ring_bytes_metric_count_invariant_multirank(V):
    """Direct core check on a (1, 2, 1) mesh: ring bytes move and are
    equal for 1 vs 3 metrics; stat ring bytes scale with FAMILIES."""
    from repro.core.twoway import CometConfig, twoway_batched
    from repro.parallel.mesh import make_comet_mesh

    import jax

    if len(jax.devices()) < 2:
        pytest.skip("needs 2 devices")
    mesh = make_comet_mesh(1, 2, 1)
    cfg = CometConfig(n_pv=2, impl="levels")
    specs1 = [get_metric("czekanowski")]
    specs3 = [get_metric(n) for n in ALL_METRICS]
    _, b1 = twoway_batched(V, mesh, cfg, specs1)
    _, b3 = twoway_batched(V, mesh, cfg, specs3)
    assert b1["ring_payload_bytes"] == b3["ring_payload_bytes"] > 0
    assert b3["families"] == 2 and b1["families"] == 1
    assert b3["stat_ring_bytes"] == 2 * b1["stat_ring_bytes"]


# ------------------------------------------------------------- validation --

def test_batched_request_validation():
    with pytest.raises(ValueError, match="duplicate metric"):
        SimilarityRequest(metric="czekanowski",
                          metrics=("czekanowski",)).validate()
    with pytest.raises(ValueError, match="duplicate indices"):
        SimilarityRequest(subsets=(("a", (1, 1)),)).validate()
    with pytest.raises(ValueError, match="empty"):
        SimilarityRequest(subsets=(("a", ()),)).validate()
    with pytest.raises(ValueError, match="duplicate subset name"):
        SimilarityRequest(subsets=(("a", (1,)), ("a", (2,)))).validate()
    with pytest.raises(ValueError, match="stage coverage"):
        SimilarityRequest(way=3, n_st=2, stages=(0,),
                          subsets=(("a", (1, 2)),)).validate()
    # complete coverage is fine
    SimilarityRequest(way=3, n_st=2, subsets=(("a", (1, 2)),)).validate()


def test_subset_indices_out_of_range(engine, V):
    with pytest.raises(ValueError, match="out of range"):
        engine.run(SimilarityRequest(
            metric="czekanowski", subsets=(("a", (0, 99)),)
        ), V)


# ------------------------------------------------------------- serve cache --

def test_serve_cache_keys_on_campaign_identity(V):
    from repro.serve.engine import SimilarityService

    svc = SimilarityService()
    r1 = SimilarityRequest(metric="czekanowski")
    r2 = SimilarityRequest(metric="czekanowski", metrics=("sorenson",))
    r3 = SimilarityRequest(metric="czekanowski",
                           subsets=(("a", (0, 1, 2)),))
    svc.submit(r1, V)
    svc.submit(r2, V)
    svc.submit(r3, V)
    assert svc.stats()["misses"] == 3 and svc.stats()["hits"] == 0
    # same campaigns spelled differently (list indices) hit the cache
    svc.submit(SimilarityRequest(metric="czekanowski",
                                 subsets=(("a", [0, 1, 2]),)), V)
    assert svc.stats()["hits"] == 1


# --------------------------------------------------- store-backed / stream --

def test_store_backed_and_streamed_batched(engine, tmp_path):
    """Batched over a packed dataset store — materialized AND streamed —
    matches the sequential in-memory impl=xla reference per campaign."""
    import os

    from repro.api import InputSpec
    from repro.store import write_dataset

    V = random_integer_vectors(56, 20, max_value=2, seed=11)
    path = os.path.join(str(tmp_path), "ds")
    write_dataset(path, V, levels=2, n_shards=2)
    inp = InputSpec(source="planes", path=path)
    for streaming in ("off", "on"):
        br = engine.run(SimilarityRequest(
            metric="czekanowski", metrics=("sorenson", "ccc"),
            subsets=SUBSETS, way=2, impl="levels",
            streaming=streaming, input=inp,
        ))
        if streaming == "on":
            assert "stream" in br.meta
        for name in ALL_METRICS:
            for sname, idx in SUBSETS:
                seq = _sequential(engine, V[:, list(idx)], name, 2)
                assert br.get(name, sname).checksum() == seq.checksum(), (
                    streaming, name, sname,
                )


def test_streamed_threeway_batched(engine, tmp_path):
    import os

    from repro.api import InputSpec
    from repro.store import write_dataset

    V = random_integer_vectors(56, 18, max_value=2, seed=12)
    path = os.path.join(str(tmp_path), "ds3")
    write_dataset(path, V, levels=2, n_shards=2)
    br = engine.run(SimilarityRequest(
        metric="czekanowski", metrics=("ccc",), way=3, impl="levels",
        streaming="on", input=InputSpec(source="planes", path=path),
    ))
    for name in ("czekanowski", "ccc"):
        seq = _sequential(engine, V, name, 3)
        assert br.get(name).checksum() == seq.checksum(), name
