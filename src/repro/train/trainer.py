"""Fault-tolerant training loop.

Features (each covered by tests):
* jit'd train step (GSPMD-sharded when a mesh is active)
* prefetched deterministic data pipeline (resume-exact)
* async atomic checkpointing + restart (bit-exact continuation)
* elastic restore onto a different mesh
* straggler watchdog
* failure injection (SimulatedFailure at step N) for crash/restart tests
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable

import jax

from repro.checkpoint.ckpt import CheckpointManager
from repro.data.tokens import PrefetchIterator, SyntheticTokens
from repro.models import api
from repro.models.common import ModelConfig
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.parallel.sharding import use_mesh
from repro.train.step import make_train_step
from repro.train.straggler import StragglerWatchdog


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 25
    log_every: int = 10
    ckpt_dir: str = "/tmp/repro_ckpt"
    seed: int = 0
    batch: int = 8
    seq_len: int = 64
    fail_at_step: int | None = None  # failure injection
    keep_ckpts: int = 3
    inject_delay: Callable[[int], float] | None = None  # straggler simulation


@dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: int = 0


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        tcfg: TrainerConfig,
        opt_cfg: AdamWConfig | None = None,
        mesh=None,
    ):
        self.cfg = cfg
        self.tcfg = tcfg
        self.opt_cfg = opt_cfg or AdamWConfig()
        self.mesh = mesh
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep_ckpts)
        self.watchdog = StragglerWatchdog()
        self.history: list[dict] = []
        self._step_fn = jax.jit(make_train_step(cfg, self.opt_cfg))

    # ------------------------------------------------------------ state --

    def init_state(self) -> TrainState:
        with use_mesh(self.mesh):
            params = api.init_model(self.cfg, jax.random.PRNGKey(self.tcfg.seed))
            opt_state = adamw_init(params)
        return TrainState(params=params, opt_state=opt_state, step=0)

    def resume_or_init(self) -> TrainState:
        latest = self.ckpt.latest_step()
        if latest is None:
            return self.init_state()
        state = self.init_state()
        (params, opt_state), step = self.ckpt.restore(
            (state.params, state.opt_state)
        )
        return TrainState(params=params, opt_state=opt_state, step=step)

    def _data(self, start_step: int) -> PrefetchIterator:
        src = SyntheticTokens(
            vocab_size=self.cfg.vocab_size,
            batch=self.tcfg.batch,
            seq_len=self.tcfg.seq_len,
            seed=self.tcfg.seed,
            family=self.cfg.family,
            d_model=self.cfg.d_model,
        )
        return PrefetchIterator(src, start_step=start_step)

    # ------------------------------------------------------------- loop --

    def train(self, state: TrainState | None = None) -> TrainState:
        state = state or self.resume_or_init()
        data = self._data(state.step)
        try:
            with use_mesh(self.mesh):
                while state.step < self.tcfg.steps:
                    step_idx, batch = next(data)
                    assert step_idx == state.step, "data iterator out of sync"
                    if (
                        self.tcfg.fail_at_step is not None
                        and state.step == self.tcfg.fail_at_step
                    ):
                        raise SimulatedFailure(f"injected failure @ {state.step}")
                    t0 = time.perf_counter()
                    if self.tcfg.inject_delay is not None:
                        time.sleep(self.tcfg.inject_delay(state.step))
                    params, opt_state, metrics = self._step_fn(
                        state.params, state.opt_state, batch
                    )
                    metrics = {k: float(v) for k, v in metrics.items()}
                    dt = time.perf_counter() - t0
                    self.watchdog.observe(state.step, dt)
                    state = TrainState(params, opt_state, state.step + 1)
                    if state.step % self.tcfg.log_every == 0:
                        self.history.append(
                            dict(step=state.step, time=dt, **metrics)
                        )
                    if state.step % self.tcfg.ckpt_every == 0:
                        self.ckpt.save(
                            state.step, (state.params, state.opt_state)
                        )
            self.ckpt.save(state.step, (state.params, state.opt_state), blocking=True)
            return state
        finally:
            data.close()
            self.ckpt.wait()
