from .kernel import (  # noqa: F401
    metric2_pop_pallas,
    metric2_pop_tri_pallas,
    threeway_batch_pop_pallas,
)
from .ops import (  # noqa: F401
    metric2_pop,
    metric2_pop_tri,
    pop_planes,
    threeway_batch_pop,
)
from .ref import pop_planes_ref, threeway_pop_ref  # noqa: F401
