"""Decoder-only LM covering the dense / GQA / MoE / SSM / hybrid families.

Layers are scanned (`jax.lax.scan` over stacked parameters) so compiled HLO
is O(1) in depth.  The hybrid (Zamba2) family interleaves a *shared*
attention+MLP block every ``hybrid_attn_every`` SSM layers inside the same
scan via ``lax.cond`` — one set of shared parameters, applied at multiple
depths (the Zamba2 design), still a single compiled layer body.

Public API:
  init_lm(cfg, key)                      -> params
  lm_forward(cfg, params, tokens|embeds) -> logits [+ aux]
  lm_loss(cfg, params, batch)            -> scalar loss
  init_decode_cache(cfg, batch, max_len) -> cache
  lm_decode_step(cfg, params, cache, tok, idx) -> (logits, cache)
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models import ssm as ssm_mod
from repro.models.common import ModelConfig, dense_init, stack_layer_params
from repro.models.norms import rms_norm
from repro.models.rope import rope_angles
from repro.parallel.sharding import DATA_AXES, shard


# --------------------------------------------------------------- init ----


def _init_block(cfg: ModelConfig, key):
    """One decoder block (attention + ffn/moe) — dense & moe families."""
    ka, kf = jax.random.split(key)
    p = {
        "ln1": jnp.ones((cfg.d_model,), cfg.pdt),
        "attn": attn_mod.init_attention(cfg, ka),
        "ln2": jnp.ones((cfg.d_model,), cfg.pdt),
    }
    if cfg.family == "moe":
        p["moe"] = mlp_mod.init_moe(cfg, kf)
    else:
        p["mlp"] = mlp_mod.init_mlp(cfg, kf)
    return p


def _init_mamba_layer(cfg: ModelConfig, key):
    return {
        "ln": jnp.ones((cfg.d_model,), cfg.pdt),
        "mamba": ssm_mod.init_mamba(cfg, key),
    }


def init_lm(cfg: ModelConfig, key):
    ke, kl, kh, ks = jax.random.split(key, 4)
    params = {
        "embed": dense_init(ke, (cfg.vocab_size, cfg.d_model), cfg.pdt, scale=0.02),
        "final_ln": jnp.ones((cfg.d_model,), cfg.pdt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(kh, (cfg.d_model, cfg.vocab_size), cfg.pdt)
    if cfg.family in ("ssm", "hybrid"):
        params["layers"] = stack_layer_params(
            partial(_init_mamba_layer, cfg), cfg.n_layers, kl
        )
        if cfg.family == "hybrid":
            kg1, kg2 = jax.random.split(ks)
            params["shared"] = {
                "ln1": jnp.ones((cfg.d_model,), cfg.pdt),
                "attn": attn_mod.init_attention(cfg, kg1),
                "ln2": jnp.ones((cfg.d_model,), cfg.pdt),
                "mlp": mlp_mod.init_mlp(cfg, kg2),
            }
    else:
        params["layers"] = stack_layer_params(
            partial(_init_block, cfg), cfg.n_layers, kl
        )
    return params


def param_sharding_rules(cfg: ModelConfig):
    """pytree of PartitionSpec entries matching init_lm's structure.

    2D FSDP + TP: Megatron tensor parallelism over "model" plus fully-sharded
    parameters over the folded data axes ("pod","data") — XLA all-gathers
    weights per layer (inside the scan) and reduce-scatters gradients, which
    is what lets the 104B/314B training cells fit 16 GB/chip.  The leading
    layer-stack axis is never sharded.
    """
    from jax.sharding import PartitionSpec as P

    F = ("pod", "data")  # FSDP axes (filtered to the active mesh)
    attn_spec = {
        "wq": P(None, F, "model"),
        "wk": P(None, F, "model"),
        "wv": P(None, F, "model"),
        "wo": P(None, "model", F),
    }
    if cfg.qkv_bias:
        attn_spec |= {"bq": P(None, "model"), "bk": P(None, "model"),
                      "bv": P(None, "model")}
    mlp_spec = {"wi": P(None, F, "model"), "wg": P(None, F, "model"),
                "wo": P(None, "model", F)}
    rules = {
        "embed": P("model", F),
        "final_ln": P(None),
    }
    if not cfg.tie_embeddings:
        rules["lm_head"] = P(F, "model")
    if cfg.family in ("ssm", "hybrid"):
        rules["layers"] = {
            "ln": P(None),
            "mamba": {
                "in_proj": P(None, F, "model"),
                "conv_w": P(None, None, "model"),
                "conv_b": P(None, "model"),
                "A_log": P(None, None),
                "D": P(None, None),
                "dt_bias": P(None, None),
                "norm_w": P(None, "model"),
                "out_proj": P(None, "model", F),
            },
        }
        if cfg.family == "hybrid":
            shared_attn = {k: P(*s[1:]) for k, s in attn_spec.items()}
            shared_mlp = {k: P(*s[1:]) for k, s in mlp_spec.items()}
            rules["shared"] = {
                "ln1": P(None), "attn": shared_attn,
                "ln2": P(None), "mlp": shared_mlp,
            }
    else:
        block = {"ln1": P(None), "attn": attn_spec, "ln2": P(None)}
        if cfg.family == "moe":
            block["moe"] = {
                "router": P(None, F, None),
                "wi": P(None, None, F, "model"),
                "wg": P(None, None, F, "model"),
                "wo": P(None, None, "model", F),
            }
        else:
            block["mlp"] = mlp_spec
        rules["layers"] = block
    if cfg.dp_only:
        rules = jax.tree.map(
            _dp_only_param_spec, rules,
            is_leaf=lambda x: isinstance(x, P),
        )
    return rules


def _dp_only_param_spec(spec):
    """ZeRO-3 remap of a param spec: TP entries dropped, the FSDP entry
    extends over the freed "model" axis."""
    from jax.sharding import PartitionSpec as P

    out = []
    for e in spec:
        if isinstance(e, (tuple, list)):
            e = tuple(e)
            if "model" not in e:
                e = e + ("model",)
            out.append(e)
        elif e == "model":
            out.append(None)
        else:
            out.append(e)
    return P(*out)


# ------------------------------------------------------------- forward ----


def _res_spec(cfg: ModelConfig):
    # sequence parallelism (Megatron-SP): the residual stream lives sharded
    # over "model" along S between blocks, turning each TP all-reduce into a
    # reduce-scatter + all-gather pair (half the wire bytes) and sharding the
    # fp32 norm math 16-ways.
    return (DATA_AXES, "model", None) if cfg.seq_parallel else (DATA_AXES, None, None)


def _block_apply(cfg: ModelConfig, p, x, cos_sin, cache=None, cache_index=None):
    h, new_cache = attn_mod.attention(
        cfg, p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps),
        cos_sin=cos_sin, cache=cache, cache_index=cache_index,
    )
    x = shard(x + h, *_res_spec(cfg))
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "moe":
        h, aux = mlp_mod.moe(cfg, p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps))
    else:
        h = mlp_mod.mlp(cfg, p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
    return shard(x + h, *_res_spec(cfg)), aux, new_cache


def _shared_apply(cfg: ModelConfig, p, x, cos_sin, cache=None, cache_index=None):
    h, new_cache = attn_mod.attention(
        cfg, p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps),
        cos_sin=cos_sin, cache=cache, cache_index=cache_index,
    )
    x = x + h
    x = x + mlp_mod.mlp(cfg, p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
    return x, new_cache


def _maybe_remat(cfg: ModelConfig, fn):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return fn


def lm_forward(cfg: ModelConfig, params, tokens=None, *, embeds=None, positions=None):
    """tokens (B,S) int32 or embeds (B,S,D) (stub frontends).  Returns
    (logits (B,S,V), aux_loss)."""
    cdt = cfg.cdt
    if embeds is None:
        x = params["embed"][tokens].astype(cdt)
    else:
        x = embeds.astype(cdt)
    x = shard(x, *_res_spec(cfg))
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :] * jnp.ones((B, 1), jnp.int32)
        if cfg.mrope_sections:
            positions = jnp.broadcast_to(positions, (3, B, S))
    cos_sin = (
        rope_angles(positions, cfg.hd, cfg.rope_theta, cfg.mrope_sections)
        if cfg.n_heads
        else None
    )

    if cfg.family in ("ssm", "hybrid"):
        shared = params.get("shared")
        every = cfg.hybrid_attn_every

        def body(carry, inp):
            x = carry
            i, lp = inp
            h, _ = ssm_mod.mamba_block(cfg, lp["mamba"],
                                       rms_norm(x, lp["ln"], cfg.norm_eps))
            x = x + h
            if shared is not None:
                x = jax.lax.cond(
                    (i + 1) % every == 0,
                    lambda x: _shared_apply(cfg, shared, x, cos_sin)[0],
                    lambda x: x,
                    x,
                )
            return x, jnp.zeros((), jnp.float32)

        body = _maybe_remat(cfg, body)
        x, auxs = jax.lax.scan(body, x, (jnp.arange(cfg.n_layers), params["layers"]))
    else:

        def body(x, lp):
            x, aux, _ = _block_apply(cfg, lp, x, cos_sin)
            return x, aux

        body = _maybe_remat(cfg, body)
        x, auxs = jax.lax.scan(body, x, params["layers"])

    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(cdt)
    logits = shard(logits, DATA_AXES, None, "model")
    return logits, auxs.mean()


def sharded_xent(logits, labels, mask=None):
    """Cross entropy that stays vocab-parallel.

    ``take_along_axis`` on vocab-sharded logits makes GSPMD re-gather the
    batch axis (a ~40 GB all-gather for a 150k vocab at 1M tokens); instead
    the label logit is a one-hot contraction and logsumexp uses plain
    reductions — both shard cleanly over the vocab axis with only (B, S)
    sized collectives."""
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(lf.max(axis=-1, keepdims=True))
    lse = jnp.log(jnp.exp(lf - m).sum(axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=lf.dtype)
    label_logit = (lf * onehot).sum(axis=-1)
    ll = label_logit - lse
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1)


def lm_loss(cfg: ModelConfig, params, batch):
    """batch: {"tokens": (B,S), "labels": (B,S), "mask": optional} -> scalar."""
    logits, aux = lm_forward(
        cfg, params, batch.get("tokens"), embeds=batch.get("embeds")
    )
    loss = sharded_xent(logits, batch["labels"], batch.get("mask"))
    if cfg.family == "moe":
        loss = loss + 0.01 * aux
    return loss


# -------------------------------------------------------------- decode ----


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int):
    cdt = cfg.cdt
    if cfg.family == "ssm":
        return {"mamba": ssm_mod.init_mamba_cache(cfg, batch, cfg.n_layers, cdt)}
    if cfg.family == "hybrid":
        return {
            "mamba": ssm_mod.init_mamba_cache(cfg, batch, cfg.n_layers, cdt),
            "kv": attn_mod.init_kv_cache(cfg, batch, max_len, cfg.n_layers, cdt),
        }
    return {"kv": attn_mod.init_kv_cache(cfg, batch, max_len, cfg.n_layers, cdt)}


def lm_decode_step(cfg: ModelConfig, params, cache, tokens, cache_index):
    """One decode (S=1) or prefill (S>1, cache_index=0) step.

    tokens (B, S) int32; cache_index: tokens already in the cache.
    Returns (logits (B, S, V), new_cache)."""
    cdt = cfg.cdt
    x = params["embed"][tokens].astype(cdt)
    B, S = tokens.shape
    positions = cache_index + jnp.arange(S)[None, :] + jnp.zeros((B, 1), jnp.int32)
    if cfg.mrope_sections:
        positions = jnp.broadcast_to(positions, (3, B, S))
    cos_sin = (
        rope_angles(positions, cfg.hd, cfg.rope_theta, cfg.mrope_sections)
        if cfg.n_heads
        else None
    )

    if cfg.family in ("ssm", "hybrid"):
        shared = params.get("shared")
        every = cfg.hybrid_attn_every

        def body(x, inp):
            if shared is not None:
                i, lp, mc, kvc = inp
            else:
                i, lp, mc = inp
                kvc = None
            h, new_mc = ssm_mod.mamba_block(
                cfg, lp["mamba"], rms_norm(x, lp["ln"], cfg.norm_eps), cache=mc
            )
            x = x + h
            new_kvc = kvc
            if shared is not None:
                def apply(op):
                    x, kvc = op
                    y, nc = _shared_apply(cfg, shared, x, cos_sin,
                                          cache=kvc, cache_index=cache_index)
                    return y, nc
                x, new_kvc = jax.lax.cond(
                    (i + 1) % every == 0, apply, lambda op: op, (x, kvc)
                )
            out = (new_mc, new_kvc) if shared is not None else (new_mc,)
            return x, out

        xs = [jnp.arange(cfg.n_layers), params["layers"], cache["mamba"]]
        if shared is not None:
            xs.append(cache["kv"])
        x, new_caches = jax.lax.scan(body, x, tuple(xs))
        new_cache = {"mamba": new_caches[0]}
        if shared is not None:
            new_cache["kv"] = new_caches[1]
    else:

        def body(x, inp):
            lp, kvc = inp
            x, _, new_kvc = _block_apply(cfg, lp, x, cos_sin,
                                         cache=kvc, cache_index=cache_index)
            return x, new_kvc

        x, new_kv = jax.lax.scan(body, x, (params["layers"], cache["kv"]))
        new_cache = {"kv": new_kv}

    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(cdt)
    return logits, new_cache
