"""Distributed 3-way Proportional Similarity engine — paper §4.2, Algs 2-3.

SPMD structure per rank (p_v, p_r) on the ("pf", "pv", "pr") mesh, computing
stage ``s_t`` of the tetrahedral schedule in ``repro.core.plan3``:

  Phase A (diagonal-edge block): 6 slices of the strict tetrahedron
           a < b < c inside the rank's own block.
  Phase B (face blocks): ring over dj; for each received block J, 6 slices of
           the prism {(a in own) x (b < c in J)}.
  Phase C (volume blocks): doubly-nested ring over (dk, dj) — Algorithm 2's
           communication pipeline — computing ONE oriented 1/6-slice per
           block (middle-id rule, ``plan3.vol_slice_rule``).

Each slice runs Algorithm 3's inner pipeline through the ``TileExecutor``:
on the XLA path the pipeline axis (length L = n_vp/(6 n_st)) is folded into
the GEMM M dimension via X[q, (l, t)] = min(left[q, l], pipe[q, j0 + t]), so

    B[t, l, r] = sum_q min(pipe[q, j0+t], left[q, l], right[q, r])

is one (m*L, n_fp) x (n_fp, m) min-plus GEMM — the TPU-friendly realization
of the paper's "sequence of 2-way operations" that maximizes mGEMM size
(their stated goal for the staging knob).  On the Pallas path the executor
instead runs the fused X_j kernel per pipeline column, so X never touches
HBM (kernels/czek3).  Pairwise numerators for the metric assembly are two
(L, m) sliced contractions + one (m, m) full contraction; all partials are
psummed over "pf" in one fused collective per item.

Round-robin: item sb executes iff sb % n_pr == p_r (lax.cond — compute is
skipped, not masked).  Phases B/C run under ``lax.fori_loop`` with the ring
``ppermute`` in the loop body, so the compiled program size is O(1) in n_pv
(306 items at n_pv=16 compile as two nested loops).

Packed bit-plane ring (resolved ``encoding == "bitplane"``): V is encoded
ONCE into packed uint8 planes before ``shard_map`` and the doubly-nested
ring carries the (levels, kb, n_vp) plane shards themselves — 1/16 of the
fp32 wire volume for {0,1,2} SNP data.  Pipeline slices are byte-range
views along the vector axis (packing is along the FIELD axis, so no bit
surgery is ever needed) and feed the level-decomposed slice kernels
directly; nothing re-encodes inside the ring loop.  Wire/storage layout:
docs/BITPLANE_FORMAT.md.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.compat import shard_map

from repro.core import checksum as ck
from repro.core.metric_spec import (
    CZEKANOWSKI,
    MetricSpec,
    batch_lead,
    group_families,
    plane_native,
)
from repro.core.plan3 import ItemKind, ThreeWayPlan, PERMS
from repro.core.tile_executor import TileExecutor
from repro.core.twoway import CometConfig, batch_accounting
from repro.obs import trace as obs

__all__ = [
    "ThreeWayOutput",
    "threeway_distributed",
    "threeway_batched",
    "czek3_distributed",
]

# lookup: (rank_own, rank_J, rank_K) base-3 -> permutation index (plan3.PERMS)
_PERM_LUT = np.zeros(27, np.int32)
for _i, _p in enumerate(PERMS):
    _PERM_LUT[_p[0] * 9 + _p[1] * 3 + _p[2]] = _i


def _vol_rule_traced(own, bj, bk):
    """Traced (slice_axis, slice_idx) — must match plan3.vol_slice_rule."""
    r_own = (own > bj).astype(jnp.int32) + (own > bk).astype(jnp.int32)
    r_j = (bj > own).astype(jnp.int32) + (bj > bk).astype(jnp.int32)
    r_k = 3 - r_own - r_j
    axis = (r_j == 1) * 1 + (r_k == 1) * 2  # 0 if own is the middle id
    idx = jnp.asarray(_PERM_LUT)[r_own * 9 + r_j * 3 + r_k]
    return axis, idx


def _item_metrics(
    pipe, left, right, s_p, s_l, s_r, j0, *, kind: ItemKind, L: int,
    execs, groups, out_dtype, deferred: bool = False,
):
    """Masked metric slices for one work item — (M, L, m, m), one per
    requested metric in flattened family order.

    pipe/left/right: (n_fp, m) field-major value blocks, or (levels, kb, m)
    packed uint8 bit-planes on the plane ring (docs/BITPLANE_FORMAT.md);
    s_*: (G, m) per-FAMILY stats (already psummed over pf) — ``groups`` is
    the ``group_families`` partition of the requested metrics, ``execs``
    the parallel per-group executor lists (``execs[g][0]`` is the family's
    contraction lead).  Each family contracts ONCE; members differ only in
    their ``assemble3`` epilogue.  Product-family groups riding a plane
    ring reconstruct exact values via ``values_from_planes`` first.  All
    families' numerators psum in ONE fused collective, so the item costs
    one collective regardless of metric count.  j0: traced pipeline offset.

    ``deferred=True`` (streamed chunk programs, ``repro.stream``) stops
    after the psum and returns the RAW fp32 numerator partials
    ``(B, n2_pl, n2_pr, n2_lr)`` — shapes (G, L, m, m), (G, L, m),
    (G, L, m), (G, m, m), zeros standing in when the family needs no pair
    terms — so the cross-shard merge epilogue can assemble and mask once
    per campaign instead of once per chunk.
    """
    m = pipe.shape[-1]
    planes = pipe.ndim == 3
    if planes:
        # packed bit-plane ring: pipeline slicing along the vector axis is
        # a plain byte-range view of the (levels, kb, m) payload — the
        # field axis (where bits pack 8-per-byte) is untouched
        from repro.kernels.mgemm_levels import slice_planes_vectors

        ps = slice_planes_vectors(pipe, j0, L)
    else:
        n_fp = pipe.shape[0]
        ps = jax.lax.dynamic_slice(pipe, (0, j0), (n_fp, L))  # (n_fp, L)
    if planes and any(not plane_native(grp[0]) for grp in groups):
        # product-family members can't contract packed planes; V = Σ plane_t
        # is exact, so they ride the SAME ring payload at full precision
        from repro.kernels.mgemm_levels import values_from_planes

        W_ps = values_from_planes(ps)
        W_left = values_from_planes(left)
        W_right = W_left if right is left else values_from_planes(right)

    # one contraction per family, all partials fused into a single psum
    parts, needs_of = [], []
    for g, grp in enumerate(groups):
        ex = execs[g][0]
        if planes and not plane_native(grp[0]):
            ops = (W_ps, W_left, W_right)
        else:
            ops = (ps, left, right)
        B = ex.threeway_slice(*ops)
        needs = any(s.needs_pair_terms for s in grp)
        needs_of.append(needs)
        parts.append(B)
        if needs:
            parts.append(ex.pair_numerator(ops[0], ops[1]))  # (L, m)
            parts.append(ex.pair_numerator(ops[0], ops[2]))  # (L, m)
            parts.append(ex.pair_numerator(ops[1], ops[2]))  # (m, m)
    parts = jax.lax.psum(tuple(parts), "pf")

    # unpack per group: (B, n2_pl, n2_pr, n2_lr) with None where unneeded
    group_res, cursor = [], 0
    for g in range(len(groups)):
        if needs_of[g]:
            group_res.append(tuple(parts[cursor:cursor + 4]))
            cursor += 4
        else:
            group_res.append((parts[cursor], None, None, None))
            cursor += 1

    if deferred:
        zero_lm = jnp.zeros((L, m), jnp.float32)
        zero_mm = jnp.zeros((m, m), jnp.float32)
        return tuple(
            jnp.stack(bufs)
            for bufs in zip(*[
                (
                    B.astype(jnp.float32),
                    zero_lm if pl is None else pl.astype(jnp.float32),
                    zero_lm if pr is None else pr.astype(jnp.float32),
                    zero_mm if lr is None else lr.astype(jnp.float32),
                )
                for B, pl, pr, lr in group_res
            ])
        )

    jg = j0 + jnp.arange(L)  # global-in-block pipeline indices
    li = jnp.arange(m)
    if kind == ItemKind.DIAG:
        mask = (li[None, :, None] < jg[:, None, None]) & (
            li[None, None, :] > jg[:, None, None]
        )
    elif kind == ItemKind.FACE:
        mask = jnp.broadcast_to(
            li[None, None, :] > jg[:, None, None], (L, m, m)
        )
    else:
        mask = jnp.ones((L, m, m), bool)

    outs = []
    for g, grp in enumerate(groups):
        B, n2_pl, n2_pr, n2_lr = group_res[g]
        sp = jax.lax.dynamic_slice(s_p[g], (j0,), (L,))
        for spec in grp:
            use = spec.needs_pair_terms
            c3 = spec.assemble3(
                B,
                n2_pl if use else None,
                n2_pr if use else None,
                n2_lr if use else None,
                sp, s_l[g], s_r[g],
            )
            outs.append(jnp.where(mask, c3, 0).astype(out_dtype))
    return jnp.stack(outs)


def _threeway_program(
    Vl, *, cfg: CometConfig, plan: ThreeWayPlan, stage: int, out_dtype,
    metric: MetricSpec = None, groups=None, deferred: bool = False,
):
    """Per-device program. Vl: (n_f/n_pf, n_vp) values, or — on the plane
    ring (resolved ``encoding == "bitplane"``) — the rank's packed plane
    shard (levels, n_fb/n_pf, n_vp) uint8.  With planes, Phases B and C
    ring-carry the packed payload itself (the same ``ppermute``s, 8 fields
    per byte per plane on the wire) and every pipeline slice is a
    byte-range view fed straight to the level-decomposed kernels — no
    per-slice re-encode.

    ``groups`` (batched campaigns) is the ``group_families`` partition of
    several requested metrics: every item contracts once per family and
    fans out through each member's epilogue, and the output gains a metric
    axis — (slots, M, L, m, m), flattened family order.  When ``groups``
    is None (the sequential API) the single ``metric`` runs as the
    degenerate one-family batch and the metric axis is squeezed away, so
    both entry points share one schedule implementation and the sequential
    output layout is unchanged.  The payload ring is identical either way
    — batching never adds a ppermute; only the (G, m) stat rows scale with
    family count.

    ``deferred=True`` (streamed chunk programs): identical schedule and
    ring, but every item stores its raw fp32 numerator partials — a
    4-tuple of slot buffers, with a leading family axis under ``groups``
    — and the per-vector stat partial is returned alongside, so
    ``repro.stream`` can accumulate across byte-axis chunks and assemble
    once in the cross-shard merge epilogue."""
    squeeze = groups is None
    if squeeze:
        groups = [[metric or CZEKANOWSKI]]
    planes = Vl.ndim == 3  # plane shards are 3-D, value shards 2-D
    n_pv, n_pr, n_st = cfg.n_pv, cfg.n_pr, cfg.n_st
    m = Vl.shape[-1]
    assert m % (6 * n_st) == 0, "n_vp must divide 6*n_st"
    L = m // (6 * n_st)
    n_groups = len(groups)
    n_metrics = sum(len(grp) for grp in groups)
    execs = [
        [TileExecutor(cfg=cfg, metric=s, out_dtype=out_dtype,
                      axis="pf", deferred=deferred) for s in grp]
        for grp in groups
    ]
    slots = plan.slots_per_rank

    pv = jax.lax.axis_index("pv")
    pr = jax.lax.axis_index("pr")
    perm = [((i + 1) % n_pv, i) for i in range(n_pv)]  # receive from upward

    if planes:
        # stats from the exact value reconstruction V = sum_t plane_t
        from repro.kernels.mgemm_levels import values_from_planes

        W = values_from_planes(Vl)
    else:
        W = Vl
    # (G, m): one psummed stat row per family, ring-carried as one array
    s_own = jnp.stack(
        [jax.lax.psum(grp[0].stat(W), "pf") for grp in groups]
    )
    if deferred:
        out0 = (
            jnp.zeros((slots, n_groups, L, m, m), jnp.float32),  # 3-way
            jnp.zeros((slots, n_groups, L, m), jnp.float32),  # pipe x left
            jnp.zeros((slots, n_groups, L, m), jnp.float32),  # pipe x right
            jnp.zeros((slots, n_groups, m, m), jnp.float32),  # left x right
        )
    else:
        out0 = jnp.zeros((slots, n_metrics, L, m, m), out_dtype)

    def j0_of(idx):
        return L * (stage + n_st * idx)

    def slot_of(sb):
        return sb // n_pr + (pr < (sb % n_pr)).astype(sb.dtype if hasattr(sb, "dtype") else jnp.int32)

    def emit(out, sb, execute, thunk):
        """Conditionally compute a slice and store it at this rank's slot."""
        def do(o):
            c3 = thunk()
            if deferred:  # c3 is the raw-partials 4-tuple
                return tuple(
                    jax.lax.dynamic_update_slice(
                        oo, cc[None], (slot_of(sb),) + (0,) * cc.ndim
                    )
                    for oo, cc in zip(o, c3)
                )
            return jax.lax.dynamic_update_slice(
                o, c3[None], (slot_of(sb),) + (0,) * c3.ndim
            )
        return jax.lax.cond(execute, do, lambda o: o, out)

    # ---- Phase A: diagonal-edge block, 6 static slices --------------------
    out = out0
    for s in range(6):
        execute = (s % n_pr) == pr
        out = emit(
            out,
            jnp.int32(s),
            execute,
            lambda s=s: _item_metrics(
                Vl, Vl, Vl, s_own, s_own, s_own, j0_of(s),
                kind=ItemKind.DIAG, L=L, execs=execs, groups=groups,
                out_dtype=out_dtype, deferred=deferred,
            ),
        )

    # ---- Phase B: face blocks, ring over dj -------------------------------
    def face_body(dj, carry):
        bufj, sbj, out = carry
        bufj = jax.lax.ppermute(bufj, "pv", perm)
        sbj = jax.lax.ppermute(sbj, "pv", perm)
        for s in range(6):  # pipe = right = J; left = own
            sb = 6 + s * (n_pv - 1) + (dj - 1)
            execute = (sb % n_pr) == pr
            out = emit(
                out,
                sb,
                execute,
                lambda s=s, bufj=bufj, sbj=sbj: _item_metrics(
                    bufj, Vl, bufj, sbj, s_own, sbj, j0_of(s),
                    kind=ItemKind.FACE, L=L, execs=execs, groups=groups,
                    out_dtype=out_dtype, deferred=deferred,
                ),
            )
        return bufj, sbj, out

    bufj, sbj, out = jax.lax.fori_loop(
        1, n_pv, face_body, (Vl, s_own, out)
    ) if n_pv > 1 else (Vl, s_own, out)
    # realign bufj to own block (it has advanced n_pv - 1 steps)
    if n_pv > 1:
        bufj = jax.lax.ppermute(bufj, "pv", perm)
        sbj = jax.lax.ppermute(sbj, "pv", perm)

    # ---- Phase C: volume blocks, doubly-nested ring (Algorithm 2) ---------
    sb_base = 6 + 6 * (n_pv - 1)

    def vol_inner(dj, carry):
        dk, bufk, sbk, bufj, sbj, sb, out = carry
        bufj = jax.lax.ppermute(bufj, "pv", perm)
        sbj = jax.lax.ppermute(sbj, "pv", perm)
        is_item = dj != dk
        execute = jnp.logical_and(is_item, (sb % n_pr) == pr)

        def thunk(bufk=bufk, sbk=sbk, bufj=bufj, sbj=sbj):
            bj_id = jnp.remainder(pv + dj, n_pv)
            bk_id = jnp.remainder(pv + dk, n_pv)
            axis, idx = _vol_rule_traced(pv, bj_id, bk_id)
            j0 = L * (stage + n_st * idx)
            # roles by sliced axis: 0 -> own, 1 -> J, 2 -> K is the pipe
            pipe, s_p = (
                jax.lax.switch(
                    axis,
                    [
                        lambda: (Vl, s_own),
                        lambda: (bufj, sbj),
                        lambda: (bufk, sbk),
                    ],
                )
            )
            left, s_l = jax.lax.switch(
                axis,
                [lambda: (bufj, sbj), lambda: (Vl, s_own), lambda: (Vl, s_own)],
            )
            right, s_r = jax.lax.switch(
                axis,
                [lambda: (bufk, sbk), lambda: (bufk, sbk), lambda: (bufj, sbj)],
            )
            return _item_metrics(
                pipe, left, right, s_p, s_l, s_r, j0,
                kind=ItemKind.VOL, L=L, execs=execs, groups=groups,
                out_dtype=out_dtype, deferred=deferred,
            )

        out = emit(out, sb, execute, thunk)
        sb = sb + is_item.astype(sb.dtype)
        return dk, bufk, sbk, bufj, sbj, sb, out

    def vol_outer(dk, carry):
        bufk, sbk, bufj, sbj, sb, out = carry
        bufk = jax.lax.ppermute(bufk, "pv", perm)
        sbk = jax.lax.ppermute(sbk, "pv", perm)
        dk_, bufk, sbk, bufj, sbj, sb, out = jax.lax.fori_loop(
            1, n_pv, vol_inner, (dk, bufk, sbk, bufj, sbj, sb, out)
        )
        # realign bufj to own block
        bufj = jax.lax.ppermute(bufj, "pv", perm)
        sbj = jax.lax.ppermute(sbj, "pv", perm)
        return bufk, sbk, bufj, sbj, sb, out

    if n_pv > 1:
        _, _, _, _, _, out = jax.lax.fori_loop(
            1, n_pv, vol_outer,
            (Vl, s_own, bufj, sbj, jnp.int32(sb_base), out),
        )
    if deferred:
        if squeeze:  # drop the one-family axis (sequential streamed API)
            out = tuple(o[:, 0] for o in out)
            return tuple(o[None, None] for o in out) + (s_own[0][None],)
        return tuple(o[None, None] for o in out) + (s_own[None],)
    if squeeze:  # drop the one-metric axis (sequential API layout)
        out = out[:, 0]
    return out[None, None]


@dataclass
class ThreeWayOutput:
    blocks: np.ndarray  # (n_pv, n_pr, slots, L, m, m)
    plan: ThreeWayPlan
    n_v: int
    n_vp: int
    stage: int

    def entries(self):
        """Yield (i, j, k, value) for every unique computed triple."""
        n_pv, n_pr = self.plan.n_pv, self.plan.n_pr
        m = self.n_vp
        L = self.blocks.shape[3]
        li = np.arange(m)
        for p_v in range(n_pv):
            for p_r in range(n_pr):
                items = self.plan.items_of(p_v, p_r)
                assert len(items) <= self.blocks.shape[2]
                for slot, it in enumerate(items):
                    own, bj, bk = it.blocks(p_v, n_pv)
                    lo, _ = self.plan.sixth_bounds(m, it.slice_idx, self.stage)
                    jg = lo + np.arange(L)
                    vals = self.blocks[p_v, p_r, slot]  # (L, m, m)
                    if it.kind == ItemKind.DIAG:
                        pipe_b = left_b = right_b = own
                        mask = (li[None, :, None] < jg[:, None, None]) & (
                            li[None, None, :] > jg[:, None, None]
                        )
                    elif it.kind == ItemKind.FACE:
                        pipe_b, left_b, right_b = bj, own, bj
                        mask = np.broadcast_to(
                            li[None, None, :] > jg[:, None, None], vals.shape
                        )
                    else:
                        if it.slice_axis == 0:
                            pipe_b, left_b, right_b = own, bj, bk
                        elif it.slice_axis == 1:
                            pipe_b, left_b, right_b = bj, own, bk
                        else:
                            pipe_b, left_b, right_b = bk, own, bj
                        mask = np.ones(vals.shape, bool)
                    T, Ll, R = np.meshgrid(jg, li, li, indexing="ij")
                    gi = pipe_b * m + T
                    gj = left_b * m + Ll
                    gk = right_b * m + R
                    mask = mask & (gi < self.n_v) & (gj < self.n_v) & (gk < self.n_v)
                    if mask.any():
                        yield gi[mask], gj[mask], gk[mask], vals[mask]

    def dense(self) -> np.ndarray:
        out = np.zeros((self.n_v,) * 3, self.blocks.dtype)
        for I, J, K, V in self.entries():
            idx = np.sort(np.stack([I, J, K]), axis=0)
            out[idx[0], idx[1], idx[2]] = V
        return out

    def checksum(self) -> int:
        return ck.combine([ck.raw_triples(I, J, K, V) for I, J, K, V in self.entries()])

    def num_triples(self) -> int:
        return sum(len(I) for I, _, _, _ in self.entries())


def _prep_payload3(V, cfg: CometConfig, metric: MetricSpec):
    """Resolve the config against V and build the sharded 3-way payload.

    Shared by the sequential and batched entry points (identical payload
    bytes either way).  Returns ``(cfg, arg, in_specs, n_vp, n_v)``.

    With the resolved ``encoding == "bitplane"`` the campaign encodes
    packed bit-planes ONCE here and the doubly-nested ring carries THEM
    through Phases B/C (for {0,1,2} SNP data 1/16 of the fp32 wire
    volume; see docs/BITPLANE_FORMAT.md) — otherwise the ring carries
    values (int8 auto-selection still quarters the fp32 wire traffic).

    Algorithm 3's pipeline geometry needs the per-rank block size to split
    into 6 sixths x n_st stages: round n_vp up to a multiple of 6*n_st and
    zero-pad.  All pad columns land at the global tail, so global index ==
    padded column index and entries() masks them with < n_v.
    """
    from repro.kernels.mgemm_levels.planes import PackedPlanes, pad_planes

    from repro.core.twoway import resolve_config

    unit = 6 * cfg.n_st
    if isinstance(V, PackedPlanes):
        n_v = V.n_v
        cfg = resolve_config(cfg, V, metric)  # always "bitplane" (or raises)
        n_vp = -(-n_v // cfg.n_pv)
        n_vp += (-n_vp) % unit
        Pp = pad_planes(V.planes, byte_align=cfg.n_pf, n_v=cfg.n_pv * n_vp)
        return cfg, jnp.asarray(Pp), P(None, "pf", "pv"), n_vp, n_v
    n_v = V.shape[1]
    V = np.asarray(V)
    cfg = resolve_config(cfg, V, metric)
    planes = cfg.encoding == "bitplane"
    n_vp = -(-n_v // cfg.n_pv)
    n_vp += (-n_vp) % unit
    fp = (-V.shape[0]) % cfg.n_pf
    Vp = np.pad(V, ((0, fp), (0, cfg.n_pv * n_vp - n_v)))
    if planes:
        # field_align pads fields to 8*n_pf so the BYTE axis splits
        # evenly over "pf" (planes.py owns the rule); pad bits are inert
        from repro.kernels.mgemm_levels import encode_bitplanes_np

        with obs.span("encode") as sp:
            arg = jnp.asarray(
                encode_bitplanes_np(Vp, cfg.levels, field_align=cfg.n_pf)
            )
            sp.add(bytes=int(arg.nbytes), levels=int(cfg.levels))
        in_specs = P(None, "pf", "pv")
    else:
        arg = jnp.asarray(Vp, dtype=jnp.dtype(cfg.ring_dtype))
        in_specs = P("pf", "pv")
    return cfg, arg, in_specs, n_vp, n_v


def threeway_distributed(
    V, mesh: Mesh, cfg: CometConfig, stage: int = 0,
    metric: MetricSpec = None,
) -> ThreeWayOutput:
    """Compute one stage of the unique 3-way metrics of V's columns.

    ``V``: (n_f, n_v) value matrix, or a pre-encoded ``PackedPlanes``
    payload (``repro.store`` zero-encode loading) — re-padded packed, never
    re-encoded on the host."""
    metric = metric or CZEKANOWSKI
    cfg, arg, in_specs, n_vp, n_v = _prep_payload3(V, cfg, metric)
    plan = ThreeWayPlan(cfg.n_pv, cfg.n_pr, cfg.n_st)
    out_dtype = jnp.dtype(cfg.out_dtype)

    fn = shard_map(
        partial(_threeway_program, cfg=cfg, plan=plan, stage=stage,
                out_dtype=out_dtype, metric=metric),
        mesh=mesh,
        in_specs=in_specs,
        out_specs=P("pv", "pr", None, None, None, None),
        check=False,
    )
    jfn = jax.jit(fn, static_argnames=())
    with obs.span("ring-step") as sp:
        blocks = obs.fence(jfn(arg))
        sp.add(stage=int(stage), payload_bytes=int(arg.nbytes))
    obs.roofline_event(jfn, (arg,), int(mesh.devices.size))
    L = n_vp // (6 * cfg.n_st)
    blocks = np.asarray(blocks).reshape(
        cfg.n_pv, cfg.n_pr, plan.slots_per_rank, L, n_vp, n_vp
    )
    return ThreeWayOutput(blocks=blocks, plan=plan, n_v=n_v, n_vp=n_vp, stage=stage)


def threeway_batched(
    V, mesh: Mesh, cfg: CometConfig, specs, stage: int = 0,
) -> tuple:
    """Batched 3-way campaigns: one tetrahedral traversal, one result per
    metric.

    ``specs``: MetricSpecs sharing the SAME payload ('auto' knobs resolve
    against ``batch_lead(specs)``).  Returns ``(outputs, binfo)``:
    per-spec ``ThreeWayOutput`` in request order, each bit-identical to
    its sequential ``threeway_distributed`` run, plus the per-stage
    ring-traffic accounting (payload hops independent of metric count).
    """
    specs = list(specs)
    cfg, arg, in_specs, n_vp, n_v = _prep_payload3(V, cfg, batch_lead(specs))
    groups = group_families(specs)
    flat = [s for grp in groups for s in grp]
    plan = ThreeWayPlan(cfg.n_pv, cfg.n_pr, cfg.n_st)
    out_dtype = jnp.dtype(cfg.out_dtype)

    fn = shard_map(
        partial(_threeway_program, cfg=cfg, plan=plan, stage=stage,
                out_dtype=out_dtype, groups=groups),
        mesh=mesh,
        in_specs=in_specs,
        out_specs=P("pv", "pr", None, None, None, None, None),
        check=False,
    )
    jfn = jax.jit(fn)
    with obs.span("ring-step") as sp:
        blocks = obs.fence(jfn(arg))
        sp.add(stage=int(stage), payload_bytes=int(arg.nbytes),
               metrics=len(flat))
    obs.roofline_event(jfn, (arg,), int(mesh.devices.size))
    blocks = np.asarray(blocks)
    L = n_vp // (6 * cfg.n_st)
    blocks = blocks.reshape(
        cfg.n_pv, cfg.n_pr, plan.slots_per_rank, len(flat), L, n_vp, n_vp
    )
    by_name = {
        s.name: ThreeWayOutput(
            blocks=np.ascontiguousarray(blocks[:, :, :, i]), plan=plan,
            n_v=n_v, n_vp=n_vp, stage=stage,
        )
        for i, s in enumerate(flat)
    }
    binfo = batch_accounting(
        int(arg.nbytes), cfg, plan, groups, n_vp,
        planes=(arg.ndim == 3), way=3,
    )
    return [by_name[s.name] for s in specs], binfo


def czek3_distributed(
    V: np.ndarray, mesh: Mesh, cfg: CometConfig, stage: int = 0
) -> ThreeWayOutput:
    """Proportional Similarity 3-way campaign (pre-registry entry point)."""
    return threeway_distributed(V, mesh, cfg, stage=stage, metric=CZEKANOWSKI)
