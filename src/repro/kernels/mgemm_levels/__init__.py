from .ops import mgemm_levels, mgemm_levels_xla  # noqa: F401
from .ref import mgemm_levels_ref  # noqa: F401
