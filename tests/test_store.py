"""repro.store: on-disk packed bit-plane dataset store.

Pins the normative on-disk contract (docs/BITPLANE_FORMAT.md "On-disk
storage"):

* write -> read round-trips byte-identically to ``encode_bitplanes_np`` of
  the full matrix (streaming field-sharded writes included), for
  non-multiple-of-8 field AND vector counts;
* memory-mapped views equal eager loads, and a disk field shard IS the
  ``shard_planes_fields`` byte range;
* the exact-stats sidecar holds per-plane popcounts whose sum is the
  column-sum denominator stat; ``levels=1`` (binary / Sorenson) datasets
  round-trip as a single plane with stats == popcounts;
* PLINK ``.bed`` ingest decodes a hand-built fixture to the hand-decoded
  dosage matrix under every missing-genotype policy;
* manifest round-trip carries provenance into ``SimilarityResult`` saves;
* campaigns loaded via ``InputSpec(source="planes")`` are bit-identical to
  the in-memory matrix on BOTH engines and provably never call the host
  encoder (counter monkeypatch); multi-device decompositions are covered
  in tests/distributed_harness.py.
"""
import json
import os

import numpy as np
import pytest

import repro.kernels.mgemm_levels as mgemm_levels
from repro.api import InputSpec, SimilarityEngine, SimilarityRequest, SimilarityResult
from repro.core.synthetic import random_integer_vectors
from repro.kernels.mgemm_levels import (
    PackedPlanes,
    encode_bitplanes_np,
    pad_planes,
    shard_planes_fields,
)
from repro.store import (
    DatasetReader,
    read_bed,
    read_manifest,
    write_dataset,
)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


def _matrix(n_f, n_v, levels, seed=0):
    return random_integer_vectors(n_f, n_v, max_value=levels, seed=seed)


# -- write -> read == encode of the full matrix -----------------------------


def _check_roundtrip(tmp_path, n_f, n_v, levels, n_shards, seed=0):
    V = _matrix(n_f, n_v, levels, seed)
    path = os.path.join(str(tmp_path), f"ds_{n_f}x{n_v}_{levels}_{n_shards}")
    manifest = write_dataset(path, V, levels=levels, n_shards=n_shards)
    r = DatasetReader(path)
    full = encode_bitplanes_np(V, levels, field_align=n_shards)
    assert np.array_equal(r.planes(), full)
    assert manifest["kb"] == full.shape[1]
    # mmap view == eager load; shards really are byte-range memmaps
    assert np.array_equal(r.planes(mmap=True), r.planes(mmap=False))
    assert isinstance(r.shard(0, mmap=True), np.memmap)
    # disk field shard == the engines' "pf" byte range
    for rank in range(n_shards):
        assert np.array_equal(
            r.shard(rank), shard_planes_fields(full, rank, n_shards)
        ), (rank, n_shards)
    # exact-stats sidecar: popcounts per plane; summed -> column sums
    stats = r.stats()
    assert stats.shape == (levels, n_v)
    assert np.array_equal(stats.sum(axis=0), V.sum(axis=0).astype(np.int64))
    r.validate()


@pytest.mark.parametrize(
    "n_f,n_v,levels,n_shards",
    [
        (64, 16, 2, 1),
        (64, 16, 2, 2),
        (29, 10, 2, 1),   # non-multiple-of-8 fields
        (29, 10, 2, 2),   # ... with a padded tail shard
        (13, 7, 3, 4),    # shards wider than the data
        (40, 9, 1, 1),    # binary (Sorenson)
        (8, 3, 15, 1),    # deep level stack
    ],
)
def test_write_read_roundtrip(tmp_path, n_f, n_v, levels, n_shards):
    _check_roundtrip(tmp_path, n_f, n_v, levels, n_shards)


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        n_f=st.integers(1, 70),
        n_v=st.integers(1, 12),
        levels=st.integers(1, 4),
        n_shards=st.integers(1, 3),
        seed=st.integers(0, 5),
    )
    def test_write_read_roundtrip_property(tmp_path_factory, n_f, n_v,
                                           levels, n_shards, seed):
        _check_roundtrip(tmp_path_factory.mktemp("ds"), n_f, n_v, levels,
                         n_shards, seed)


# -- writer guards ----------------------------------------------------------


def test_writer_rejects_out_of_range(tmp_path):
    V = _matrix(16, 4, 3)
    with pytest.raises(ValueError, match="max value 3.* exceeds levels=2"):
        write_dataset(str(tmp_path / "bad"), V, levels=2)


def test_writer_rejects_non_integer_and_negative(tmp_path):
    with pytest.raises(ValueError, match="non-integer"):
        write_dataset(str(tmp_path / "f"), np.full((4, 2), 0.5), levels=1)
    with pytest.raises(ValueError, match="min value -1"):
        write_dataset(str(tmp_path / "n"), np.full((4, 2), -1), levels=1)


def test_levels1_binary_guard_and_popcount_stats(tmp_path):
    """levels=1 (Sorenson use case): store admits exactly {0,1} matrices and
    the single plane's popcounts ARE the per-vector stats — the identity the
    ROADMAP popcount-kernel item will build on."""
    with pytest.raises(ValueError, match="exceeds levels=1"):
        write_dataset(str(tmp_path / "bad1"), _matrix(16, 4, 2), levels=1)
    V = _matrix(21, 6, 1, seed=3)
    path = str(tmp_path / "bin")
    write_dataset(path, V, levels=1)
    r = DatasetReader(path)
    assert r.levels == 1 and r.planes().shape[0] == 1
    stats = r.stats()
    assert np.array_equal(stats[0], V.sum(axis=0).astype(np.int64))
    assert np.array_equal(stats[0], stats.sum(axis=0))  # stats == popcounts
    r.validate()


# -- validate() catches corruption ------------------------------------------


def test_validate_catches_payload_corruption(tmp_path):
    path = str(tmp_path / "ds")
    manifest = write_dataset(path, _matrix(24, 6, 2), levels=2)
    shard = os.path.join(path, manifest["shard_files"][0])
    P = np.load(shard)
    P[0, 0, 0] ^= 1
    np.save(shard, P)
    with pytest.raises(ValueError, match="checksum"):
        DatasetReader(path).validate()


def test_manifest_structural_validation(tmp_path):
    path = str(tmp_path / "ds")
    write_dataset(path, _matrix(24, 6, 2), levels=2)
    with open(os.path.join(path, "dataset.json")) as f:
        m = json.load(f)
    m["kb"] = 7  # not divisible by n_shards is fine for 1; break n_f bound
    m["n_f"] = 99
    with open(os.path.join(path, "dataset.json"), "w") as f:
        json.dump(m, f)
    with pytest.raises(ValueError, match="n_f=99"):
        read_manifest(path)
    with pytest.raises(ValueError, match="not a dataset directory"):
        read_manifest(str(tmp_path / "nowhere"))


# -- manifest round-trip with provenance ------------------------------------


def test_manifest_provenance_roundtrip(tmp_path):
    V = _matrix(32, 8, 2, seed=9)
    ds = str(tmp_path / "ds")
    write_dataset(ds, V, levels=2,
                  source={"kind": "npy", "path": "/data/cohort.npy"})
    m = read_manifest(ds)
    assert m["source"] == {"kind": "npy", "path": "/data/cohort.npy"}
    # the campaign result's manifest records the dataset provenance...
    request = SimilarityRequest(way=2, impl="levels", levels=2,
                                input=InputSpec(source="planes", path=ds))
    result = SimilarityEngine().run(request)
    assert result.meta["dataset"]["checksum"] == m["checksum"]
    out = str(tmp_path / "result")
    saved = result.save(out)
    assert saved["dataset"]["path"] == ds
    # ... and provenance survives the result load round-trip
    loaded = SimilarityResult.load(out)
    assert loaded.meta["dataset"]["checksum"] == m["checksum"]
    assert loaded.checksum() == result.checksum()


# -- PLINK .bed ingest ------------------------------------------------------

_BED_DOSAGES = np.array([  # 3 SNPs x 5 samples; 255 = missing
    [2, 1, 0, 0, 1],
    [0, 0, 2, 1, 255],
    [1, 1, 1, 2, 0],
])


def _write_bed_fixture(tmp_path):
    """Hand-pack the PLINK 2-bit codes for _BED_DOSAGES."""
    code_of = {2: 0b00, 1: 0b10, 0: 0b11, 255: 0b01}
    payload = b""
    for snp in _BED_DOSAGES:
        for b0 in range(0, len(snp), 4):
            byte = 0
            for i, s in enumerate(snp[b0:b0 + 4]):
                byte |= code_of[int(s)] << (2 * i)
            payload += bytes([byte])
    prefix = os.path.join(str(tmp_path), "toy")
    with open(prefix + ".bed", "wb") as f:
        f.write(b"\x6c\x1b\x01" + payload)
    with open(prefix + ".bim", "w") as f:
        f.write("".join(f"1 snp{i} 0 {i} A G\n" for i in range(3)))
    with open(prefix + ".fam", "w") as f:
        f.write("".join(f"f{i} i{i} 0 0 0 -9\n" for i in range(5)))
    return prefix


def test_bed_parity_and_missing_policies(tmp_path):
    prefix = _write_bed_fixture(tmp_path)
    with pytest.raises(ValueError, match="missing genotype"):
        read_bed(prefix)
    V, info = read_bed(prefix, missing="zero")
    assert np.array_equal(V, np.where(_BED_DOSAGES == 255, 0, _BED_DOSAGES).T)
    assert info["n_missing"] == 1 and info["missing_policy"] == "zero"
    Vd, infod = read_bed(prefix, missing="drop")
    assert np.array_equal(Vd, _BED_DOSAGES[[0, 2]].T)
    assert infod["dropped_snps"] == 1
    Vs, _ = read_bed(prefix, missing="zero", vectors="samples")
    assert np.array_equal(Vs, np.where(_BED_DOSAGES == 255, 0, _BED_DOSAGES))
    # .bed -> store -> campaign equals the same campaign on the decoded matrix
    from dataclasses import replace

    ds = str(tmp_path / "ds")
    write_dataset(ds, V, levels=2, n_shards=1)
    request = SimilarityRequest(way=2, impl="levels", levels=2)
    engine = SimilarityEngine()
    assert (engine.run(request, V).checksum()
            == engine.run(replace(request,
                                  input=InputSpec(source="planes", path=ds))
                          ).checksum())


def test_bed_rejects_bad_headers(tmp_path):
    prefix = _write_bed_fixture(tmp_path)
    with open(prefix + ".bed", "r+b") as f:
        f.seek(2)
        f.write(b"\x00")  # individual-major
    with pytest.raises(ValueError, match="individual-major"):
        read_bed(prefix, missing="zero")
    with open(prefix + ".bed", "r+b") as f:
        f.write(b"\x00\x00")
    with pytest.raises(ValueError, match="bad magic"):
        read_bed(prefix, missing="zero")
    with open(prefix + ".bed", "wb") as f:
        f.write(b"\x6c\x1b")  # magic only, no mode byte
    with pytest.raises(ValueError, match="truncated header"):
        read_bed(prefix, missing="zero")
    os.remove(prefix + ".fam")
    with pytest.raises(ValueError, match="incomplete"):
        read_bed(prefix, missing="zero")


def test_bed_input_spec_materializes_dosages(tmp_path):
    prefix = _write_bed_fixture(tmp_path)
    V = InputSpec(source="bed", path=prefix, missing="zero").materialize()
    assert V.shape == (5, 3) and V.max() == 2


# -- zero-encode campaign loading (acceptance criterion) --------------------


def _counting_encoder(monkeypatch):
    calls = {"n": 0}
    orig = mgemm_levels.encode_bitplanes_np

    def counted(*args, **kwargs):
        calls["n"] += 1
        return orig(*args, **kwargs)

    monkeypatch.setattr(mgemm_levels, "encode_bitplanes_np", counted)
    return calls


@pytest.mark.parametrize("way,impl", [
    (2, "levels"), (2, "levels_xla"), (3, "levels"), (3, "levels_xla"),
])
def test_planes_campaign_parity_and_zero_encode(tmp_path, monkeypatch,
                                                way, impl):
    """source='planes' checksums == in-memory checksums on both engines,
    and the pre-encoded path never calls the host encoder."""
    V = _matrix(29, 12, 2, seed=11)  # non-multiple-of-8 fields
    ds = str(tmp_path / "ds")
    write_dataset(ds, V, levels=2)
    engine = SimilarityEngine()
    ref = engine.run(
        SimilarityRequest(way=way, impl=impl, levels=2), V
    ).checksum()
    calls = _counting_encoder(monkeypatch)
    got = engine.run(SimilarityRequest(
        way=way, impl=impl, levels=2,
        input=InputSpec(source="planes", path=ds),
    ))
    assert got.checksum() == ref
    assert calls["n"] == 0, "pre-encoded campaign ran the host encoder"
    # sanity: the counter DOES see the in-memory encode
    engine.run(SimilarityRequest(way=way, impl=impl, levels=2), V)
    assert calls["n"] > 0


def test_planes_input_requires_plane_path(tmp_path):
    ds = str(tmp_path / "ds")
    write_dataset(ds, _matrix(16, 6, 2), levels=2)
    engine = SimilarityEngine()
    spec = InputSpec(source="planes", path=ds)
    with pytest.raises(ValueError, match="impl="):
        engine.run(SimilarityRequest(way=2, impl="xla", input=spec))
    with pytest.raises(ValueError, match="encoding='none'"):
        engine.run(SimilarityRequest(way=2, impl="levels", levels=2,
                                     encoding="none", input=spec))
    with pytest.raises(ValueError, match="levels=3"):
        engine.run(SimilarityRequest(way=2, impl="levels", levels=3,
                                     input=spec))


def test_service_cache_fingerprints_planes_input(tmp_path):
    """The serving cache keys pre-encoded input on payload BYTES (a naive
    ndarray coercion of the PackedPlanes dataclass would hash object
    pointers and never hit)."""
    from repro.serve.engine import SimilarityService

    ds = str(tmp_path / "ds")
    write_dataset(ds, _matrix(24, 8, 2, seed=1), levels=2)
    svc = SimilarityService()
    request = SimilarityRequest(way=2, impl="levels", levels=2,
                                input=InputSpec(source="planes", path=ds))
    first = svc.submit(request)
    again = svc.submit(request)  # fresh materialize -> same payload bytes
    assert svc.hits == 1 and svc.misses == 1
    assert first.checksum() == again.checksum()
    # provenance travels on the PackedPlanes handle, so even the serving
    # path (which materializes BEFORE engine.run) records the dataset
    assert first.meta["dataset"]["checksum"] == read_manifest(ds)["checksum"]


# -- PackedPlanes / pad_planes unit coverage --------------------------------


def test_packed_planes_validation():
    P = encode_bitplanes_np(_matrix(16, 4, 2), 2)
    with pytest.raises(ValueError, match="uint8"):
        PackedPlanes(P.astype(np.int16), n_f=16)
    with pytest.raises(ValueError, match="n_f"):
        PackedPlanes(P, n_f=99)
    with pytest.raises(ValueError, match="3-D|levels"):
        PackedPlanes(P[0], n_f=16)
    # identity semantics (eq=False): comparing/hashing handles must not
    # trip over the ndarray field
    a, b = PackedPlanes(P, n_f=16), PackedPlanes(P.copy(), n_f=16)
    assert a == a and a != b and isinstance(hash(a), int)


def test_pad_planes_commutes_with_encode():
    V = _matrix(13, 5, 2, seed=2)
    P = encode_bitplanes_np(V, 2)
    got = pad_planes(P, byte_align=2, n_v=8)
    want = encode_bitplanes_np(np.pad(V, ((0, 0), (0, 3))), 2, field_align=2)
    assert np.array_equal(got, want)
    with pytest.raises(ValueError, match="shrink"):
        pad_planes(P, n_v=3)


# -- InputSpec(source="npy") validation (satellite) -------------------------


def _save_npy(tmp_path, name, arr):
    path = os.path.join(str(tmp_path), name)
    np.save(path, arr)
    return path


def test_npy_validation_names_offending_stat(tmp_path):
    ok = _save_npy(tmp_path, "ok.npy", _matrix(16, 4, 2))
    assert InputSpec(source="npy", path=ok).materialize().shape == (16, 4)
    bad_shape = _save_npy(tmp_path, "s.npy", np.zeros(7))
    with pytest.raises(ValueError, match=r"2-D .*got shape \(7,\)"):
        InputSpec(source="npy", path=bad_shape).materialize()
    nonfinite = _save_npy(tmp_path, "nf.npy",
                          np.array([[1.0, np.nan], [np.inf, 0.0]]))
    with pytest.raises(ValueError, match="2 non-finite"):
        InputSpec(source="npy", path=nonfinite).materialize()
    negative = _save_npy(tmp_path, "neg.npy", np.array([[1, -3], [0, 2]]))
    with pytest.raises(ValueError, match="min value -3"):
        InputSpec(source="npy", path=negative).materialize()
    huge = _save_npy(tmp_path, "huge.npy",
                     np.full((64, 4), 2 ** 20, np.int64))
    with pytest.raises(ValueError, match="overflows exact fp32"):
        InputSpec(source="npy", path=huge).materialize()
    empty = _save_npy(tmp_path, "e.npy", np.zeros((0, 4)))
    with pytest.raises(ValueError, match="empty"):
        InputSpec(source="npy", path=empty).materialize()
    # bool matrices (binary/Sorenson) are legal input and store as levels=1
    boolean = _save_npy(tmp_path, "bool.npy", _matrix(16, 4, 1).astype(bool))
    Vb = InputSpec(source="npy", path=boolean).materialize()
    assert Vb.dtype == np.bool_
    write_dataset(str(tmp_path / "bool_ds"), Vb, levels=1)
    DatasetReader(str(tmp_path / "bool_ds")).validate()
    # sparse large-n_f matrices pass the ACTUAL-column-sum overflow gate
    sparse = np.zeros((100_000, 2), np.int32)
    sparse[:5] = 15
    ok_sparse = _save_npy(tmp_path, "sparse.npy", sparse)
    assert InputSpec(source="npy", path=ok_sparse).materialize().shape[0] == 100_000
