"""repro.store — on-disk packed bit-plane dataset store.

A dataset directory holds per-field-shard uint8 plane payloads in the
normative ``(levels, kb, n_v)`` wire layout of docs/BITPLANE_FORMAT.md
("On-disk storage" chapter), an exact-stats sidecar, and a checksummed
``dataset.json`` manifest.  ``write_dataset`` ingests npy / synthetic /
PLINK ``.bed`` sources with streaming field-sharded encodes;
``DatasetReader`` serves memory-mapped plane views whose ``packed()``
handle both distributed engines consume directly — campaigns load planes
from disk and never run the host encoder.  CLI:
``python -m repro.launch.dataset {encode,inspect,validate}`` and
``python -m repro.launch.similarity --dataset``.
"""
from repro.store.bed import bed_paths, read_bed  # noqa: F401
from repro.store.format import (  # noqa: F401
    FORMAT_NAME,
    FORMAT_VERSION,
    MANIFEST_NAME,
    read_manifest,
)
from repro.store.reader import DatasetReader, ShardedPlanes  # noqa: F401
from repro.store.writer import (  # noqa: F401
    append_dataset,
    validate_leveled,
    write_dataset,
)

__all__ = [
    "DatasetReader",
    "ShardedPlanes",
    "write_dataset",
    "append_dataset",
    "validate_leveled",
    "read_bed",
    "bed_paths",
    "read_manifest",
    "FORMAT_NAME",
    "FORMAT_VERSION",
    "MANIFEST_NAME",
]
