"""jit'd public wrappers for the mGEMM Pallas kernels + impl registration.

Wrappers interpret automatically off-TPU (kernel-body-on-CPU), which is how
the CPU test harness and CI drive every kernel path.
"""
from __future__ import annotations

import jax

from repro.core.mgemm import register_impl

from .kernel import (
    czek2_metric_pallas,
    metric2_pallas,
    metric2_tri_pallas,
    mgemm_pallas,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def mgemm(A, B, **kw):
    """Pallas mGEMM; interprets automatically off-TPU (kernel-body-on-CPU)."""
    kw.setdefault("interpret", not _on_tpu())
    return mgemm_pallas(A, B, **kw)


def czek2_metric(A, B, sa, sb, **kw):
    kw.setdefault("interpret", not _on_tpu())
    return czek2_metric_pallas(A, B, sa, sb, **kw)


def metric2_tiles(A, B, sa, sb, *, combine, epilogue, **kw):
    """Generated fused metric kernel, rectangular tile grid."""
    kw.setdefault("interpret", not _on_tpu())
    return metric2_pallas(A, B, sa, sb, combine=combine, epilogue=epilogue, **kw)


def metric2_tri(A, B, sa, sb, *, combine, epilogue, **kw):
    """Generated fused metric kernel, triangular (diagonal-block) grid.

    Returns packed (P, bt, bt) tiles; see ``unpack_tri_tiles``."""
    kw.setdefault("interpret", not _on_tpu())
    return metric2_tri_pallas(A, B, sa, sb, combine=combine, epilogue=epilogue, **kw)


register_impl("pallas", mgemm)
