"""Input-matrix validation shared by the API loaders and the dataset store.

One structural gate for every externally supplied (n_f, n_v) matrix — the
``.npy`` loader (``InputSpec``), the dataset writer, and the ``.bed``
transcode all funnel through here so hostile inputs fail with an error
naming the offending stat (shape / dtype / non-finite count / min / max /
column sum) instead of surfacing as a wrong checksum downstream.

Two layered checks on top of the structural gate:

* ``levels`` — require integer values in ``[0, levels]``, the exactness
  domain of the plane decomposition (the store writer's guard; ``levels=1``
  thereby admits exactly binary matrices).
* ``check_fp32_sums`` — require every actual column sum below ``2^24`` so
  integer accumulation stays exact in fp32 (paper §5's bit-exactness
  contract).  The bound is the real ``max(colsum)``, not the worst-case
  ``max * n_f`` — sparse matrices with large ``n_f`` are fine.
"""
from __future__ import annotations

import numpy as np

__all__ = ["validate_matrix"]

_FP32_EXACT = 2 ** 24


def validate_matrix(
    V: np.ndarray, *, what: str, levels: int = None,
    check_fp32_sums: bool = False,
) -> np.ndarray:
    """Raise ValueError naming the offending stat; return V unchanged."""
    if V.ndim != 2:
        raise ValueError(
            f"{what}: expected a 2-D (n_f, n_v) matrix, got shape {V.shape}"
        )
    if V.size == 0:
        raise ValueError(f"{what}: empty matrix {V.shape}")
    is_bool = V.dtype == np.bool_  # binary/Sorenson matrices save as bool
    if not is_bool and (
        not np.issubdtype(V.dtype, np.number)
        or np.issubdtype(V.dtype, np.complexfloating)
    ):
        raise ValueError(f"{what}: unsupported dtype {V.dtype} (need real numeric)")
    if np.issubdtype(V.dtype, np.floating) and not np.isfinite(V).all():
        bad = int(V.size - np.isfinite(V).sum())
        raise ValueError(f"{what}: {bad} non-finite entries")
    lo = V.min()
    if lo < 0:
        raise ValueError(
            f"{what}: min value {lo} is negative (similarity metrics assume "
            f"non-negative data)"
        )
    integral = (
        is_bool
        or np.issubdtype(V.dtype, np.integer)
        or bool((V == np.floor(V)).all())
    )
    if levels is not None:
        if not integral:
            raise ValueError(
                f"{what}: non-integer values (plane encoding is exact only "
                f"for integers in [0, levels])"
            )
        hi = V.max()
        if hi > levels:
            raise ValueError(
                f"{what}: max value {hi} exceeds levels={levels} — the plane "
                f"decomposition would silently clip; re-encode with levels>="
                f"{int(hi)}"
            )
    if check_fp32_sums and integral:
        # dtype=float64 accumulates without materializing a converted copy
        smax = V.sum(axis=0, dtype=np.float64).max()
        if smax >= _FP32_EXACT:
            raise ValueError(
                f"{what}: max column sum {int(smax)} overflows exact fp32 "
                f"integer accumulation (2^24) — the paper's bit-exactness "
                f"contract would silently break"
            )
    return V
