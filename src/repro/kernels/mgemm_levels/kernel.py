"""Pallas TPU kernel: level-decomposition mGEMM on the MXU (beyond-paper).

For inputs quantized to integer levels {0, 1, ..., L}:

    min(a, b) = sum_{t=1}^{L} 1[a >= t] * 1[b >= t]

so the min-plus contraction equals a sum of L *ordinary* GEMMs of 0/1
indicator matrices — which the 128x128 MXU executes at bf16 peak
(197 TFLOP/s on v5e) instead of the ~1 TOP/s VPU rate of the faithful
kernel.  Exact for integer data with values <= L (SNP allele counts are
{0,1,2}; the paper's companion CCC work uses 2-3 bit codes).  This is the
TPU-native generalization of the paper's §2.3 observation that the binary
(Sorenson) case maps to fast bit arithmetic.

Indicator construction happens in VMEM per tile (on the VPU, overlapped by
the MXU matmuls), so HBM traffic is identical to a plain GEMM of the raw
operands.

Cost: L * 2*M*N*K MXU FLOPs; for L <= 4 a ~25-50x win over the VPU kernel on
the compute roofline term (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.mgemm.kernel import _tri_decode, tri_tile_coords

DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 512
# packed-plane kernels tile the contraction in BYTES (8 fields per byte)
DEFAULT_BKB = 64


def _levels_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k_steps: int, levels: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    acc = jnp.zeros_like(acc_ref)
    for t in range(1, levels + 1):  # static unroll: L MXU matmuls per tile
        at = (a >= t).astype(jnp.bfloat16)
        bt = (b >= t).astype(jnp.bfloat16)
        acc += jnp.dot(at, bt, preferred_element_type=jnp.float32)
    acc_ref[...] += acc

    @pl.when(pl.program_id(2) == n_k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("levels", "bm", "bn", "bk", "interpret", "out_dtype")
)
def mgemm_levels_pallas(
    A,
    B,
    *,
    levels: int,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
    out_dtype=jnp.float32,
):
    """Exact min-plus GEMM for integer-valued A, B in [0, levels]."""
    m, k = A.shape
    k2, n = B.shape
    assert k == k2
    mp, np_, kp = (-m) % bm, (-n) % bn, (-k) % bk
    if mp or kp:
        A = jnp.pad(A, ((0, mp), (0, kp)))  # pad 0 -> indicator 0 -> no contribution
    if np_ or kp:
        B = jnp.pad(B, ((0, kp), (0, np_)))
    M, K = A.shape
    N = B.shape[1]
    n_k_steps = K // bk
    grid = (M // bm, N // bn, n_k_steps)
    out = pl.pallas_call(
        functools.partial(_levels_kernel, n_k_steps=n_k_steps, levels=levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, t: (i, t)),
            pl.BlockSpec((bk, bn), lambda i, j, t: (t, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(A, B)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# Packed bit-plane kernels (the fused campaign path)
#
# Operands are pre-encoded packed planes in the documented wire layout
# (docs/BITPLANE_FORMAT.md; encoders in ``planes.py``): (levels, kb, w)
# uint8, field-major, 8 plane-bits per byte LSB-first along the
# contraction axis.  ``_unpack_plane_tile`` / ``_plane_matmuls`` below are
# THE shared realization of that layout — the 3-way slice kernel
# (kernels/czek3) imports them so the engines can never drift.
# Each K-tile unpacks its byte tile in VMEM (VPU work,
# overlapped by the MXU) and performs ``levels`` MXU ``dot_general``s into a
# fp32 VMEM accumulator; the flush applies the metric's ``assemble_tile``
# epilogue in place, so — like the VPU fused path — the numerator block
# never round-trips HBM.  Bit-planes are built ONCE per campaign instead of
# ``(V >= t)`` per ring step, and the packed operands are what the ring
# carries (L/32 of the fp32 wire traffic).
# ---------------------------------------------------------------------------


def _unpack_plane_tile(bytes_u8):
    """(bkb, w) packed uint8 -> (8*bkb, w) bf16 indicator tile, LSB-first."""
    kb, w = bytes_u8.shape
    shifts = jax.lax.broadcasted_iota(jnp.int32, (1, 8, 1), 1)
    bits = (bytes_u8.astype(jnp.int32)[:, None, :] >> shifts) & 1
    return bits.reshape(kb * 8, w).astype(jnp.bfloat16)


def _plane_matmuls(pa, pb, levels: int):
    """sum_t unpack(pa[t])^T-free field-major contraction on the MXU.

    pa (levels, bkb, bm), pb (levels, bkb, bn) packed tiles; contracts the
    unpacked field axis (axis 0 of each plane tile) -> (bm, bn) fp32."""
    acc = None
    for t in range(levels):  # static unroll: L MXU matmuls per K-tile
        at = _unpack_plane_tile(pa[t])
        bt = _unpack_plane_tile(pb[t])
        part = jax.lax.dot_general(
            at, bt, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc = part if acc is None else acc + part
    return acc


def _levels_fused_kernel(
    pa_ref, pb_ref, sa_ref, sb_ref, o_ref, acc_ref,
    *, n_k_steps: int, levels: int, epilogue,
):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _plane_matmuls(pa_ref[...], pb_ref[...], levels)

    @pl.when(pl.program_id(2) == n_k_steps - 1)
    def _flush():
        acc = acc_ref[...]
        vals = acc if epilogue is None else epilogue(
            acc, sa_ref[...], sb_ref[...]
        )
        o_ref[...] = vals.astype(o_ref.dtype)


def _levels_fused_tri_kernel(
    idx_ref, pa_ref, pb_ref, sa_ref, sb_ref, o_ref, acc_ref,
    *, n_k_steps: int, levels: int, epilogue,
):
    """Triangular-schedule plane kernel for diagonal blocks (paper §5):
    grid axis 0 walks only the ``tj >= ti`` tiles; on-diagonal tiles are
    masked to the strict upper triangle at flush."""
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _plane_matmuls(pa_ref[...], pb_ref[...], levels)

    @pl.when(pl.program_id(1) == n_k_steps - 1)
    def _flush():
        acc = acc_ref[...]
        vals = acc if epilogue is None else epilogue(
            acc, sa_ref[...], sb_ref[...]
        )
        on_diag = idx_ref[0, 0] == idx_ref[0, 1]
        li = jax.lax.broadcasted_iota(jnp.int32, vals.shape, 0)
        lj = jax.lax.broadcasted_iota(jnp.int32, vals.shape, 1)
        keep = jnp.logical_or(jnp.logical_not(on_diag), li < lj)
        o_ref[0] = jnp.where(keep, vals, 0.0).astype(o_ref.dtype)


def _pad_planes(P, last_pad: int, kb_pad: int):
    """Zero-pad packed planes: zero bytes are zero plane bits -> inert."""
    if last_pad or kb_pad:
        P = jnp.pad(P, ((0, 0), (0, kb_pad), (0, last_pad)))
    return P


def _pad_stat(s, pad: int):
    """Stats pad with ZERO so ``safe_denom`` covers pad rows/columns exactly
    like all-zero real vectors (same contract as mgemm._pad_operands)."""
    return jnp.pad(jnp.asarray(s, jnp.float32).reshape(-1), (0, pad))


@functools.partial(
    jax.jit,
    static_argnames=("epilogue", "bm", "bn", "bkb", "interpret", "out_dtype"),
)
def metric2_levels_pallas(
    Pa,
    Pb,
    sa,
    sb,
    *,
    epilogue,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bkb: int = DEFAULT_BKB,
    interpret: bool = False,
    out_dtype=jnp.float32,
):
    """Fused 2-way metric kernel on packed bit-planes (rectangular grid).

    Pa (levels, kb, m) / Pb (levels, kb, n) packed planes of the two vector
    blocks; sa (m,) / sb (n,) per-vector stats.  Returns
    ``epilogue(sum_t plane_t(A)^T @ plane_t(B), sa, sb)`` — for leveled
    integer data this is exactly the metric on the min-plus numerator.
    ``epilogue=None`` returns the raw fp32 numerator (the unfused plane
    contraction used when ``n_pf > 1`` splits the reduction across ranks).
    """
    levels, kb, m = Pa.shape
    n = Pb.shape[2]
    assert Pb.shape[:2] == (levels, kb), (Pa.shape, Pb.shape)
    mp, np_, kbp = (-m) % bm, (-n) % bn, (-kb) % bkb
    Pa = _pad_planes(Pa, mp, kbp)
    Pb = _pad_planes(Pb, np_, kbp)
    sa = _pad_stat(sa, mp)[:, None]
    sb = _pad_stat(sb, np_)[None, :]
    M, N, KB = m + mp, n + np_, kb + kbp
    n_k_steps = KB // bkb
    grid = (M // bm, N // bn, n_k_steps)
    out = pl.pallas_call(
        functools.partial(
            _levels_fused_kernel, n_k_steps=n_k_steps, levels=levels,
            epilogue=epilogue,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((levels, bkb, bm), lambda i, j, t: (0, t, i)),
            pl.BlockSpec((levels, bkb, bn), lambda i, j, t: (0, t, j)),
            pl.BlockSpec((bm, 1), lambda i, j, t: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, t: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(Pa, Pb, sa, sb)
    return out[:m, :n]


@functools.partial(
    jax.jit,
    static_argnames=("epilogue", "bt", "bkb", "interpret", "out_dtype"),
)
def metric2_levels_tri_pallas(
    P,
    s,
    *,
    epilogue,
    bt: int = DEFAULT_BM,
    bkb: int = DEFAULT_BKB,
    interpret: bool = False,
    out_dtype=jnp.float32,
):
    """Fused diagonal-block plane kernel on the triangular tile schedule.

    P (levels, kb, m) are the packed planes of ONE vector block (both
    operand orientations read the same array); only the T(T+1)/2 tiles with
    ``tj >= ti`` are enumerated.  Returns the packed tile list (P, bt, bt)
    in ``tri_tile_coords`` order, like ``metric2_tri_pallas``."""
    levels, kb, m = P.shape
    mp, kbp = (-m) % bt, (-kb) % bkb
    P = _pad_planes(P, mp, kbp)
    sp = _pad_stat(s, mp)
    sa, sb = sp[:, None], sp[None, :]
    M, KB = m + mp, kb + kbp
    T = M // bt
    nP = T * (T + 1) // 2
    n_k_steps = KB // bkb
    ti, tj = tri_tile_coords(T)
    idx = jnp.asarray(np.stack([ti, tj], axis=1))  # (nP, 2) static schedule

    def a_map(p, t):
        return (0, t, _tri_decode(p, T)[0])

    def b_map(p, t):
        return (0, t, _tri_decode(p, T)[1])

    def sa_map(p, t):
        return (_tri_decode(p, T)[0], 0)

    def sb_map(p, t):
        return (0, _tri_decode(p, T)[1])

    out = pl.pallas_call(
        functools.partial(
            _levels_fused_tri_kernel, n_k_steps=n_k_steps, levels=levels,
            epilogue=epilogue,
        ),
        grid=(nP, n_k_steps),
        in_specs=[
            pl.BlockSpec((1, 2), lambda p, t: (p, 0)),
            pl.BlockSpec((levels, bkb, bt), a_map),
            pl.BlockSpec((levels, bkb, bt), b_map),
            pl.BlockSpec((bt, 1), sa_map),
            pl.BlockSpec((1, bt), sb_map),
        ],
        out_specs=pl.BlockSpec((1, bt, bt), lambda p, t: (p, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nP, bt, bt), out_dtype),
        scratch_shapes=[pltpu.VMEM((bt, bt), jnp.float32)],
        interpret=interpret,
    )(idx, P, P, sa, sb)
    return out
