"""Numpy oracle for the popcount bit-GEMM — byte-table popcount over the
AND outer product, sharing the format owner's ``POPCOUNT`` table so the
reference and the store sidecar count bytes identically."""
import numpy as np

from repro.kernels.mgemm_levels import POPCOUNT


def pop_planes_ref(Pa, Pb):
    """Pa (1, kb, m), Pb (1, kb, n) uint8 -> (m, n) float64 numerator.

    N[i, j] = sum_q POPCOUNT[Pa[0, q, i] & Pb[0, q, j]] — the binary
    min-plus numerator, bitwise-AND formulation (paper §2.3)."""
    Pa, Pb = np.asarray(Pa), np.asarray(Pb)
    assert Pa.shape[0] == Pb.shape[0] == 1, (Pa.shape, Pb.shape)
    and_ = Pa[0][:, :, None] & Pb[0][:, None, :]
    return POPCOUNT[and_].sum(axis=0, dtype=np.float64)


def threeway_pop_ref(Pown, PX, Pright):
    """3-way oracle: B[t, i, k] = sum_q popcount(own & X[:, t] & right)."""
    Pown, PX, Pright = np.asarray(Pown), np.asarray(PX), np.asarray(Pright)
    L = PX.shape[2]
    out = np.empty((L, Pown.shape[2], Pright.shape[2]), np.float64)
    for t in range(L):
        xo = (Pown[0] & PX[0, :, t:t + 1])[None]
        out[t] = pop_planes_ref(xo, Pright)
    return out
