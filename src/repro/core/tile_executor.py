"""TileExecutor — the single tiled hot path under both distributed engines.

Before this layer existed, ``_twoway_program`` / ``_threeway_program`` built
their own contraction pipelines: a plain mGEMM via ``cfg.impl_fn()``, the
metric assembly in XLA *outside* the kernel (one HBM round-trip of every
numerator block), and diagonal blocks computed in full before masking one
triangle with ``jnp.where``.  The executor owns all of that now:

* **Kernel dispatch** across the implementation registry (``xla`` /
  ``pallas`` / ``levels*``) plus the *generated fused path*: any metric with
  a Pallas-composable ``assemble_tile`` epilogue and a combine-sum
  contraction gets the fused kernel of ``repro.kernels.mgemm`` — the
  numerator tile is divided in VMEM and never written to HBM (paper §3.1's
  epilogue fusion, for every registered metric instead of a hard-coded
  Czekanowski one-off).
* **In-kernel symmetry elimination** (paper §5): diagonal blocks run the
  triangular tile schedule — the Pallas grid enumerates only tiles with
  ``tj >= ti`` — replacing compute-both-then-mask.
* **Block padding / tile selection**: operands are padded to tile multiples
  inside the kernels; tile sizes adapt to the block shape (capped at the
  TPU-sized defaults, 8-aligned for the VPU register shape) so interpret
  mode on CPU does not pay for 128x512 padding of a 12-vector test block.

Bit-exactness contract: the fused path performs op-for-op the same fp32
arithmetic as the out-of-kernel assembly (exact integer numerators, then
``assemble_tile`` == ``assemble2`` division), so every campaign checksum is
bit-identical across ``impl="xla"`` and ``impl="pallas"`` on integer data —
verified in tests/distributed_harness.py and tests/test_fused_epilogue.py.

The fused epilogue needs the *complete* numerator at flush time, so it
engages only when the contraction is not split over ranks (``n_pf == 1``);
otherwise the executor falls back to contraction + psum + out-of-kernel
assembly, unchanged from the pre-executor engines.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.metric_spec import CZEKANOWSKI, MetricSpec

__all__ = ["TileExecutor"]

_TILE_ALIGN = 8  # VPU sublane multiple; real TPU tiles stay (8k, 128)-shaped


def _auto_tile(extent: int, cap: int) -> int:
    """Smallest 8-aligned tile covering ``extent``, capped at the default."""
    return int(min(cap, -(-extent // _TILE_ALIGN) * _TILE_ALIGN))


@dataclass(frozen=True)
class TileExecutor:
    """Tile-level kernel dispatch for one (config, metric, out_dtype) triple.

    ``axis`` is the mesh axis numerator partials are psummed over on the
    unfused path ("pf" inside the distributed programs); ``None`` outside
    shard_map (single-process tests, benchmarks).
    """

    cfg: Any  # CometConfig (duck-typed to avoid a core.twoway import cycle)
    metric: MetricSpec = None
    out_dtype: Any = jnp.float32
    axis: Optional[str] = "pf"

    def __post_init__(self):
        if self.metric is None:
            object.__setattr__(self, "metric", CZEKANOWSKI)

    # -- dispatch predicates ------------------------------------------------

    @property
    def fused(self) -> bool:
        """True when 2-way blocks run the fused-epilogue Pallas kernel."""
        return (
            self.cfg.impl == "pallas"
            and self.cfg.n_pf == 1
            and self.metric.assemble_tile is not None
            and self.metric.contract_is_combine_sum
        )

    @property
    def fused3(self) -> bool:
        """True when 3-way pipeline steps run the fused X_j Pallas kernel."""
        return self.cfg.impl == "pallas" and self.metric.contract_is_combine_sum

    # -- internals ----------------------------------------------------------

    def _psum(self, x):
        return jax.lax.psum(x, self.axis) if self.axis is not None else x

    def contract(self, A, B):
        """Numerator contraction via the metric's registry dispatch."""
        return self.metric.contract_fn(self.cfg)(A, B)

    # -- 2-way --------------------------------------------------------------

    def pair_block(self, Va, sa, Vb, sb, *, diagonal: bool = False):
        """One (m, n) block of 2-way metric values.

        Va (n_fp, m) / Vb (n_fp, n) field-major vector blocks; sa / sb the
        psummed per-vector stats.  ``diagonal`` marks Va and Vb as the same
        block: only the strict upper triangle is returned (zeros elsewhere),
        computed on the triangular tile schedule on the fused path.
        """
        k, m = Va.shape
        n = Vb.shape[1]
        if self.fused:
            # late import: kernels register against core.mgemm at import time
            from repro.kernels.mgemm import (
                metric2_tiles,
                metric2_tri,
                unpack_tri_tiles,
            )
            from repro.kernels.mgemm.kernel import (
                DEFAULT_BK,
                DEFAULT_BM,
                DEFAULT_BN,
            )

            kw = dict(
                combine=self.metric.combine,
                epilogue=self.metric.assemble_tile,
                bk=_auto_tile(k, DEFAULT_BK),
                out_dtype=jnp.dtype(self.out_dtype),
            )
            if diagonal:
                bt = _auto_tile(m, DEFAULT_BM)
                packed = metric2_tri(Va.T, Vb, sa, sb, bt=bt, **kw)
                return unpack_tri_tiles(packed, m, bt)
            return metric2_tiles(
                Va.T, Vb, sa, sb,
                bm=_auto_tile(m, DEFAULT_BM), bn=_auto_tile(n, DEFAULT_BN),
                **kw,
            )
        # unfused: contraction (registry impl) + psum + out-of-kernel
        # assembly — op-for-op the pre-executor engine arithmetic.
        n2 = self._psum(self.contract(Va.T, Vb).astype(jnp.float32))
        vals = self.metric.assemble2(n2, sa[:, None], sb[None, :]).astype(
            self.out_dtype
        )
        if diagonal:
            tri = jnp.triu(jnp.ones((m, n), bool), k=1)
            vals = jnp.where(tri, vals, 0)
        return vals

    # -- 3-way --------------------------------------------------------------

    def threeway_slice(self, ps, left, right):
        """Batched 3-way numerator B[t, l, r] = Σ_q combine(ps_t, left_l,
        right_r) for one pipeline slice.  NOT psummed — the caller fuses the
        psum with the pairwise terms into one collective.

        Fused path: one batched ``threeway_batch`` launch (the pipeline axis
        is a kernel grid dimension, so trace/compile cost is O(1) in L), the
        X_j = combine(left, ps_t) tiles built in VMEM (never HBM).  Unfused:
        the pipeline axis folds into the GEMM M dimension (one batched
        contraction), exactly the pre-executor formulation.
        """
        n_fp, L = ps.shape
        m = left.shape[1]
        n = right.shape[1]
        if self.fused3:
            from repro.kernels.czek3 import threeway_batch
            from repro.kernels.czek3.kernel import (
                DEFAULT_BK,
                DEFAULT_BM,
                DEFAULT_BN,
            )

            return threeway_batch(
                left, ps, right,
                combine=self.metric.combine,
                bm=_auto_tile(m, DEFAULT_BM),
                bn=_auto_tile(n, DEFAULT_BN),
                bk=_auto_tile(n_fp, DEFAULT_BK),
            )
        X = self.metric.combine(left[:, :, None], ps[:, None, :]).reshape(
            n_fp, m * L
        )
        return self.contract(X.T, right).reshape(m, L, n).transpose(1, 0, 2)
