"""Input specs + step builders for every (arch x shape) dry-run cell.

Shapes (assignment):
    train_4k     seq=4096,   global_batch=256   -> train_step
    prefill_32k  seq=32768,  global_batch=32    -> prefill_step (cache fill)
    decode_32k   seq=32768,  global_batch=128   -> serve_step (1 new token)
    long_500k    seq=524288, global_batch=1     -> serve_step; SSM/hybrid only

``long_500k`` is skipped for pure full-attention archs (quadratic attention
at 524k; DESIGN.md §5) and runs for mamba2 (SSM) and zamba2 (hybrid).

Everything here returns ShapeDtypeStructs (weak-type-correct, shardable, no
device allocation) — the dry-run lowers + compiles against them.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.registry import get_config
from repro.models import api
from repro.models.common import ModelConfig
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.optim.schedule import warmup_cosine
from repro.parallel.sharding import named_sharding
from repro.train.step import make_train_step

DATA = ("pod", "data")


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

LM_ARCHS = [
    "qwen1.5-0.5b",
    "llama3-8b",
    "command-r-plus-104b",
    "deepseek-67b",
    "qwen2-vl-2b",
    "grok-1-314b",
    "granite-moe-3b-a800m",
    "zamba2-1.2b",
    "mamba2-1.3b",
    "seamless-m4t-large-v2",
]
COMET_ARCHS = ["comet_2way", "comet_3way", "comet_2way_mxu", "comet_3way_mxu"]


def applicable(arch: str, shape: str) -> tuple[bool, str]:
    cfg = get_config(arch)
    if shape == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, "full quadratic attention at 524k seq — skipped (DESIGN §5)"
    return True, ""


def cells(include_comet: bool = True):
    """All runnable (arch, shape) dry-run cells."""
    out = []
    for arch in LM_ARCHS:
        for shape in SHAPES:
            ok, _ = applicable(arch, shape)
            if ok:
                out.append((arch, shape))
    if include_comet:
        out += [(a, "paper") for a in COMET_ARCHS]
    return out


def _prep_cfg(cfg: ModelConfig, kind: str) -> ModelConfig:
    # production lowering settings: bf16 compute, remat for training
    return cfg.replace(
        compute_dtype="bfloat16",
        param_dtype="float32",
        remat="full" if kind == "train" else "none",
    )


def _with_sharding(struct_tree, spec_tree, mesh):
    """Attach NamedShardings (PartitionSpec leaves in spec_tree) to structs."""

    def one(st, spec):
        return jax.ShapeDtypeStruct(
            st.shape, st.dtype, sharding=named_sharding(mesh, *spec, shape=st.shape)
        )

    flat_s, treedef = jax.tree.flatten(struct_tree)
    flat_spec = treedef.flatten_up_to(spec_tree)
    return treedef.unflatten([one(s, sp) for s, sp in zip(flat_s, flat_spec)])


def param_structs(cfg: ModelConfig, mesh: Mesh):
    struct = jax.eval_shape(partial(api.init_model, cfg), jax.random.PRNGKey(0))
    rules = api.param_sharding_rules(cfg)
    return _with_sharding(struct, rules, mesh)


def opt_structs(cfg: ModelConfig, params_struct, mesh: Mesh):
    struct = jax.eval_shape(adamw_init, params_struct)
    rules = api.param_sharding_rules(cfg)
    opt_rules = {"mu": rules, "nu": rules, "count": P()}
    return _with_sharding(struct, opt_rules, mesh)


def _sds(mesh, shape, dtype, *spec):
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=named_sharding(mesh, *spec, shape=shape)
    )


def batch_structs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh):
    B, S = shape.batch, shape.seq
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    DATA = ("data", "model") if cfg.dp_only else globals()["DATA"]
    batch = {"labels": _sds(mesh, (B, S), i32, DATA, None)}
    if cfg.family == "encdec":
        batch["src_embeds"] = _sds(mesh, (B, S, cfg.d_model), bf16, DATA, None, None)
        batch["tokens"] = _sds(mesh, (B, S), i32, DATA, None)
    elif cfg.family == "vlm":
        batch["embeds"] = _sds(mesh, (B, S, cfg.d_model), bf16, DATA, None, None)
    else:
        batch["tokens"] = _sds(mesh, (B, S), i32, DATA, None)
    return batch


def cache_structs(cfg: ModelConfig, batch: int, max_len: int, mesh: Mesh):
    if cfg.family == "encdec":
        # built by hand: init_cache runs the encoder, which the dry-run skips
        kv_shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
        return {
            "enc": _sds(mesh, (batch, max_len, cfg.d_model), cfg.cdt,
                        DATA, None, None),
            "kv": {
                "k": _sds(mesh, kv_shape, cfg.cdt, None, DATA, "model", None, None),
                "v": _sds(mesh, kv_shape, cfg.cdt, None, DATA, "model", None, None),
            },
        }
    struct = jax.eval_shape(lambda: api.init_cache(cfg, None, batch, max_len))
    spec_map = {}
    if "kv" in struct:
        spec_map["kv"] = {
            "k": P(None, DATA, "model", None, None),
            "v": P(None, DATA, "model", None, None),
        }
    if "mamba" in struct:
        spec_map["mamba"] = {
            "conv": P(None, DATA, None, "model"),
            "ssm": P(None, DATA, "model", None, None),
        }
    return _with_sharding(struct, spec_map, mesh)


def build_cell(arch: str, shape_name: str, mesh: Mesh, overrides=None):
    """Returns (step_fn, arg_structs, meta) ready for jit(...).lower(*args)."""
    shape = SHAPES[shape_name]
    cfg = _prep_cfg(get_config(arch), shape.kind)
    if overrides:
        cfg = cfg.replace(**overrides)
    meta = {"arch": arch, "shape": shape_name, "kind": shape.kind,
            "seq": shape.seq, "batch": shape.batch}

    if shape.kind == "train":
        opt_cfg = AdamWConfig(schedule=warmup_cosine(2000, 100000))
        step = make_train_step(cfg, opt_cfg)
        if cfg.dp_only:
            from repro.parallel.sharding import dp_only_mode

            inner = step

            def step(params, opt_state, batch):
                with dp_only_mode():
                    return inner(params, opt_state, batch)

        params = param_structs(cfg, mesh)
        opt = opt_structs(cfg, params, mesh)
        batch = batch_structs(cfg, shape, mesh)
        return step, (params, opt, batch), meta

    if shape.kind == "prefill":
        params = param_structs(cfg, mesh)
        cache = cache_structs(cfg, shape.batch, shape.seq, mesh)
        toks = _sds(mesh, (shape.batch, shape.seq), jnp.int32, DATA, None)
        if cfg.family == "vlm":
            # stub frontend: prefill consumes tokens for lowering purposes
            pass

        def prefill(params, cache, tokens):
            return api.decode_step(cfg, params, cache, tokens, 0)

        return prefill, (params, cache, toks), meta

    # decode
    params = param_structs(cfg, mesh)
    cache = cache_structs(cfg, shape.batch, shape.seq, mesh)
    toks = _sds(mesh, (shape.batch, 1), jnp.int32, DATA, None)
    idx = shape.seq - 1

    def decode(params, cache, tokens):
        return api.decode_step(cfg, params, cache, tokens, idx)

    return decode, (params, cache, toks), meta


# ------------------------------------------------------------- comet ----


def build_comet_cell(arch: str, mesh: Mesh, multi_pod: bool, overrides=None):
    """Lowerable distributed similarity engine over the pod's devices."""
    from repro.configs.registry import get_config as _gc
    from repro.parallel.compat import shard_map
    from repro.core.plan2 import TwoWayPlan
    from repro.core.plan3 import ThreeWayPlan
    from repro.core.threeway import _threeway_program
    from repro.core.twoway import CometConfig, _twoway_program
    from repro.parallel.mesh import make_comet_mesh

    ccfg = _gc(arch)
    if overrides:
        import dataclasses
        ccfg = dataclasses.replace(ccfg, **overrides)
    chips = mesh.devices.size
    n_pf, n_pv, n_pr = ccfg.decomposition(chips, multi_pod)
    comet_cfg = CometConfig(
        n_pf=n_pf, n_pv=n_pv, n_pr=n_pr, n_st=ccfg.n_st,
        impl=ccfg.impl, levels=ccfg.levels or 2, out_dtype=ccfg.out_dtype,
        ring_dtype=ccfg.ring_dtype,
    )
    cmesh = make_comet_mesh(n_pf, n_pv, n_pr, devices=mesh.devices.ravel())
    n_v = ccfg.n_vp * n_pv
    V = jax.ShapeDtypeStruct(
        (ccfg.n_f, n_v), jnp.dtype(ccfg.ring_dtype),
        sharding=NamedSharding(cmesh, P("pf", "pv")),
    )
    out_dtype = jnp.dtype(ccfg.out_dtype)
    if ccfg.way == 2:
        plan = TwoWayPlan(n_pv, n_pr)
        fn = shard_map(
            partial(_twoway_program, cfg=comet_cfg, plan=plan, out_dtype=out_dtype),
            mesh=cmesh, in_specs=P("pf", "pv"),
            out_specs=P("pv", "pr", None, None, None), check=False,
        )
    else:
        plan = ThreeWayPlan(n_pv, n_pr, ccfg.n_st)
        fn = shard_map(
            partial(_threeway_program, cfg=comet_cfg, plan=plan, stage=0,
                    out_dtype=out_dtype),
            mesh=cmesh, in_specs=P("pf", "pv"),
            out_specs=P("pv", "pr", None, None, None, None), check=False,
        )
    # cost_analysis statically counts EVERY round-robin cond branch; a rank
    # executes only its share at runtime.  work_fraction rescales the
    # compute/memory terms (collectives run unconditionally on the ring).
    if ccfg.way == 2:
        work_fraction = plan.slots_per_rank / plan.n_steps
    else:
        work_fraction = plan.slots_per_rank / plan.items_per_slab
    meta = {
        "arch": arch, "shape": "paper", "kind": f"comet{ccfg.way}way",
        "n_f": ccfg.n_f, "n_v": n_v, "n_pf": n_pf, "n_pv": n_pv, "n_pr": n_pr,
        "n_st": ccfg.n_st, "impl": ccfg.impl, "work_fraction": work_fraction,
    }
    return fn, (V,), meta
