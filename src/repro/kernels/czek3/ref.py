"""Pure-jnp oracle for the fused 3-way inner step."""
import jax.numpy as jnp


def czek3_step_ref(own, x, right, out_dtype=jnp.float32):
    """B[i, k] = sum_q min(own[q, i], x[q], right[q, k])."""
    if x.ndim == 2:
        x = x[:, 0]
    m3 = jnp.minimum(
        jnp.minimum(own[:, :, None], x[:, None, None]), right[:, None, :]
    ).astype(jnp.float32)
    return m3.sum(axis=0).astype(out_dtype)
