"""zamba2-1.2b [hybrid] — arXiv:2411.15242 (hf-verified).

38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000, ssm_state=64 — Mamba2
backbone with a SHARED attention+MLP block applied every 6 SSM layers
(single parameter set reused at multiple depths; Zamba2's per-application
LoRA deltas are omitted — noted in DESIGN.md).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    head_dim=64,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    hybrid_attn_every=6,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="zamba2-1.2b-smoke",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    ssm_state=16,
    ssm_head_dim=16,
    hybrid_attn_every=2,
)
