"""Exhaustive verification of the paper's redundancy-elimination schedules.

These tests are the ground truth for the engines: every unique pair/triple is
covered exactly once, and the work is balanced as the paper claims.
"""
import itertools

import numpy as np
import pytest

from repro.core.plan2 import TwoWayPlan, covered_block_pairs, global_pairs_of_block
from repro.core.plan3 import ItemKind, ThreeWayPlan, vol_slice_rule


# ---------------------------------------------------------------- 2-way ----


@pytest.mark.parametrize("n_pv", [1, 2, 3, 4, 5, 6, 7, 8, 16])
def test_2way_block_coverage(n_pv):
    pairs = covered_block_pairs(n_pv)
    want = [tuple(sorted(p)) for p in itertools.combinations_with_replacement(range(n_pv), 2)]
    assert sorted(pairs) == sorted(want)
    assert len(pairs) == len(set(pairs)), "block pair computed twice"


@pytest.mark.parametrize("n_pv,n_pr", [(4, 1), (5, 1), (4, 2), (6, 2), (8, 4), (16, 3)])
def test_2way_load_balance(n_pv, n_pr):
    plan = TwoWayPlan(n_pv, n_pr)
    w = plan.work_per_rank()
    # every rank within 1 block of every other (paper's claim for the
    # circulant schedule; the pr round-robin adds at most 1 more)
    assert w.max() - w.min() <= 2
    assert w.sum() == len(plan.all_computed_blocks())


@pytest.mark.parametrize("n_pv,n_vp", [(1, 7), (2, 4), (3, 5), (4, 4), (5, 3), (8, 2)])
def test_2way_global_pair_coverage(n_pv, n_vp):
    plan = TwoWayPlan(n_pv, 1)
    n_v = n_pv * n_vp
    seen = set()
    for p_v, d, col in plan.all_computed_blocks():
        I, J, mask = global_pairs_of_block(p_v, col, n_vp)
        for i, j in zip(I[mask], J[mask]):
            key = (min(i, j), max(i, j))
            assert key not in seen, f"pair {key} computed twice"
            assert i != j
            seen.add(key)
    assert len(seen) == n_v * (n_v - 1) // 2


def test_2way_rank_computes_matches_blocks():
    plan = TwoWayPlan(6, 2)
    executed = [
        (p_v, d)
        for d in range(plan.n_steps)
        for p_r in range(plan.n_pr)
        for p_v in range(plan.n_pv)
        if plan.rank_computes(p_v, p_r, d)
    ]
    blocks = [(p_v, d) for p_v, d, _ in plan.all_computed_blocks()]
    assert sorted(executed) == sorted(blocks)


# ---------------------------------------------------------------- 3-way ----


def test_vol_slice_rule_is_exact_sixths():
    """The six permutation-image blocks of an unordered block triple select
    six distinct sixths, all on the axis carrying the middle id."""
    for ids in itertools.combinations(range(7), 3):
        seen = set()
        for perm in itertools.permutations(ids):
            ax, idx = vol_slice_rule(*perm)
            # the sliced axis must hold the middle id
            assert perm[ax] == sorted(ids)[1]
            seen.add(idx)
        assert seen == set(range(6))


@pytest.mark.parametrize("n_pv", [1, 2, 3, 4, 5])
def test_3way_item_count(n_pv):
    plan = ThreeWayPlan(n_pv, 1)
    assert len(plan.slab_items()) == (n_pv + 1) * (n_pv + 2)


@pytest.mark.parametrize(
    "n_pv,n_vp,n_st",
    [(1, 6, 1), (2, 6, 1), (3, 6, 1), (4, 6, 1), (3, 12, 1), (3, 12, 2), (2, 12, 2)],
)
def test_3way_global_triple_coverage(n_pv, n_vp, n_st):
    """THE key schedule property: union over slabs, items and stages covers
    every unique triple i<j<k exactly once."""
    plan = ThreeWayPlan(n_pv, 1, n_st)
    n_v = n_pv * n_vp
    seen = {}
    for p_v in range(n_pv):
        for it in plan.items_of(p_v, 0):
            for st in range(n_st):
                gi, gj, gk = plan.item_cells(p_v, it, n_vp, st)
                for a, b, c in zip(gi, gj, gk):
                    key = tuple(sorted((a, b, c)))
                    assert len(set(key)) == 3, f"degenerate triple {key} ({it})"
                    assert key not in seen, f"triple {key} twice: {seen[key]} and {it}"
                    seen[key] = (p_v, it)
    assert len(seen) == n_v * (n_v - 1) * (n_v - 2) // 6


@pytest.mark.parametrize("n_pv,n_pr", [(3, 1), (3, 4), (4, 5), (5, 7)])
def test_3way_round_robin_partitions_items(n_pv, n_pr):
    plan = ThreeWayPlan(n_pv, n_pr)
    all_items = {it.sb for it in plan.slab_items()}
    union = set()
    for p_r in range(n_pr):
        mine = {it.sb for it in plan.items_of(0, p_r)}
        assert union.isdisjoint(mine)
        union |= mine
    assert union == all_items
    w = plan.work_per_rank()
    assert w.max() - w.min() <= 1


def test_3way_load_imbalance_factor_matches_paper():
    """Paper: slices per slab = (n_pv+1)(n_pv+2) with imbalance factor
    n_pv^2 / ((n_pv+1)(n_pv+2)) -> 1 as n_pv grows."""
    for n_pv in (4, 8, 16, 64):
        plan = ThreeWayPlan(n_pv, 1)
        vol = sum(1 for it in plan.slab_items() if it.kind == ItemKind.VOL)
        total = len(plan.slab_items())
        assert vol == (n_pv - 1) * (n_pv - 2)
        factor = n_pv**2 / total
        assert abs(factor - n_pv**2 / ((n_pv + 1) * (n_pv + 2))) < 1e-12
        if n_pv == 64:
            assert factor > 0.95  # becomes insignificant at scale


def test_3way_stage_union_is_sixth():
    plan = ThreeWayPlan(2, 1, n_st=3)
    n_vp = 18
    for s in range(6):
        rngs = [plan.sixth_bounds(n_vp, s, st) for st in range(3)]
        covered = sorted(itertools.chain(*[range(lo, hi) for lo, hi in rngs]))
        lo6 = s * n_vp // 6
        hi6 = (s + 1) * n_vp // 6
        assert covered == list(range(lo6, hi6))
