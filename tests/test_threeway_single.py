"""In-process (single device) 3-way engine tests: DIAG phase + assembly."""
import numpy as np

from repro.core.metrics import czek3_metric_np
from repro.core.synthetic import analytic_window_vectors, random_integer_vectors
from repro.core.threeway import czek3_distributed
from repro.core.twoway import CometConfig, czek2_distributed
from repro.core.metrics import czek2_metric_np
from repro.parallel.mesh import make_comet_mesh


def _mesh1():
    return make_comet_mesh(1, 1, 1)


def test_3way_single_device_matches_oracle():
    V = random_integer_vectors(30, 12, seed=0)
    out = czek3_distributed(V, _mesh1(), CometConfig(), stage=0)
    assert out.num_triples() == 12 * 11 * 10 // 6
    d = out.dense()
    ref = czek3_metric_np(V)
    for i in range(12):
        for j in range(i + 1, 12):
            for k in range(j + 1, 12):
                assert abs(d[i, j, k] - ref[i, j, k]) < 1e-6


def test_3way_ragged_n_v_padding():
    """n_v not a multiple of 6: zero-pad vectors must be masked out."""
    V = random_integer_vectors(20, 10, seed=1)
    out = czek3_distributed(V, _mesh1(), CometConfig(), stage=0)
    assert out.num_triples() == 10 * 9 * 8 // 6
    ref = czek3_metric_np(V)
    d = out.dense()
    for i in range(10):
        for j in range(i + 1, 10):
            for k in range(j + 1, 10):
                assert abs(d[i, j, k] - ref[i, j, k]) < 1e-6


def test_3way_staging_partitions_results():
    V = random_integer_vectors(20, 12, seed=2)
    cfg = CometConfig(n_st=2)
    seen = set()
    for stage in range(2):
        out = czek3_distributed(V, _mesh1(), cfg, stage=stage)
        for I, J, K, _ in out.entries():
            for t in zip(I, J, K):
                key = tuple(sorted(t))
                assert key not in seen
                seen.add(key)
    assert len(seen) == 12 * 11 * 10 // 6


def test_3way_analytic_dataset():
    """Closed-form verification — no O(n^3) oracle needed (paper's analytic
    synthetic mode)."""
    V, aw = analytic_window_vectors(36, 12, width=8, seed=3)
    out = czek3_distributed(V, _mesh1(), CometConfig(), stage=0)
    for I, J, K, W in out.entries():
        np.testing.assert_allclose(W, aw.c3(I, J, K).astype(np.float32), rtol=1e-6)


def test_2way_ragged_and_analytic():
    V, aw = analytic_window_vectors(40, 11, width=9, seed=4)
    out = czek2_distributed(V, _mesh1(), CometConfig())
    assert out.num_pairs() == 11 * 10 // 2
    for I, J, W in out.entries():
        np.testing.assert_allclose(W, aw.c2(I, J).astype(np.float32), rtol=1e-6)


def test_2way_impl_variants_bit_identical():
    V = random_integer_vectors(32, 8, seed=5, max_value=7)
    ref = czek2_distributed(V, _mesh1(), CometConfig()).dense()
    for impl, kw in [("pallas", {}), ("levels_xla", {"levels": 7})]:
        cfg = CometConfig(impl=impl, **({"levels": 7} if impl.startswith("lev") else {}))
        got = czek2_distributed(V, _mesh1(), cfg).dense()
        assert (got == ref).all(), impl
