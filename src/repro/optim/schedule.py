"""LR schedules (multipliers on the base LR)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(warmup, 1)
        t = (step - warmup) / jnp.maximum(total - warmup, 1)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * jnp.clip(t, 0, 1)))
        return jnp.where(step < warmup, warm, cos)

    return fn


def constant():
    return lambda step: jnp.ones_like(step, jnp.float32)
