"""Loop-aware HLO cost model tests: scan trip counts, dot flops, collective
accounting — the foundation of the §Roofline numbers."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline.hlo import analyze_hlo, shape_bytes, shape_elems


def _cost(fn, *args):
    compiled = jax.jit(fn).lower(*args).compile()
    return analyze_hlo(compiled.as_text(), 1), compiled


def test_shape_parsing():
    assert shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert shape_bytes("bf16[8]") == 16
    assert shape_bytes("(f32[4,4], s32[2])") == 64 + 8
    assert shape_elems("f32[3,5]") == 15


def test_dot_flops_exact():
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    y = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    cost, _ = _cost(lambda a, b: a @ b, x, y)
    assert cost.flops == pytest.approx(2 * 64 * 128 * 32, rel=0.01)


def test_scan_multiplies_body_cost():
    """THE critical property: while bodies are priced x trip count (XLA's own
    cost_analysis counts them once)."""
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def f(a):
        out, _ = jax.lax.scan(lambda c, _: (c @ c, None), a, None, length=10)
        return out

    cost, compiled = _cost(f, x)
    one = 2 * 128**3
    assert cost.flops == pytest.approx(10 * one, rel=0.05)
    from repro.parallel.compat import cost_analysis_dict

    assert float(cost_analysis_dict(compiled)["flops"]) == pytest.approx(one, rel=0.05)


def test_nested_scan_multiplies():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(a):
        def outer(c, _):
            def inner(ci, _):
                return ci @ ci, None
            c, _ = jax.lax.scan(inner, c, None, length=4)
            return c, None
        out, _ = jax.lax.scan(outer, a, None, length=3)
        return out

    cost, _ = _cost(f, x)
    assert cost.flops == pytest.approx(12 * 2 * 64**3, rel=0.05)


def test_elementwise_flops_counted():
    x = jax.ShapeDtypeStruct((1000,), jnp.float32)
    cost, _ = _cost(lambda a: jnp.minimum(a, 2.0) + a, x)
    # min + add = 2 flops/elem (allow fusion-dependent slack)
    assert 1000 <= cost.flops <= 5000


def test_bytes_nonzero_and_reasonable():
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    cost, _ = _cost(lambda a: (a @ a) * 2.0, x)
    lo = 3 * 256 * 256 * 4  # read a twice-ish + write result
    assert cost.bytes >= lo


def test_collectives_in_loop_multiplied():
    import subprocess, sys, os, textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.parallel.compat import make_mesh, shard_map
        from repro.roofline.hlo import analyze_hlo
        mesh = make_mesh((4,), ("x",))
        def prog(v):
            def body(i, c):
                return jax.lax.ppermute(c, "x", [(a, (a+1)%4) for a in range(4)])
            return jax.lax.fori_loop(0, 7, body, v)
        f = shard_map(prog, mesh=mesh, in_specs=P("x"), out_specs=P("x"),
                      check=False)
        x = jax.ShapeDtypeStruct((4, 100), jnp.float32)
        c = jax.jit(f).lower(x).compile()
        cost = analyze_hlo(c.as_text(), 4)
        n = cost.counts.get("collective-permute", 0)
        assert n == 7, f"expected 7 permutes, got {n}"
        per = 100 * 4  # one shard
        assert abs(cost.operand_bytes["collective-permute"] - 7 * per) < per
        print("OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=300)
    assert "OK" in r.stdout, r.stderr[-2000:]
