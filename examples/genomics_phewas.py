"""End-to-end PheWAS-style similarity campaign (paper §6.8 workflow) on the
unified API.

Synthetic SNP association profiles (values {0,1,2} like allele counts) ->
distributed 2-way Czekanowski metrics on the MXU-exact level-decomposition
path -> thresholded output + full result saved with a manifest and exact
checksum -> staged 3-way pass over the strongest cluster.

    PYTHONPATH=src python examples/genomics_phewas.py [--n-v 600] [--n-f 385]
"""
import argparse
import json
import os

import numpy as np

from repro.api import SimilarityEngine, SimilarityRequest
from repro.core.synthetic import random_integer_vectors


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-v", type=int, default=600)
    ap.add_argument("--n-f", type=int, default=385)  # the paper's real n_f
    ap.add_argument("--threshold", type=float, default=0.8)
    ap.add_argument("--out", default="/tmp/phewas_campaign")
    args = ap.parse_args()

    # {0,1,2} allele-count-like profiles: exact on the levels (MXU) path
    V = random_integer_vectors(args.n_f, args.n_v, max_value=2, seed=11)
    engine = SimilarityEngine()

    result = engine.run(
        SimilarityRequest(metric="czekanowski", way=2,
                          impl="levels_xla", levels=2), V)
    os.makedirs(args.out, exist_ok=True)
    # streaming tile scan: the hit filter never materializes the dense matrix
    n_hits = 0
    hits = []
    for tile in result.tiles():
        I, J = tile.index
        sel = tile.values >= args.threshold
        n_hits += int(sel.sum())
        hits.extend(zip(I[sel].tolist(), J[sel].tolist(),
                        tile.values[sel].tolist()))
    # paper §6.8: metrics written as single bytes (~2.5 sig figs)
    u8 = {(i, j): int(w * 255 + 0.5) for i, j, w in hits}
    with open(os.path.join(args.out, "hits_u8.json"), "w") as f:
        json.dump({f"{i},{j}": v for (i, j), v in u8.items()}, f)
    manifest = result.save(os.path.join(args.out, "full"))
    summary = {
        "n_f": args.n_f, "n_v": args.n_v,
        "pairs": result.num_results(), "hits": n_hits,
        "threshold": args.threshold, "checksum": manifest["checksum"],
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(summary, f, indent=2)
    print(json.dumps(summary, indent=2))

    # 3-way follow-up on the densest hub vectors (staged like the paper):
    # stages=None runs every stage of n_st through one request
    deg = np.zeros(args.n_v, int)
    for i, j, _ in hits:
        deg[i] += 1
        deg[j] += 1
    hub = np.argsort(-deg)[:36]
    out3 = engine.run(
        SimilarityRequest(metric="czekanowski", way=3, n_st=2, stages=None),
        V[:, hub],
    )
    print(f"3-way follow-up on {len(hub)} hub vectors: "
          f"{out3.num_results()} unique triples over stages {list(out3.stages)}")


if __name__ == "__main__":
    main()
