"""Bit-plane encoding + fused levels path (interpret mode).

Contract under test (the packed bit-plane campaign path):

1. encode/pack/unpack round-trips exactly — including non-multiple-of-8
   field counts, where the padding remainder bits must be zero (inert),
2. the plane contraction (XLA and MXU-kernel realizations) equals the
   min-plus numerator bit-for-bit on leveled integer data,
3. the fused levels kernels (rectangular + triangular diagonal schedule)
   are bit-identical to the unfused contraction + out-of-kernel assembly,
4. the executor's path/encoding dispatch resolves as documented, and
5. campaign checksums are bit-identical across impl in {xla, levels,
   levels_xla} on {0,1,2} data, 2-way and 3-way (single-device here;
   multi-device decompositions live in distributed_harness.py).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.metric_spec import CZEKANOWSKI
from repro.core.mgemm import mgemm_xla
from repro.core.synthetic import random_integer_vectors
from repro.core.tile_executor import TileExecutor
from repro.core.twoway import (
    CometConfig,
    czek2_distributed,
    resolve_config,
)
from repro.core.threeway import czek3_distributed
from repro.kernels.czek3 import threeway_batch_levels
from repro.kernels.mgemm import unpack_tri_tiles
from repro.kernels.mgemm_levels import (
    decode_bitplanes,
    encode_bitplanes,
    encode_bitplanes_np,
    metric2_levels,
    metric2_levels_planes_ref,
    metric2_levels_tri,
    mgemm_levels_planes,
    mgemm_levels_planes_xla,
    values_from_planes,
)
from repro.parallel.mesh import make_comet_mesh

try:  # property tests run under hypothesis when present (CI installs it);
    # a deterministic case sweep below keeps coverage without it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


# -- encode / pack / unpack round-trips -------------------------------------


def _check_roundtrip(k, n, levels, seed):
    rng = np.random.default_rng(seed)
    V = rng.integers(0, levels + 1, (k, n)).astype(np.float32)
    P = encode_bitplanes_np(V, levels)
    kb = -(-k // 8)
    assert P.shape == (levels, kb, n) and P.dtype == np.uint8
    # jnp encoder agrees byte-for-byte with the numpy packer
    assert (np.asarray(encode_bitplanes(jnp.asarray(V), levels)) == P).all()
    # planes decode to the exact indicators, padding remainder bits zero
    dec = np.asarray(decode_bitplanes(jnp.asarray(P)))
    Vpad = np.pad(V, ((0, kb * 8 - k), (0, 0)))
    for t in range(1, levels + 1):
        assert (dec[t - 1] == (Vpad >= t)).all()
    # V = sum_t plane_t reconstructs values exactly
    vals = np.asarray(values_from_planes(jnp.asarray(P)))
    assert (vals[:k] == V).all()
    assert (vals[k:] == 0).all()


# non-multiple-of-8 field counts and padding remainders, deterministically
@pytest.mark.parametrize(
    "k,n,levels,seed",
    [(1, 1, 1, 0), (7, 3, 2, 1), (8, 4, 2, 2), (13, 5, 3, 3),
     (40, 12, 5, 4), (33, 2, 4, 5)],
)
def test_encode_decode_roundtrip_cases(k, n, levels, seed):
    _check_roundtrip(k, n, levels, seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(
        k=st.integers(1, 40),   # includes non-multiple-of-8 field counts
        n=st.integers(1, 12),
        levels=st.integers(1, 5),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_encode_decode_roundtrip_property(k, n, levels, seed):
        _check_roundtrip(k, n, levels, seed)


def test_encode_field_align_shards_bytes():
    """field_align pads fields to 8*align so the byte axis splits evenly."""
    V = np.ones((13, 3), np.float32)
    P = encode_bitplanes_np(V, 2, field_align=4)
    assert P.shape[1] % 4 == 0
    assert (np.asarray(values_from_planes(jnp.asarray(P)))[:13] == 1).all()


def _check_plane_contraction(m, k, n, levels, seed):
    """sum_t plane_t(A)^T plane_t(B) == sum_q min(a, b), bit-for-bit."""
    rng = np.random.default_rng(seed)
    Va = rng.integers(0, levels + 1, (k, m)).astype(np.float32)
    Vb = rng.integers(0, levels + 1, (k, n)).astype(np.float32)
    Pa = encode_bitplanes_np(Va, levels)
    Pb = encode_bitplanes_np(Vb, levels)
    want = np.asarray(mgemm_xla(jnp.asarray(Va.T), jnp.asarray(Vb)))
    assert (metric2_levels_planes_ref(Pa, Pb) == want).all()
    got_xla = np.asarray(mgemm_levels_planes_xla(jnp.asarray(Pa), jnp.asarray(Pb)))
    assert (got_xla == want).all()
    got_mxu = np.asarray(mgemm_levels_planes(
        jnp.asarray(Pa), jnp.asarray(Pb), bm=8, bn=8, bkb=2))
    assert (got_mxu == want).all()


@pytest.mark.parametrize(
    "m,k,n,levels,seed",
    [(1, 1, 1, 1, 0), (5, 7, 4, 2, 1), (8, 32, 8, 2, 2), (10, 40, 9, 4, 3),
     (3, 17, 6, 3, 4)],
)
def test_plane_contraction_is_minplus_cases(m, k, n, levels, seed):
    _check_plane_contraction(m, k, n, levels, seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(
        m=st.integers(1, 10),
        k=st.integers(1, 40),
        n=st.integers(1, 10),
        levels=st.integers(1, 4),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_plane_contraction_is_minplus_property(m, k, n, levels, seed):
        _check_plane_contraction(m, k, n, levels, seed)


# -- fused kernels vs unfused assembly --------------------------------------


def _blocks(k, m, n, levels, seed):
    rng = np.random.default_rng(seed)
    Va = rng.integers(0, levels + 1, (k, m)).astype(np.float32)
    Vb = rng.integers(0, levels + 1, (k, n)).astype(np.float32)
    return jnp.asarray(Va), jnp.asarray(Vb)


@pytest.mark.parametrize("m,k,n", [(8, 32, 16), (11, 45, 7), (24, 96, 33)])
@pytest.mark.parametrize("out_dtype", ["float32", "bfloat16"])
def test_fused_levels_rectangular_parity(m, k, n, out_dtype):
    spec = CZEKANOWSKI
    dt = jnp.dtype(out_dtype)
    fused = TileExecutor(cfg=CometConfig(impl="levels", levels=2),
                         metric=spec, out_dtype=dt, axis=None)
    unfused = TileExecutor(cfg=CometConfig(impl="xla"), metric=spec,
                           out_dtype=dt, axis=None)
    assert fused.path == "fused-levels" and unfused.path == "unfused"
    Va, Vb = _blocks(k, m, n, 2, seed=m * k + n)
    sa = jnp.asarray(np.asarray(spec.stat(Va)))
    sb = jnp.asarray(np.asarray(spec.stat(Vb)))
    got = fused.pair_block(Va, sa, Vb, sb, diagonal=False)
    want = unfused.pair_block(Va, sa, Vb, sb, diagonal=False)
    assert got.dtype == want.dtype == dt
    assert (np.asarray(got) == np.asarray(want)).all()


@pytest.mark.parametrize("m", [8, 11, 24, 200])
def test_fused_levels_triangular_parity(m):
    """Diagonal block on the triangular plane schedule == compute-then-mask
    (m=200 > the 128-capped auto tile exercises multi-tile decode)."""
    spec = CZEKANOWSKI
    fused = TileExecutor(cfg=CometConfig(impl="levels", levels=2),
                         metric=spec, out_dtype=jnp.float32, axis=None)
    unfused = TileExecutor(cfg=CometConfig(impl="xla"), metric=spec,
                           out_dtype=jnp.float32, axis=None)
    V = jnp.asarray(random_integer_vectors(32, m, max_value=2, seed=m))
    s = jnp.asarray(np.asarray(spec.stat(V)))
    got = fused.pair_block(V, s, V, s, diagonal=True)
    want = unfused.pair_block(V, s, V, s, diagonal=True)
    assert (np.asarray(got) == np.asarray(want)).all()
    assert (np.asarray(got)[np.tril_indices(m)] == 0).all()


def test_fused_levels_accepts_pre_encoded_planes():
    """The campaign path feeds packed planes straight into pair_block."""
    spec = CZEKANOWSKI
    ex = TileExecutor(cfg=CometConfig(impl="levels", levels=2,
                                      encoding="bitplane"),
                      metric=spec, out_dtype=jnp.float32, axis=None)
    Va, Vb = _blocks(40, 9, 13, 2, seed=5)
    sa = jnp.asarray(np.asarray(spec.stat(Va)))
    sb = jnp.asarray(np.asarray(spec.stat(Vb)))
    from_values = ex.pair_block(Va, sa, Vb, sb)
    from_planes = ex.pair_block(
        encode_bitplanes(Va, 2), sa, encode_bitplanes(Vb, 2), sb
    )
    assert (np.asarray(from_values) == np.asarray(from_planes)).all()


def test_fused_levels_zero_denominator_guarded():
    """All-zero vectors must yield 0 through the in-kernel epilogue."""
    V = np.zeros((16, 4), np.float32)
    V[:, 0] = 1.0
    P = encode_bitplanes_np(V, 1)
    s = jnp.asarray(V.sum(axis=0))
    got = np.asarray(metric2_levels(
        jnp.asarray(P), jnp.asarray(P), s, s,
        epilogue=CZEKANOWSKI.assemble_tile, bm=8, bn=8, bkb=1))
    assert np.isfinite(got).all()
    assert got[0, 0] == 1.0
    assert (got[1:, :] == 0).all() and (got[:, 1:] == 0).all()


def test_tri_plane_kernel_packed_storage():
    """Triangular plane kernel emits only the T(T+1)/2 upper tiles."""
    V = jnp.asarray(random_integer_vectors(16, 32, max_value=2, seed=2))
    P = encode_bitplanes(V, 2)
    s = jnp.asarray(np.asarray(CZEKANOWSKI.stat(V)))
    packed = metric2_levels_tri(P, s, epilogue=CZEKANOWSKI.assemble_tile,
                                bt=8, bkb=1)
    T = 32 // 8
    assert packed.shape == (T * (T + 1) // 2, 8, 8)
    dense = unpack_tri_tiles(packed, 32, 8)
    num = jnp.minimum(V[:, :, None], V[:, None, :]).astype(jnp.float32).sum(0)
    want = np.asarray(CZEKANOWSKI.assemble2(num, s[:, None], s[None, :]))
    want = np.where(np.triu(np.ones((32, 32), bool), 1), want, 0)
    assert (np.asarray(dense) == want.astype(np.float32)).all()


def test_threeway_levels_batch_parity():
    """Packed-AND 3-way slice kernel == chained-min XLA formulation."""
    rng = np.random.default_rng(9)
    n_f, m, L, lv = 24, 10, 3, 2
    own = rng.integers(0, lv + 1, (n_f, m)).astype(np.float32)
    X = rng.integers(0, lv + 1, (n_f, L)).astype(np.float32)
    right = rng.integers(0, lv + 1, (n_f, m)).astype(np.float32)
    got = np.asarray(threeway_batch_levels(
        encode_bitplanes(jnp.asarray(own), lv),
        encode_bitplanes(jnp.asarray(X), lv),
        encode_bitplanes(jnp.asarray(right), lv),
        bm=8, bn=8, bkb=1,
    ))
    want = np.zeros((L, m, m), np.float32)
    for t in range(L):
        Xo = np.minimum(own, X[:, t:t + 1])  # (n_f, m)
        want[t] = np.minimum(Xo[:, :, None], right[:, None, :]).sum(axis=0)
    assert (got == want).all()


# -- executor dispatch / path surfacing -------------------------------------


def test_executor_path_property():
    spec = CZEKANOWSKI
    cases = [  # (cfg, want_path, reason_fragment)
        (CometConfig(impl="pallas"), "fused-vpu", ""),
        (CometConfig(impl="levels"), "fused-levels", ""),
        (CometConfig(impl="levels_xla"), "unfused", "no fused kernel"),
        (CometConfig(impl="xla"), "unfused", "no fused kernel"),
        # n_pf > 1 keeps the MXU path fused: raw in-kernel numerators,
        # psummed over "pf", assembled by the merge epilogue
        (CometConfig(impl="levels", n_pf=2), "fused-levels",
         "merge epilogue"),
        # the VPU kernel has no raw-numerator form, so it still demotes
        (CometConfig(impl="pallas", n_pf=2), "unfused", "n_pf"),
    ]
    for cfg, want, frag in cases:
        ex = TileExecutor(cfg=cfg, metric=spec)
        assert ex.path == want, (cfg.impl, cfg.n_pf, ex.path)
        assert ex.fused == (want != "unfused")
        assert frag in ex.path_reason, (want, ex.path_reason)
    # a product-combine metric cannot take the level decomposition
    from repro.api.registry import get_metric

    ccc = get_metric("ccc")
    ex = TileExecutor(cfg=CometConfig(impl="levels"), metric=ccc)
    assert ex.path == "unfused" and "min" in ex.path_reason


def test_executor_path3_matrix():
    """3-way slice dispatch: the resolved plane campaign reports the
    end-to-end ring state; a value ring keeps the per-slice kernel path
    with a reason (so --dry-run shows why the ring was not planed)."""
    spec = CZEKANOWSKI
    cases = [  # (cfg, want_path3, reason_fragment)
        (CometConfig(impl="levels", encoding="bitplane"),
         "fused-levels-ring", ""),
        (CometConfig(impl="levels", encoding="none"),
         "fused-levels", "encoded per slice"),
        (CometConfig(impl="levels"),  # unresolved 'auto' != plane ring
         "fused-levels", "encoded per slice"),
        (CometConfig(impl="pallas"), "fused-vpu", ""),
        (CometConfig(impl="levels_xla", encoding="bitplane"),
         "unfused", "no fused kernel"),
        (CometConfig(impl="xla"), "unfused", "no fused kernel"),
        # unlike 2-way, n_pf does not demote the 3-way slice path
        (CometConfig(impl="levels", encoding="bitplane", n_pf=2),
         "fused-levels-ring", ""),
    ]
    for cfg, want, frag in cases:
        ex = TileExecutor(cfg=cfg, metric=spec)
        assert ex.path3 == want, (cfg.impl, cfg.encoding, ex.path3)
        assert frag in ex.path3_reason, (want, ex.path3_reason)
        assert ex.fused3 == (want != "unfused")
    from repro.api.registry import get_metric

    ex = TileExecutor(cfg=CometConfig(impl="levels"), metric=get_metric("ccc"))
    assert ex.path3 == "unfused" and "min" in ex.path3_reason


def test_threeway_slice_accepts_pre_encoded_planes():
    """The plane ring feeds packed operands straight into threeway_slice;
    fused (levels) and unfused (levels_xla) realizations both match the
    value-fed slice bit-for-bit, as do the pairwise numerators."""
    rng = np.random.default_rng(11)
    n_f, m, L, lv = 21, 9, 3, 2  # non-multiple-of-8 fields
    ps = jnp.asarray(rng.integers(0, lv + 1, (n_f, L)).astype(np.float32))
    left = jnp.asarray(rng.integers(0, lv + 1, (n_f, m)).astype(np.float32))
    right = jnp.asarray(rng.integers(0, lv + 1, (n_f, m)).astype(np.float32))
    Pp, Pl, Pr = (encode_bitplanes(x, lv) for x in (ps, left, right))
    for impl in ("levels", "levels_xla"):
        vals = TileExecutor(cfg=CometConfig(impl=impl, levels=lv,
                                            encoding="none"),
                            metric=CZEKANOWSKI, axis=None)
        ring = TileExecutor(cfg=CometConfig(impl=impl, levels=lv,
                                            encoding="bitplane"),
                            metric=CZEKANOWSKI, axis=None)
        got = np.asarray(ring.threeway_slice(Pp, Pl, Pr))
        want = np.asarray(vals.threeway_slice(ps, left, right))
        assert (got == want).all(), impl
        n2 = np.asarray(ring.pair_numerator(Pp, Pl))
        n2_want = np.asarray(vals.pair_numerator(ps, left))
        assert (n2 == n2_want).all(), impl


def test_resolve_config_auto_knobs():
    V012 = random_integer_vectors(16, 6, max_value=2, seed=0)
    spec = CZEKANOWSKI
    r = resolve_config(CometConfig(impl="levels", levels=2), V012, spec)
    assert r.ring_dtype == "int8" and r.encoding == "bitplane"
    # explicit float32 opt-out survives resolution
    r = resolve_config(
        CometConfig(impl="levels", levels=2, ring_dtype="float32"), V012, spec)
    assert r.ring_dtype == "float32"
    # out-of-range data: auto falls back, explicit bitplane raises
    Vbig = random_integer_vectors(16, 6, max_value=9, seed=0)
    r = resolve_config(CometConfig(impl="levels", levels=2), Vbig, spec)
    assert r.encoding == "none"
    with pytest.raises(ValueError):
        resolve_config(
            CometConfig(impl="levels", levels=2, encoding="bitplane"),
            Vbig, spec)
    # non-integer data: no int8 ring, no bitplane
    Vf = np.random.default_rng(0).random((16, 6)).astype(np.float32)
    r = resolve_config(CometConfig(impl="levels", levels=2), Vf, spec)
    assert r.ring_dtype == "float32" and r.encoding == "none"
    # bitplane is a levels-path knob
    with pytest.raises(ValueError):
        resolve_config(CometConfig(impl="xla", encoding="bitplane"),
                       V012, spec)


# -- campaign checksum parity (single device; multi-device in harness) ------


def test_campaign_checksum_parity_2way_and_3way():
    """impl in {xla, levels, levels_xla} x encoding settings: bit-identical
    checksums on {0,1,2} SNP-style data."""
    V = random_integer_vectors(40, 18, max_value=2, seed=7)
    mesh = make_comet_mesh(1, 1, 1)
    ref = czek2_distributed(
        V, mesh, CometConfig(ring_dtype="float32", encoding="none")
    ).checksum()
    for cfg in [
        CometConfig(impl="levels", levels=2),
        CometConfig(impl="levels_xla", levels=2),
        CometConfig(impl="levels", levels=2, encoding="none"),
        CometConfig(impl="levels_xla", levels=2, encoding="bitplane"),
    ]:
        assert czek2_distributed(V, mesh, cfg).checksum() == ref, cfg

    V3 = V[:, :12]
    ref3 = czek3_distributed(
        V3, mesh, CometConfig(ring_dtype="float32"), stage=0
    ).checksum()
    for cfg in [
        CometConfig(impl="levels", levels=2),  # auto -> plane ring
        CometConfig(impl="levels_xla", levels=2),  # plane ring, unfused slice
        CometConfig(impl="levels", levels=2, encoding="none"),  # value ring
        CometConfig(impl="levels", levels=2, encoding="bitplane"),
    ]:
        assert czek3_distributed(V3, mesh, cfg, stage=0).checksum() == ref3, cfg
