from .ops import (  # noqa: F401
    metric2_levels,
    metric2_levels_tri,
    mgemm_levels,
    mgemm_levels_planes,
    mgemm_levels_planes_xla,
    mgemm_levels_xla,
)
from .planes import (  # noqa: F401
    POPCOUNT,
    PackedPlanes,
    decode_bitplanes,
    encode_bitplanes,
    encode_bitplanes_np,
    pad_planes,
    planes_nbytes,
    shard_planes_fields,
    slice_planes_vectors,
    take_planes_vectors,
    values_from_planes,
)
from .ref import metric2_levels_planes_ref, mgemm_levels_ref  # noqa: F401
