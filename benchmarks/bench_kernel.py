"""Paper Table 1: metric contraction kernels vs standard GEMM (single device).

The paper compares modified-MAGMA mGEMM against cuBLAS GEMM on a K20X
(mGEMM within ~2.5x of GEMM-achievable).  Post-API-redesign the contraction
is owned by the metric registry, so this table times every registered
metric's contraction through ``MetricSpec.contract_fn`` at the same (scaled)
shape: Czekanowski's min-plus mGEMM (XLA + the beyond-paper MXU level path)
and CCC's plain dot (which IS the GEMM baseline, giving the paper's ratio
directly).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import row, time_fn
from repro.api import available_metrics, get_metric
from repro.core.twoway import CometConfig

# paper shape n_v=10240, n_f=12288 scaled /8 to stay CPU-friendly
M = N = 1280
K = 1536


# -- BENCH_kernels.json sweep (perf trajectory) -----------------------------
#
# impl × size grid timing the 2-way contraction kernels, plus the fused
# metric kernels: "pallas_fused" (VPU contraction + in-kernel epilogue) and
# "fused-levels" (MXU bit-plane contraction + in-kernel epilogue — the
# packed-campaign TileExecutor hot path), and the hoisted plane entries
# ("levels", "levels_xla_hoisted") where bit-planes are encoded ONCE outside
# the timed region, as the campaign path does, instead of ``(V >= t)`` per
# call.  GiB/s counts the operand reads + result write; comparisons/s is the
# paper's element-op rate (m*k*n combines per call).

SWEEP_SHAPES = [(128, 256, 128), (256, 512, 256)]

# ingest grows past the kernel grid: at (128, 256, 128) the dataset-store
# mmap load still loses to the host encoder (fixed open/parse overhead on a
# 12 KiB payload); the larger shapes are where the zero-encode path pays
INGEST_SHAPES = SWEEP_SHAPES + [(512, 1024, 512), (1024, 4096, 1024)]

# streamed-pipeline overlap entries: a genomics-profile campaign shape
# (n_f >> n_v) streamed chunk by chunk through repro.stream
STREAM_SHAPE = (256, 65536, 256)
#: modeled staging bandwidth (MiB/s) for the stream entries.  CI storage
#: serves the payload from the page cache at memory speed — no real
#: out-of-core source does — so the staged fill is floored to this rate
#: (a mid-range shared-filesystem figure) to make the io/compute overlap
#: measurable and reproducible.  The fill itself is the real mmap chunk
#: copy; only its minimum duration is modeled.
STREAM_MODEL_MIB_S = 128


def _sweep_callables(A, B, sa, sb, levels):
    from repro.core.metric_spec import czek_assemble_tile
    from repro.core.mgemm import get_impl
    from repro.kernels.mgemm import czek2_metric
    from repro.kernels.mgemm_levels import (
        encode_bitplanes,
        metric2_levels,
        mgemm_levels_planes_xla,
    )

    xla = get_impl("xla")
    lvl = get_impl("levels_xla")
    lvl_mxu = get_impl("levels")
    pallas = get_impl("pallas")
    # hoisted entries: planes pre-encoded, like the campaign ring payload
    Pa = jax.block_until_ready(encode_bitplanes(A.T, levels))
    Pb = jax.block_until_ready(encode_bitplanes(B, levels))
    m, n = A.shape[0], B.shape[1]
    bm = min(256, m)
    bn = min(256, n)
    return {
        "xla": lambda: xla(A, B),
        "levels_xla": lambda: lvl(A, B, levels=levels),
        "levels_xla_hoisted": lambda: mgemm_levels_planes_xla(Pa, Pb),
        "levels": lambda: lvl_mxu(A, B, levels=levels),
        "pallas": lambda: pallas(A, B),
        "pallas_fused": lambda: czek2_metric(A, B, sa, sb),
        "fused-levels": lambda: metric2_levels(
            Pa, Pb, sa, sb, epilogue=czek_assemble_tile, bm=bm, bn=bn),
    }


def binary_sweep(shapes=SWEEP_SHAPES):
    """levels=1 entries for BENCH_kernels.json: the popcount bit-GEMM vs the
    bf16 plane kernels on the same binary ({0,1}) operands.

    Three impls per shape, all fed the SAME pre-encoded single-plane
    payload (campaign conditions — encode is hoisted):

    * ``popcount``     — ``metric2_pop``: AND + ``lax.population_count`` on
      packed bytes, fused epilogue (``path == "fused-popcount"``);
    * ``fused-levels`` — ``metric2_levels`` at levels=1: unpack to bf16
      indicators, MXU plane dot, fused epilogue (what binary campaigns ran
      before the fast path);
    * ``levels_xla``   — the unfused XLA plane contraction.

    Entries carry ``"levels": 1`` so the binary rows are distinguishable
    from the leveled sweep at the same shapes.  The acceptance gate:
    ``popcount`` >= the ``fused-levels`` rate at every measured shape.
    """
    from repro.core.metric_spec import czek_assemble_tile
    from repro.kernels.mgemm_levels import (
        encode_bitplanes,
        metric2_levels,
        mgemm_levels_planes_xla,
    )
    from repro.kernels.popgemm import metric2_pop

    entries = []
    rng = np.random.default_rng(1)
    for m, k, n in shapes:
        A = jnp.asarray(rng.integers(0, 2, (m, k)).astype(np.float32))
        B = jnp.asarray(rng.integers(0, 2, (k, n)).astype(np.float32))
        sa = A.sum(axis=1)
        sb = B.sum(axis=0)
        Pa = jax.block_until_ready(encode_bitplanes(A.T, 1))
        Pb = jax.block_until_ready(encode_bitplanes(B, 1))
        bm = min(256, m)
        bn = min(256, n)
        bytes_moved = (m * k + k * n + m * n) * 4
        calls = {
            "popcount": lambda: metric2_pop(
                Pa, Pb, sa, sb, epilogue=czek_assemble_tile, bm=bm, bn=bn),
            "fused-levels": lambda: metric2_levels(
                Pa, Pb, sa, sb, epilogue=czek_assemble_tile, bm=bm, bn=bn),
            "levels_xla": lambda: mgemm_levels_planes_xla(Pa, Pb),
        }
        for impl, fn in calls.items():
            t = time_fn(lambda fn=fn: fn(), warmup=2, iters=9, reduce="min")
            entries.append({
                "impl": impl,
                "levels": 1,
                "m": m, "k": k, "n": n,
                "seconds": t,
                "gib_per_s": bytes_moved / t / 2**30,
                "comparisons_per_s": m * k * n / t,
            })
    return entries


def ingest_entries(shapes=INGEST_SHAPES, max_value=3):
    """Store-load vs host-encode entries for BENCH_kernels.json.

    For each sweep shape, times getting a (k = n_f, n = n_v) leveled matrix
    into campaign-ready packed planes two ways:

    * ``host_encode`` — ``encode_bitplanes_np`` of the in-memory matrix
      (what every in-memory campaign pays per run);
    * ``store_load``  — ``DatasetReader.packed()`` off a pre-written
      dataset directory (mmap -> PackedPlanes, the zero-encode path).

    ``gib_per_s`` moves the packed payload bytes; ``comparisons_per_s``
    reuses the schema slot for matrix elements ingested per second.
    """
    import tempfile

    from benchmarks.util import time_fn
    from repro.kernels.mgemm_levels import encode_bitplanes_np, planes_nbytes
    from repro.store import DatasetReader, write_dataset

    entries = []
    rng = np.random.default_rng(0)
    levels = max_value
    for m, k, n in shapes:
        V = rng.integers(0, max_value + 1, (k, n)).astype(np.float32)
        payload = planes_nbytes(k, n, levels)
        with tempfile.TemporaryDirectory() as tmp:
            write_dataset(tmp, V, levels=levels)

            def load(tmp=tmp):
                # eager read (the campaign materializes the payload too)
                return DatasetReader(tmp).packed(mmap=False).planes

            for impl, fn in (
                ("host_encode", lambda: encode_bitplanes_np(V, levels)),
                ("store_load", load),
            ):
                t = time_fn(lambda fn=fn: fn(), warmup=2, iters=9,
                            reduce="min")
                entries.append({
                    "impl": impl,
                    "m": m, "k": k, "n": n,
                    "seconds": t,
                    "gib_per_s": payload / t / 2**30,
                    "comparisons_per_s": k * n / t,
                })
    return entries


def stream_entries(shape=STREAM_SHAPE, max_value=3,
                   model_mib_s=STREAM_MODEL_MIB_S):
    """Steady-state out-of-core overlap entries for BENCH_kernels.json.

    One multi-shard dataset, streamed chunk by chunk two ways:

    * ``stream``     — the ``repro.stream`` double-buffered pipeline: the
      ``ShardPrefetcher`` worker stages chunk ``s+1`` from the shard mmaps
      while the device contracts chunk ``s`` (the consumer blocks inside
      XLA with the GIL released, so the worker's copies genuinely overlap);
    * ``stream_seq`` — the same chunks staged and contracted serially (what
      a loop without the prefetcher pays).

    Staging is floored to ``model_mib_s`` (see STREAM_MODEL_MIB_S); the
    per-chunk device work is the real packed-plane contraction.  The gap
    between the two entries is the overlap win the prefetcher buys at
    steady state: ``stream`` ~ max(staging, compute) per chunk against
    ``stream_seq``'s sum.
    """
    import tempfile
    import time as _time

    from benchmarks.util import time_fn
    from repro.kernels.mgemm_levels import mgemm_levels_planes_xla
    from repro.store import DatasetReader, write_dataset
    from repro.stream import ShardPrefetcher, StreamPlan, fill_chunk

    _, k, n = shape
    levels = max_value
    rng = np.random.default_rng(0)
    V = rng.integers(0, max_value + 1, (k, n)).astype(np.float32)
    floor_bps = model_mib_s * 2**20
    with tempfile.TemporaryDirectory() as tmp:
        for n_shards in (8, 4, 2, 1):  # most shards the byte axis divides
            try:
                write_dataset(tmp, V, levels=levels, n_shards=n_shards)
                break
            except ValueError:
                continue
        reader = DatasetReader(tmp)
        splan = StreamPlan.for_reader(reader, n_v=reader.n_v)
        chunks = splan.chunks()

        def make_shard_of():
            cache = {}

            def shard_of(rank):
                if rank not in cache:
                    cache[rank] = reader.shard(rank)
                return cache[rank]

            return shard_of

        def staged_fill(buf, chunk, shard_of):
            t0 = _time.perf_counter()
            fill_chunk(buf, chunk, shard_of, reader.n_v)
            rest = splan.chunk_nbytes / floor_bps - (_time.perf_counter() - t0)
            if rest > 0:
                _time.sleep(rest)

        def run_seq():
            shard_of = make_shard_of()
            buf = np.zeros(splan.chunk_shape, np.uint8)
            acc = np.zeros((n, n), np.float32)
            for c in chunks:
                staged_fill(buf, c, shard_of)
                out = mgemm_levels_planes_xla(jnp.asarray(buf),
                                              jnp.asarray(buf))
                np.add(acc, np.asarray(out), out=acc)
            return acc

        def run_stream():
            shard_of = make_shard_of()
            bufs = [np.zeros(splan.chunk_shape, np.uint8)
                    for _ in range(splan.n_buffers)]
            acc = np.zeros((n, n), np.float32)

            def fill(i, buf):
                staged_fill(buf, chunks[i], shard_of)

            with ShardPrefetcher(fill, len(chunks), bufs) as pf:
                for _i, buf in pf:
                    out = mgemm_levels_planes_xla(jnp.asarray(buf),
                                                  jnp.asarray(buf))
                    np.add(acc, np.asarray(out), out=acc)
                    pf.release(buf)
            return acc

        total_bytes = splan.chunk_nbytes * len(chunks)
        entries = []
        for impl, fn in (("stream_seq", run_seq), ("stream", run_stream)):
            t = time_fn(lambda fn=fn: fn(), warmup=1, iters=5, reduce="min")
            entries.append({
                "impl": impl,
                "m": n, "k": k, "n": n,
                "seconds": t,
                "gib_per_s": total_bytes / t / 2**30,
                "comparisons_per_s": k * n * n / t,
            })
    return entries


# batched-campaign entries: a PheWAS-style multi-campaign job at a
# campaign-scale shape (n_f >> typical kernel tiles is unnecessary here —
# the win being measured is encode/traversal/compile sharing, not FLOPs)
BATCHED_SHAPE = (256, 512, 256)


def batched_sweep(shape=BATCHED_SHAPE, max_value=2):
    """Batched-campaign vs sequential-loop entries for BENCH_kernels.json.

    One PheWAS-style job — 2 metrics (czekanowski + sorenson: ONE shared
    numerator family) x 2 overlapping named subsets whose union is the full
    vector set, i.e. 4 campaigns — run two ways through the SAME engine:

    * ``batched``     — one ``SimilarityEngine`` run with ``metrics=[...]``
      + ``subsets=[...]``: one encode, one ring traversal, one contraction
      per family, epilogue/extraction fan-out per campaign;
    * ``batched_seq`` — the loop it replaces: 4 independent sequential
      campaigns, each encoding and traversing its own payload slice.

    Entries carry ``"campaigns": 4`` so the rows are recognizably batched.
    The acceptance gate: ``batched`` >= 1.5x the ``batched_seq`` rate at
    campaigns >= 4.
    """
    from benchmarks.util import time_fn
    from repro.api import SimilarityEngine, SimilarityRequest

    _, k, n = shape
    rng = np.random.default_rng(2)
    V = rng.integers(0, max_value + 1, (k, n)).astype(np.float32)
    third = max(1, n // 3)
    subsets = (
        ("first", tuple(range(0, min(n, 2 * third)))),
        ("second", tuple(range(third, n))),
    )
    metrics = ("czekanowski", "sorenson")
    levels = max(2, max_value)
    engine = SimilarityEngine()
    breq = SimilarityRequest(
        metric=metrics[0], metrics=metrics[1:], subsets=subsets,
        way=2, impl="levels", levels=levels,
    )

    def run_batched():
        return engine.run(breq, V)

    def run_seq():
        results = []
        for mname in metrics:
            for _sname, idx in subsets:
                results.append(engine.run(
                    SimilarityRequest(metric=mname, way=2, impl="levels",
                                      levels=levels),
                    V[:, list(idx)],
                ))
        return results

    campaigns = len(metrics) * len(subsets)
    # identical logical work both ways: per campaign v(v-1)/2 pairs x k
    pairs = len(metrics) * sum(
        len(idx) * (len(idx) - 1) // 2 for _s, idx in subsets
    )
    bytes_moved = k * n * 4  # the shared payload, read once per traversal
    entries = []
    for impl, fn in (("batched_seq", run_seq), ("batched", run_batched)):
        t = time_fn(lambda fn=fn: fn(), warmup=1, iters=5, reduce="min")
        entries.append({
            "impl": impl,
            "m": n, "k": k, "n": n,
            "campaigns": campaigns,
            "seconds": t,
            "gib_per_s": bytes_moved / t / 2**30,
            "comparisons_per_s": pairs * k / t,
        })
    # Attach the per-phase wall-time breakdown from ONE traced rerun to
    # the batched entry (where did the traversal's time go: encode vs
    # ring-step vs merge), so a phase-share regression is visible across
    # committed BENCH_kernels.json revisions.  Best-effort: the timing
    # entries above stand alone, and existing files without "obs" stay
    # valid (benchmarks.run gates the schema).
    try:
        from repro.obs import trace as obs

        obs.enable()
        try:
            result = run_batched()
        finally:
            tracer = obs.disable()
        entries[-1]["obs"] = {
            "phases": {
                name: p["seconds"]
                for name, p in sorted(tracer.phase_stats().items())
                if name != "roofline"
            },
            "comparisons_per_s": result.meta["obs"]["comparisons_per_s"],
        }
    except Exception:
        pass
    return entries


def kernel_sweep(shapes=SWEEP_SHAPES, max_value=3):
    """Entries for BENCH_kernels.json: impl × size × GiB/s, comparisons/s."""
    entries = []
    rng = np.random.default_rng(0)
    for m, k, n in shapes:
        A = jnp.asarray(rng.integers(0, max_value + 1, (m, k)).astype(np.float32))
        B = jnp.asarray(rng.integers(0, max_value + 1, (k, n)).astype(np.float32))
        sa = A.sum(axis=1)
        sb = B.sum(axis=0)
        bytes_moved = (m * k + k * n + m * n) * 4
        for impl, fn in _sweep_callables(A, B, sa, sb, max_value).items():
            # min of 9: the trajectory file gates future PRs, so the
            # entries need to be stable against scheduler noise
            t = time_fn(lambda fn=fn: fn(), warmup=2, iters=9, reduce="min")
            entries.append({
                "impl": impl,
                "m": m, "k": k, "n": n,
                "seconds": t,
                "gib_per_s": bytes_moved / t / 2**30,
                "comparisons_per_s": m * k * n / t,
            })
    return entries


def main():
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.integers(0, 3, (M, K)).astype(np.float32))
    B = jnp.asarray(rng.integers(0, 3, (K, N)).astype(np.float32))

    t_gemm = time_fn(jax.jit(lambda a, b: a @ b), A, B)
    ops = 2 * M * K * N
    rows = [row("table1/gemm", t_gemm, f"{ops / t_gemm / 1e9:.2f}_GOps")]

    variants = []
    for name in available_metrics():
        spec = get_metric(name)
        variants.append((name, spec, CometConfig()))
        if spec.uses_mgemm:  # the MXU level-decomposition path (beyond-paper)
            variants.append(
                (f"{name}_levels_L2", spec,
                 CometConfig(impl="levels_xla", levels=2))
            )
    for label, spec, cfg in variants:
        contract = spec.contract_fn(cfg)
        t = time_fn(jax.jit(lambda a, b, c=contract: c(a, b)), A, B)
        rows.append(row(
            f"table1/{label}", t,
            f"{ops / t / 1e9:.2f}_GOps_ratio={t / t_gemm:.2f}x",
        ))
    return rows


if __name__ == "__main__":
    from benchmarks.util import print_rows

    print_rows(main())
