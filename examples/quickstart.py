"""Quickstart: all-pairs + all-triples similarity through the unified API.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.api import SimilarityEngine, SimilarityRequest, available_metrics
from repro.core.synthetic import random_integer_vectors


def main():
    # 200 vectors of 128 fields — think "SNP profiles" or "metabolite peaks"
    V = random_integer_vectors(n_f=128, n_v=198, max_value=15, seed=7)
    engine = SimilarityEngine()  # owns mesh construction; scales via (pf,pv,pr)
    print(f"registered metrics: {available_metrics()}")

    out2 = engine.run(SimilarityRequest(metric="czekanowski", way=2), V)
    print(f"2-way: {out2.num_results()} unique pairs, "
          f"checksum {hex(out2.checksum())[:18]}")
    pairs = [(i, j, w) for i, j, w in out2.entries()]
    for i, j, w in sorted(pairs, key=lambda t: -t[2])[:5]:
        print(f"  most similar: v{i} ~ v{j}  c2={w:.4f}")

    # 3-way on a subset (O(n^3) results!)
    out3 = engine.run(SimilarityRequest(metric="czekanowski", way=3), V[:, :48])
    print(f"3-way: {out3.num_results()} unique triples, "
          f"checksum {hex(out3.checksum())[:18]}")
    triples = [(i, j, k, w) for i, j, k, w in out3.entries()]
    for i, j, k, w in sorted(triples, key=lambda t: -t[3])[:5]:
        print(f"  most similar: (v{i}, v{j}, v{k})  c3={w:.4f}")

    # any registered metric runs through the same engine — e.g. the Custom
    # Correlation Coefficient of the companion paper (arXiv:1705.08213)
    ccc = engine.run(SimilarityRequest(metric="ccc", way=2), V)
    top = max(ccc.entries(), key=lambda t: t[2])
    print(f"ccc:   top pair v{top[0]} ~ v{top[1]}  ccc={top[2]:.4f}")


if __name__ == "__main__":
    main()
