"""deepseek-67b [dense] — arXiv:2401.02954 (hf-verified).

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400, llama-arch.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
    head_dim=128,
)

SMOKE = CONFIG.replace(
    name="deepseek-67b-smoke",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=192,
    vocab_size=256,
    head_dim=16,
)
