"""grok-1-314b [moe] — hf:xai-org/grok-1 (unverified).

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, MoE 8 experts top-2.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    head_dim=128,
    n_experts=8,
    experts_per_token=2,
    moe_d_ff=32768,
)

SMOKE = CONFIG.replace(
    name="grok-1-314b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    n_experts=4,
    experts_per_token=2,
    moe_d_ff=128,
)
