"""Dataset-store CLI: encode / inspect / validate packed bit-plane datasets.

    # encode: npy matrix, synthetic draw, or PLINK fileset -> dataset dir
    python -m repro.launch.dataset encode --input V.npy --levels 2 --out ds/
    python -m repro.launch.dataset encode --synthetic --n-f 1000 --n-v 512 \
        --max-value 2 --seed 0 --out ds/ --shards 4
    python -m repro.launch.dataset encode --bed cohort --missing drop --out ds/

    # append: grow a dataset with new vectors (byte-column append — the
    # existing payload is never re-encoded); omit --out to grow in place
    python -m repro.launch.dataset append --to ds/ --input new.npy --out ds2/
    python -m repro.launch.dataset append --to ds/ --synthetic --n-v 32 --seed 1

    # inspect: manifest + stats summary
    python -m repro.launch.dataset inspect ds/

    # validate: recompute payload checksum + stats against the manifest
    python -m repro.launch.dataset validate ds/

A campaign then consumes the store with zero host-side encode:

    python -m repro.launch.similarity --way 2 --dataset ds/ --impl levels

Format spec: docs/BITPLANE_FORMAT.md ("On-disk storage" chapter).
"""
import argparse
import sys


def _cmd_encode(args) -> int:
    import numpy as np

    from repro.store import write_dataset

    picked = [bool(args.input), bool(args.bed), args.synthetic]
    if sum(picked) != 1:
        print("error: pick exactly one of --input / --bed / --synthetic",
              file=sys.stderr)
        return 2
    levels = args.levels
    if args.input:
        from repro.core.validate import validate_matrix

        V = validate_matrix(np.load(args.input), what=args.input,
                            check_fp32_sums=True)
        source = {"kind": "npy", "path": args.input}
    elif args.bed:
        from repro.store import read_bed

        V, source = read_bed(args.bed, missing=args.missing)
        if levels is None:
            levels = 2  # {0, 1, 2} dosages
    else:
        from repro.core.synthetic import random_integer_vectors

        V = random_integer_vectors(
            args.n_f, args.n_v, max_value=args.max_value, seed=args.seed
        )
        source = {"kind": "synthetic", "n_f": args.n_f, "n_v": args.n_v,
                  "max_value": args.max_value, "seed": args.seed}
        if levels is None:
            levels = args.max_value
    if levels is None:
        levels = int(V.max()) if V.size else 1
    manifest = write_dataset(
        args.out, V, levels=levels, n_shards=args.shards, source=source
    )
    print(f"wrote {args.out}: n_f={manifest['n_f']} n_v={manifest['n_v']} "
          f"levels={manifest['levels']} shards={manifest['n_shards']} "
          f"kb={manifest['kb']}")
    print(f"checksum={manifest['checksum']}")
    return 0


def _cmd_append(args) -> int:
    import numpy as np

    from repro.store import append_dataset, read_manifest

    if bool(args.input) == args.synthetic:
        print("error: pick exactly one of --input / --synthetic",
              file=sys.stderr)
        return 2
    if args.input:
        from repro.core.validate import validate_matrix

        V_new = validate_matrix(np.load(args.input), what=args.input,
                                check_fp32_sums=True)
    else:
        from repro.core.synthetic import random_integer_vectors

        parent = read_manifest(args.to)
        # synthetic appends inherit the target's field count and draw
        # within its encoded level range so the grown payload stays valid
        V_new = random_integer_vectors(
            parent["n_f"], args.n_v,
            max_value=(args.max_value if args.max_value is not None
                       else parent["levels"]),
            seed=args.seed,
        )
    manifest = append_dataset(args.to, V_new, out=(args.out or None))
    where = args.out or args.to
    parent = manifest["parent"]
    print(f"appended {V_new.shape[1]} vector(s): {where} now n_v="
          f"{manifest['n_v']} (v{manifest['dataset_version']}, parent n_v="
          f"{parent['n_v']})")
    print(f"checksum={manifest['checksum']}")
    print(f"parent_checksum={parent['checksum']}")
    return 0


def _cmd_inspect(args) -> int:
    from repro.kernels.mgemm_levels import planes_nbytes
    from repro.store import DatasetReader

    r = DatasetReader(args.path)
    m = r.manifest
    stats = r.stats()
    print(f"dataset {args.path}")
    print(f"  n_f={m['n_f']} n_v={m['n_v']} levels={m['levels']} "
          f"kb={m['kb']} shards={m['n_shards']}")
    print(f"  payload={planes_nbytes(8 * m['kb'], m['n_v'], m['levels'])} bytes "
          f"({m['levels']} plane(s) x {m['kb']} bytes x {m['n_v']} vectors)")
    print(f"  checksum={m['checksum']}")
    print(f"  source={m.get('source', {})}")
    pops = stats.sum(axis=1)
    for t in range(m["levels"]):
        print(f"  plane {t + 1}: popcount={int(pops[t])}")
    print(f"  column-sum range=[{int(stats.sum(axis=0).min())}, "
          f"{int(stats.sum(axis=0).max())}]")
    return 0


def _cmd_validate(args) -> int:
    from repro.store import DatasetReader

    m = DatasetReader(args.path).validate()
    print(f"{args.path}: OK ({m['n_shards']} shard(s), {m['checksum']})")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.launch.dataset")
    sub = ap.add_subparsers(dest="cmd", required=True)

    enc = sub.add_parser("encode", help="encode a source into a plane dataset")
    enc.add_argument("--input", default="", help=".npy (n_f, n_v) matrix")
    enc.add_argument("--bed", default="",
                     help="PLINK fileset prefix (or .bed path)")
    enc.add_argument("--missing", default="error",
                     choices=("error", "zero", "drop"),
                     help="PLINK missing-genotype policy")
    enc.add_argument("--synthetic", action="store_true",
                     help="draw the paper's random-integer dataset")
    enc.add_argument("--n-f", type=int, default=512)
    enc.add_argument("--n-v", type=int, default=240)
    enc.add_argument("--max-value", type=int, default=2)
    enc.add_argument("--seed", type=int, default=0)
    enc.add_argument("--levels", type=int, default=None,
                     help="plane count (default: max-value for synthetic, "
                          "2 for bed, data max for npy)")
    enc.add_argument("--shards", type=int, default=1,
                     help="field shards on disk (= the n_pf byte ranges)")
    enc.add_argument("--out", required=True, help="dataset directory")
    enc.set_defaults(fn=_cmd_encode)

    app = sub.add_parser("append",
                         help="append vectors to a dataset (byte-column "
                              "append; no re-encode of the existing payload)")
    app.add_argument("--to", required=True, help="existing dataset directory")
    app.add_argument("--input", default="",
                     help=".npy (n_f, m) matrix of new vectors")
    app.add_argument("--synthetic", action="store_true",
                     help="draw new synthetic vectors matching the "
                          "dataset's n_f and levels")
    app.add_argument("--n-v", type=int, default=32,
                     help="synthetic vector count to append")
    app.add_argument("--max-value", type=int, default=None,
                     help="synthetic value range (default: dataset levels)")
    app.add_argument("--seed", type=int, default=1)
    app.add_argument("--out", default="",
                     help="write the grown dataset here (default: grow "
                          "--to in place)")
    app.set_defaults(fn=_cmd_append)

    ins = sub.add_parser("inspect", help="print manifest + stats summary")
    ins.add_argument("path")
    ins.set_defaults(fn=_cmd_inspect)

    val = sub.add_parser("validate",
                         help="recompute checksum + stats vs the manifest")
    val.add_argument("path")
    val.set_defaults(fn=_cmd_validate)

    args = ap.parse_args(argv)
    try:
        return args.fn(args)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    sys.exit(main())
