"""Distributed 2-way Proportional Similarity engine — paper §4.1, Algorithm 1.

SPMD mapping (shard_map over a ("pf", "pv", "pr") mesh):

* V (n_f, n_v) is sharded over "pf" (vector elements) and "pv" (vector
  number), replicated over "pr".
* Ring: at step d, every rank holds block (p_v + d) mod n_pv via
  ``jax.lax.ppermute`` (the paper's pipelined send/recv; XLA's async
  collective-permute scheduler overlaps it with the mGEMM, replacing the
  paper's hand-rolled double buffering).
* Block-circulant schedule: rank row p_v computes block (p_v, p_v + d);
  the final step of an even ring is computed by the lower half only.
* "pr" round-robin: step d executes on ranks with d % n_pr == p_r under
  ``lax.cond`` (compute genuinely skipped, not masked).
* "pf" reduction: numerator partials are ``psum`` over "pf"; row-sum
  denominators are psummed once and ring-carried alongside V.

Bit-exactness contract (paper §5): with integer-valued inputs every
numerator is an exact fp integer regardless of summation order, so any
(n_pf, n_pv, n_pr) decomposition produces bit-identical metric values —
verified by checksum in tests/distributed_harness.py.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.compat import shard_map

from repro.core import checksum as ck
from repro.core.metric_spec import CZEKANOWSKI, MetricSpec
from repro.core.mgemm import get_impl
from repro.core.plan2 import TwoWayPlan, global_pairs_of_block

__all__ = [
    "CometConfig",
    "TwoWayOutput",
    "twoway_distributed",
    "czek2_distributed",
    "pad_vectors",
]


@dataclass(frozen=True)
class CometConfig:
    """Decomposition + implementation knobs (paper's n_pf / n_pv / n_pr / n_st)."""

    n_pf: int = 1
    n_pv: int = 1
    n_pr: int = 1
    n_st: int = 1  # 3-way staging
    impl: str = "xla"  # mgemm implementation registry key
    levels: int = 2  # for impl='levels*'
    out_dtype: str = "float32"
    # ring payload dtype (beyond-paper §Perf): int8 quarters the ICI wire
    # traffic of the V ring — EXACT for integer data with values <= 127
    # (SNP {0,1,2} codes); metric math still accumulates in fp32.
    ring_dtype: str = "float32"
    # contraction-axis chunk of the XLA mgemm (memory/speed trade-off)
    chunk: int = 128

    @property
    def n_ranks(self) -> int:
        return self.n_pf * self.n_pv * self.n_pr

    def impl_fn(self):
        fn = get_impl(self.impl)
        if self.impl.startswith("levels"):
            return partial(fn, levels=self.levels)
        if self.impl == "xla":
            return partial(fn, chunk=self.chunk)
        return fn


def pad_vectors(V: np.ndarray, cfg: CometConfig) -> np.ndarray:
    """Pad fields to n_pf multiple and vectors to n_pv multiple with zeros.

    Zero padding is inert: pad vectors produce zero numerators and are
    excluded by index bookkeeping on the host side."""
    n_f, n_v = V.shape
    fp = (-n_f) % cfg.n_pf
    vp = (-n_v) % cfg.n_pv
    if fp or vp:
        V = np.pad(V, ((0, fp), (0, vp)))
    return V


@dataclass
class TwoWayOutput:
    """Per-rank metric blocks + the metadata to read them."""

    blocks: np.ndarray  # (n_pv, n_pr, slots, m, m)
    plan: TwoWayPlan
    n_v: int  # true (unpadded) vector count
    n_vp: int  # padded block size

    def entries(self):
        """Yield (i, j, value) for every unique computed pair (i < j)."""
        n_pv, n_pr = self.plan.n_pv, self.plan.n_pr
        for p_v in range(n_pv):
            for p_r in range(n_pr):
                for d in self.plan.steps_of_pr(p_r):
                    if not self.plan.rank_computes(p_v, p_r, d):
                        continue
                    row, col = self.plan.block_of(p_v, d)
                    I, J, mask = global_pairs_of_block(row, col, self.n_vp)
                    mask = mask & (I < self.n_v) & (J < self.n_v)
                    vals = self.blocks[p_v, p_r, d // n_pr]
                    yield I[mask], J[mask], vals[mask]

    def dense(self) -> np.ndarray:
        """(n_v, n_v) symmetric metric matrix (tests / small problems)."""
        out = np.zeros((self.n_v, self.n_v), self.blocks.dtype)
        for I, J, V in self.entries():
            lo, hi = np.minimum(I, J), np.maximum(I, J)
            out[lo, hi] = V
            out[hi, lo] = V
        return out

    def checksum(self) -> int:
        return ck.combine([ck.raw_pairs(I, J, V) for I, J, V in self.entries()])

    def num_pairs(self) -> int:
        return sum(len(I) for I, _, _ in self.entries())


def _twoway_program(
    Vl, *, cfg: CometConfig, plan: TwoWayPlan, out_dtype, metric: MetricSpec = None
):
    """Per-device program (inside shard_map). Vl: (n_f/n_pf, n_vp)."""
    metric = metric or CZEKANOWSKI
    n_pv, n_pr = cfg.n_pv, cfg.n_pr
    m = Vl.shape[1]
    contract = metric.contract_fn(cfg)
    s_own = jax.lax.psum(metric.stat(Vl), "pf")  # (m,)
    pv = jax.lax.axis_index("pv")
    pr = jax.lax.axis_index("pr")
    # receive from upward neighbour: src (i+1) -> dst i
    perm = [((i + 1) % n_pv, i) for i in range(n_pv)]
    tri = jnp.triu(jnp.ones((m, m), bool), k=1)

    Vr, sr = Vl, s_own
    out = jnp.zeros((plan.slots_per_rank, m, m), out_dtype)
    for d in range(plan.n_steps):
        if d > 0:
            Vr = jax.lax.ppermute(Vr, "pv", perm)
            sr = jax.lax.ppermute(sr, "pv", perm)
        execute = (d % n_pr) == pr
        if plan.is_half_step(d):
            execute = jnp.logical_and(execute, pv < n_pv // 2)

        def compute(o, Vr=Vr, sr=sr, d=d):
            n2 = jax.lax.psum(contract(Vl.T, Vr).astype(jnp.float32), "pf")
            vals = metric.assemble2(n2, s_own[:, None], sr[None, :]).astype(out_dtype)
            if d == 0:
                vals = jnp.where(tri, vals, 0)
            return o.at[d // n_pr].set(vals)

        out = jax.lax.cond(execute, compute, lambda o: o, out)
    return out[None, None]  # leading (pv=1, pr=1) device dims


def twoway_distributed(
    V: np.ndarray, mesh: Mesh, cfg: CometConfig, metric: MetricSpec = None
) -> TwoWayOutput:
    """Compute all unique 2-way metrics of V's columns on the mesh."""
    metric = metric or CZEKANOWSKI
    n_v = V.shape[1]
    Vp = pad_vectors(np.asarray(V), cfg)
    n_vp = Vp.shape[1] // cfg.n_pv
    plan = TwoWayPlan(cfg.n_pv, cfg.n_pr)
    out_dtype = jnp.dtype(cfg.out_dtype)

    fn = shard_map(
        partial(_twoway_program, cfg=cfg, plan=plan, out_dtype=out_dtype,
                metric=metric),
        mesh=mesh,
        in_specs=P("pf", "pv"),
        out_specs=P("pv", "pr", None, None, None),
        check=False,
    )
    blocks = jax.jit(fn)(jnp.asarray(Vp, dtype=jnp.dtype(cfg.ring_dtype)))
    blocks = np.asarray(blocks).reshape(
        cfg.n_pv, cfg.n_pr, plan.slots_per_rank, n_vp, n_vp
    )
    return TwoWayOutput(blocks=blocks, plan=plan, n_v=n_v, n_vp=n_vp)


def czek2_distributed(V: np.ndarray, mesh: Mesh, cfg: CometConfig) -> TwoWayOutput:
    """Proportional Similarity 2-way campaign (pre-registry entry point)."""
    return twoway_distributed(V, mesh, cfg, metric=CZEKANOWSKI)
