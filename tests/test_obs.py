"""repro.obs: the tracing/metrics contract.

Pins the observability design constraints (docs/OBSERVABILITY.md):

* **Disabled is free** — ``span()`` returns ONE shared no-op singleton
  (no allocation), ``fence`` passes values through untouched, and a
  traced-then-untraced campaign is checksum **bit-identical** on both
  the streamed and the delta paths;
* spans nest through the contextvar stack and cross threads via
  ``copy_context`` — a ``ShardPrefetcher`` staging span and a
  ``SimilarityService`` worker span both record the submitting
  context's campaign span as their ``parent``;
* histogram percentiles are exact nearest-rank over the bounded window;
* every exported trace is valid Chrome trace-event JSON — property-
  tested over random span trees and cross-checked by the rejection
  cases ``validate_chrome_trace`` must catch;
* ``format_phase_table`` prints every canonical phase row even at count
  0 (the zero-encode proof for dataset campaigns is a ROW, not an
  absence), so CI can grep unconditionally.
"""
import os
import threading

import numpy as np
import pytest

try:  # property tests run under hypothesis when present (CI installs it);
    # a seeded deterministic sweep covers the same generator otherwise
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.api import InputSpec, SimilarityEngine, SimilarityRequest
from repro.core.synthetic import random_integer_vectors
from repro.obs import trace
from repro.obs.metrics import Histogram, MetricsRegistry
from repro.stream.prefetch import ShardPrefetcher
from repro.store import append_dataset, write_dataset


@pytest.fixture(autouse=True)
def _no_leaked_tracer():
    """Every test leaves the process untraced (disabled is the default)."""
    trace.disable()
    yield
    trace.disable()


# -- disabled mode: zero overhead --------------------------------------------


def test_disabled_span_is_shared_singleton():
    assert not trace.enabled()
    assert trace.get_tracer() is None
    s1, s2 = trace.span("a"), trace.span("b", {"k": 1})
    assert s1 is s2  # one process-wide null object, no allocation
    with s1 as sp:
        assert sp.add(bytes=10) is sp  # no-ops, chainable


def test_disabled_fence_is_identity():
    x = object()
    assert trace.fence(x) is x


def test_disabled_roofline_is_noop():
    trace.roofline_event(None, (), 1)  # would raise if it touched jitted


# -- enabled: nesting, attrs, aggregation ------------------------------------


def test_span_nesting_records_parent_path():
    t = trace.enable()
    with trace.span("campaign"):
        assert trace.current_path() == ("campaign",)
        with trace.span("ring-step") as sp:
            sp.add(steps=3)
    trace.disable()
    evs = t.events()
    kinds = [(ph, name) for ph, name, *_ in evs]
    assert kinds == [("B", "campaign"), ("B", "ring-step"),
                     ("E", "ring-step"), ("E", "campaign")]
    b_inner = evs[1]
    assert b_inner[4] == {"parent": "campaign"}
    e_inner = evs[2]
    assert e_inner[4] == {"steps": 3}
    agg = t.phase_stats()
    assert agg["ring-step"]["count"] == 1
    assert 0.0 <= agg["ring-step"]["seconds"] <= agg["campaign"]["seconds"]


def test_complete_virtual_lane_keeps_nesting_wellformed():
    """An externally measured interval overlapping the thread's own spans
    goes on a virtual tid lane — the exported trace still validates."""
    t = trace.enable()
    with trace.span("serve-compute"):
        now = t._clock()
        t.complete("serve-queue-wait", now - 5_000_000, now,
                   {"wait_seconds": 0.005}, tid=0)
    trace.disable()
    assert trace.validate_chrome_trace(t.chrome_trace()) == 4
    waits = [e for e in t.events() if e[1] == "serve-queue-wait"]
    assert {e[3] for e in waits} == {0}


def test_prefetcher_spans_nest_under_campaign_across_threads():
    t = trace.enable()
    buffers = [np.zeros(4, np.uint8) for _ in range(2)]
    seen_tids = set()

    def fill(idx, buf):
        buf[:] = idx
        seen_tids.add(threading.get_ident())

    with trace.span("campaign"):
        # prefetcher constructed INSIDE the span: copy_context carries it
        with ShardPrefetcher(fill, 3, buffers) as pf:
            for idx, buf in pf:
                assert buf[0] == idx
                pf.release(buf)
    trace.disable()
    assert seen_tids and threading.get_ident() not in seen_tids
    stages = [e for e in t.events() if e[0] == "B" and e[1] == "prefetch-stage"]
    assert len(stages) == 3
    assert all(e[4] == {"parent": "campaign"} for e in stages)
    assert trace.validate_chrome_trace(t.chrome_trace()) == t.event_count()


def test_service_worker_spans_carry_submitter_context():
    from repro.serve.engine import SimilarityService

    V = random_integer_vectors(24, 10, max_value=2, seed=0)
    t = trace.enable()
    with trace.span("client"):
        with SimilarityService() as svc:
            svc.submit(SimilarityRequest(way=2, metric="czekanowski"), V)
    trace.disable()
    names = {e[1] for e in t.events()}
    assert {"serve-queue-wait", "serve-compute", "campaign"} <= names
    b_compute = next(e for e in t.events()
                     if e[0] == "B" and e[1] == "serve-compute")
    assert b_compute[4] == {"parent": "client"}
    assert trace.validate_chrome_trace(t.chrome_trace()) == t.event_count()


# -- metrics registry ---------------------------------------------------------


def test_histogram_nearest_rank_percentiles():
    h = Histogram(threading.RLock())
    for v in range(1, 101):
        h.observe(float(v))
    assert h.percentile(50) == 50.0
    assert h.percentile(90) == 90.0
    assert h.percentile(99) == 99.0
    assert h.percentile(100) == 100.0
    snap = h.snapshot()
    assert snap["count"] == 100 and snap["mean"] == 50.5
    assert snap["p50"] == 50.0 and snap["max"] == 100.0


def test_histogram_empty_and_bounded_window():
    h = Histogram(threading.RLock(), max_samples=4)
    assert h.percentile(50) == 0.0 and h.snapshot()["p99"] == 0.0
    for v in (1.0, 2.0, 3.0, 4.0, 5.0, 6.0):
        h.observe(v)
    # count/sum see everything; the window retains the most recent 4
    assert h.count == 6 and h.sum == 21.0
    assert h.percentile(100) == 6.0 and h.percentile(1) == 3.0


def test_registry_single_lock_and_type_guard():
    reg = MetricsRegistry()
    c = reg.counter("hits")
    assert reg.counter("hits") is c
    with pytest.raises(TypeError, match="Counter"):
        reg.gauge("hits")
    with reg.locked():
        c.inc()  # RLock: metric ops re-enter under the held registry lock
        reg.gauge("depth").inc(2)
    assert reg.snapshot() == {"hits": 1, "depth": 2.0}


# -- Chrome trace format: property test + rejection cases ---------------------

_SPAN_NAMES = ("encode", "ring-step", "merge", "x")


def _emit(node):
    if isinstance(node, str):
        with trace.span(node):
            pass
    else:
        name, kids = node
        with trace.span(name):
            for k in kids:
                _emit(k)


def _random_tree(rng, depth=0):
    name = _SPAN_NAMES[rng.integers(len(_SPAN_NAMES))]
    if depth >= 3 or rng.random() < 0.4:
        return name
    return (name, [_random_tree(rng, depth + 1)
                   for _ in range(rng.integers(0, 4))])


def _check_forest(forest):
    t = trace.enable()
    for node in forest:
        _emit(node)
    ts = t._clock()
    t.complete("roofline", ts, ts, {"bound_seconds": 0.0})
    trace.disable()
    payload = t.chrome_trace()
    assert trace.validate_chrome_trace(payload) == t.event_count()
    assert all(ev["ts"] >= 0.0 for ev in payload["traceEvents"])


if HAVE_HYPOTHESIS:
    _NAMES = st.sampled_from(_SPAN_NAMES)
    _TREES = st.recursive(
        _NAMES, lambda kids: st.tuples(_NAMES, st.lists(kids, max_size=3)),
        max_leaves=12,
    )

    @settings(max_examples=40, deadline=None)
    @given(st.lists(_TREES, max_size=4))
    def test_random_span_trees_export_valid_chrome_traces(forest):
        _check_forest(forest)
else:
    def test_random_span_trees_export_valid_chrome_traces():
        for seed in range(40):
            rng = np.random.default_rng(seed)
            _check_forest([_random_tree(rng)
                           for _ in range(rng.integers(0, 5))])


def test_validator_rejections():
    pid, tid = 1, 1

    def ev(ph, name, ts):
        return {"name": name, "ph": ph, "ts": ts, "pid": pid, "tid": tid}

    with pytest.raises(ValueError, match="traceEvents"):
        trace.validate_chrome_trace(["not", "a", "dict"])
    with pytest.raises(ValueError, match="missing field 'tid'"):
        trace.validate_chrome_trace(
            {"traceEvents": [{"name": "a", "ph": "B", "ts": 0, "pid": 1}]}
        )
    with pytest.raises(ValueError, match="monotonic"):
        trace.validate_chrome_trace(
            {"traceEvents": [ev("B", "a", 5.0), ev("E", "a", 1.0)]}
        )
    with pytest.raises(ValueError, match="does not match"):
        trace.validate_chrome_trace(
            {"traceEvents": [ev("B", "a", 0.0), ev("E", "b", 1.0)]}
        )
    with pytest.raises(ValueError, match="unclosed"):
        trace.validate_chrome_trace({"traceEvents": [ev("B", "a", 0.0)]})
    with pytest.raises(ValueError, match="not 'B'/'E'"):
        trace.validate_chrome_trace({"traceEvents": [ev("X", "a", 0.0)]})
    assert trace.validate_chrome_trace({"traceEvents": []}) == 0


# -- phase table --------------------------------------------------------------


def test_phase_table_always_prints_canonical_rows():
    table = trace.format_phase_table({})
    lines = table.splitlines()
    assert lines[0].split() == ["phase", "count", "seconds", "share"]
    for name in trace.CANONICAL_PHASES:
        assert any(ln.startswith(name + " ") for ln in lines[1:]), name
    # recorded extras appear; roofline never does
    table = trace.format_phase_table({
        "roofline": {"count": 2, "seconds": 0.0},
        "campaign": {"count": 1, "seconds": 2.0},
        "ring-step": {"count": 4, "seconds": 1.0},
    })
    assert "campaign" in table and "roofline" not in table
    row = next(ln for ln in table.splitlines() if ln.startswith("ring-step"))
    assert row.split() == ["ring-step", "4", "1.000000", "33.3%"]


# -- bit-identity: tracing must not change results ----------------------------


def _streamed_request(path):
    return SimilarityRequest(
        way=2, metric="czekanowski", impl="levels", levels=2,
        streaming="on", max_host_bytes=400,
        input=InputSpec(source="planes", path=path),
    )


def test_traced_streamed_campaign_is_bit_identical(tmp_path):
    path = os.path.join(str(tmp_path), "ds")
    write_dataset(path, random_integer_vectors(64, 20, max_value=2, seed=7),
                  levels=2, n_shards=2)
    engine = SimilarityEngine()
    plain = engine.run(_streamed_request(path))

    t = trace.enable()
    traced = engine.run(_streamed_request(path))
    trace.disable()

    assert traced.checksum() == plain.checksum()
    # untraced results still carry the normalized obs block...
    obs_plain = plain.meta["obs"]
    assert obs_plain["comparisons"] > 0 and "phases" not in obs_plain
    # ...and always-on overlap accounting
    assert plain.meta["stream"]["stall_seconds"] >= 0.0
    assert plain.meta["stream"]["compute_seconds"] > 0.0
    # traced run: per-phase breakdown + roofline-bound utilization
    obs_traced = traced.meta["obs"]
    phases = obs_traced["phases"]
    assert phases["ring-step"]["count"] == plain.meta["stream"]["chunks"]
    assert phases["prefetch-stage"]["count"] == phases["ring-step"]["count"]
    assert phases["merge"]["count"] == 1 and "encode" not in phases
    assert obs_traced["bound_seconds"] > 0.0
    assert obs_traced["utilization"] > 0.0
    assert trace.validate_chrome_trace(t.chrome_trace()) == t.event_count()


def test_traced_delta_campaign_is_bit_identical(tmp_path):
    path = os.path.join(str(tmp_path), "ds")
    V0 = random_integer_vectors(32, 12, max_value=2, seed=8)
    Vn = random_integer_vectors(32, 5, max_value=2, seed=9)
    write_dataset(path, V0, levels=2, n_shards=1)
    base = dict(way=2, metric="czekanowski", impl="levels", levels=2)
    engine = SimilarityEngine()
    req = SimilarityRequest(**base, input=InputSpec(source="planes",
                                                    path=path))
    prior = engine.run(req)
    append_dataset(path, Vn)

    plain = engine.run_delta(req, prior)

    t = trace.enable()
    traced = engine.run_delta(req, prior)
    trace.disable()

    assert traced.checksum() == plain.checksum()
    phases = traced.meta["obs"]["phases"]
    assert phases["delta-border"]["count"] == 1
    assert phases["merge"]["count"] == 1
    assert "ring-step" not in phases  # delta campaigns have no ring
    # border-proportional comparisons, not N^2
    d = traced.meta["delta"]
    assert traced.meta["obs"]["comparisons"] == d["computed_entries"] * 32
    assert trace.validate_chrome_trace(t.chrome_trace()) == t.event_count()
