"""Subprocess scaling harness (Figs 6-10): runs strong/weak scaling sweeps
over virtual CPU devices and emits JSON.  Invoked by bench_scaling.py so the
main benchmark process keeps the default single device.
"""
import json
import os
import sys

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)

import time  # noqa: E402

import numpy as np  # noqa: E402


_ENGINE = None


def measure(way, n_f, n_v, n_pv, n_pr=1, n_st=1):
    from repro.api import SimilarityEngine, SimilarityRequest
    from repro.core.synthetic import random_integer_vectors

    global _ENGINE
    if _ENGINE is None:
        _ENGINE = SimilarityEngine()  # mesh cache shared across the sweep

    V = random_integer_vectors(n_f, n_v, seed=0)
    req = SimilarityRequest(
        way=way, n_pv=n_pv, n_pr=n_pr, n_st=n_st,
        stages=(0,) if way == 3 else None,
    )
    _ENGINE.run(req, V)  # warmup/compile
    t0 = time.perf_counter()
    out = _ENGINE.run(req, V)
    dt = time.perf_counter() - t0
    n_results = out.num_results()
    return {
        "way": way, "n_f": n_f, "n_v": n_v, "n_pv": n_pv, "n_pr": n_pr,
        "seconds": dt, "results": n_results,
        "comparisons": n_results * n_f,
        "rate": n_results * n_f / dt,
        "rate_per_rank": n_results * n_f / dt / (n_pv * n_pr),
    }


def main():
    results = {"strong_2way": [], "strong_3way": [], "weak_2way": [], "weak_3way": []}
    # Fig 6 analog: strong scaling, fixed problem
    for n_pv in (1, 2, 4, 8):
        results["strong_2way"].append(measure(2, 512, 1024, n_pv))
    for n_pv in (1, 2, 4):
        results["strong_3way"].append(measure(3, 64, 96, n_pv))
    # Figs 7-10 analog: weak scaling, fixed per-rank work
    for n_pv in (1, 2, 4, 8):
        results["weak_2way"].append(measure(2, 512, 512 * n_pv, n_pv))
    for n_pv in (1, 2, 4):
        results["weak_3way"].append(measure(3, 64, 48 * n_pv, n_pv))
    print(json.dumps(results))
    return 0


if __name__ == "__main__":
    sys.exit(main())
