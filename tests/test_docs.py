"""Docs gate: markdown cross-references must resolve, and the documented
entry points the docs name must actually exist.

Scans README.md, docs/*.md and results/README.md for relative markdown
links and asserts every target exists (so docs/BITPLANE_FORMAT.md and
docs/ARCHITECTURE.md cross-references can't rot).  Also pins the
README -> docs links the PR-4 acceptance criteria require, and checks
that code identifiers the format spec declares as producers/consumers are
importable.  CI runs this alongside ``pytest --doctest-modules`` over
``planes.py`` as the docs step.
"""
import os
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_LINK = re.compile(r"(?<!!)\[[^\]]+\]\(([^)\s]+)\)")  # [text](target), not images


def _doc_files():
    files = [os.path.join(REPO, "README.md"),
             os.path.join(REPO, "results", "README.md")]
    docs = os.path.join(REPO, "docs")
    for name in sorted(os.listdir(docs)):
        if name.endswith(".md"):
            files.append(os.path.join(docs, name))
    return files


def _relative_links(path):
    with open(path) as f:
        text = f.read()
    # strip fenced code blocks: bash snippets aren't hyperlinks
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        yield target.split("#", 1)[0]


@pytest.mark.parametrize("doc", _doc_files(),
                         ids=lambda p: os.path.relpath(p, REPO))
def test_markdown_relative_links_resolve(doc):
    base = os.path.dirname(doc)
    missing = [t for t in _relative_links(doc)
               if not os.path.exists(os.path.join(base, t))]
    assert not missing, f"{os.path.relpath(doc, REPO)} has dead links: {missing}"


def test_readme_links_required_docs():
    """The acceptance criteria: both specs exist AND are linked from README."""
    with open(os.path.join(REPO, "README.md")) as f:
        readme = f.read()
    for target in ("docs/ARCHITECTURE.md", "docs/BITPLANE_FORMAT.md"):
        assert os.path.exists(os.path.join(REPO, target)), target
        assert target in readme, f"README does not link {target}"


def test_format_spec_names_real_code():
    """docs/BITPLANE_FORMAT.md's producer/consumer table must not rot."""
    from repro.core.threeway import _threeway_program  # noqa: F401
    from repro.core.twoway import _twoway_program  # noqa: F401
    from repro.kernels.czek3.kernel import threeway_batch_levels_pallas  # noqa: F401
    from repro.kernels.mgemm_levels import (  # noqa: F401
        PackedPlanes,
        decode_bitplanes,
        encode_bitplanes,
        encode_bitplanes_np,
        pad_planes,
        shard_planes_fields,
        slice_planes_vectors,
        values_from_planes,
    )
    from repro.kernels.mgemm_levels.kernel import (  # noqa: F401
        _plane_matmuls,
        _unpack_plane_tile,
    )
    # the binary fast path the format spec's "Binary fast path" note names
    from repro.kernels.mgemm_levels import POPCOUNT  # noqa: F401
    from repro.kernels.popgemm import (  # noqa: F401
        metric2_pop,
        pop_planes,
        threeway_batch_pop,
    )
    from repro.kernels.popgemm.kernel import (  # noqa: F401
        _pack_words,
        _pop_contract,
    )


def test_store_spec_names_real_code():
    """The "On-disk storage" chapter's named entry points must exist, and
    the spec constants it documents must match the code."""
    from repro.store import (  # noqa: F401
        DatasetReader,
        FORMAT_NAME,
        FORMAT_VERSION,
        MANIFEST_NAME,
        bed_paths,
        read_bed,
        read_manifest,
        validate_leveled,
        write_dataset,
    )

    assert FORMAT_NAME == "repro-bitplane-dataset"
    assert MANIFEST_NAME == "dataset.json"
    # the dataset CLI the README quickstart drives
    from repro.launch.dataset import main  # noqa: F401

    with open(os.path.join(REPO, "docs", "BITPLANE_FORMAT.md")) as f:
        spec = f.read()
    for name in ("On-disk storage", "dataset.json", "stats.npy",
                 "shard_planes_fields", "pad_planes", "sha256",
                 "Missing-genotype"):
        assert name in spec, f"BITPLANE_FORMAT.md lost its {name!r} section"
    with open(os.path.join(REPO, "README.md")) as f:
        readme = f.read()
    assert "repro.launch.dataset" in readme, "README lost the dataset quickstart"
    assert "--dataset" in readme


def test_append_delta_docs_name_real_code():
    """The "Append & delta" chapter (BITPLANE_FORMAT.md) and the serving /
    delta sections (ARCHITECTURE.md) must name code that exists."""
    from repro.api.engine import SimilarityEngine
    from repro.core.delta import (  # noqa: F401
        delta_accounting,
        merge_delta,
        packed_upper_index,
        twoway_delta,
    )
    from repro.core.twoway import _cached_jit  # noqa: F401
    from repro.serve.engine import SimilarityService, _payload_hash  # noqa: F401
    from repro.store import append_dataset  # noqa: F401
    from repro.stream import stream_twoway_delta  # noqa: F401

    assert hasattr(SimilarityEngine, "run_delta")
    for attr in ("submit_async", "submit", "warmup", "shutdown"):
        assert hasattr(SimilarityService, attr), attr

    with open(os.path.join(REPO, "docs", "BITPLANE_FORMAT.md")) as f:
        spec = f.read()
    for name in ("Append & delta", "append_dataset", "dataset_version",
                 "parent", "merge_delta", "packed_upper_index",
                 "ring_payload_bytes = 0"):
        assert name in spec, f"BITPLANE_FORMAT.md lost its {name!r} mention"
    with open(os.path.join(REPO, "docs", "ARCHITECTURE.md")) as f:
        arch = f.read()
    for name in ("Delta campaigns", "Serving layer", "SimilarityService",
                 "submit_async", "run_delta", "delta_from", "warmup",
                 "delta_hits", "stream_twoway_delta"):
        assert name in arch, f"ARCHITECTURE.md lost its {name!r} mention"
    with open(os.path.join(REPO, "README.md")) as f:
        readme = f.read()
    assert "--delta-from" in readme, "README lost the delta quickstart"
    assert "append" in readme


def test_architecture_path_matrix_matches_executor():
    """The fallback matrix documented in docs/ARCHITECTURE.md is the one
    the executor implements (spot-check the load-bearing rows)."""
    from repro.core.tile_executor import TileExecutor
    from repro.core.twoway import CometConfig

    rows3 = {  # (impl, encoding) -> documented path3
        ("levels", "bitplane"): "fused-levels-ring",
        ("levels", "none"): "fused-levels",
        ("pallas", "none"): "fused-vpu",
        ("levels_xla", "bitplane"): "unfused",
        ("xla", "none"): "unfused",
    }
    for (impl, enc), want in rows3.items():
        ex = TileExecutor(cfg=CometConfig(impl=impl, encoding=enc))
        assert ex.path3 == want, (impl, enc, ex.path3)
    # n_pf > 1 keeps the fused MXU path: raw in-kernel partials, psummed
    # over "pf", assembled by the merge epilogue out of kernel
    ex = TileExecutor(cfg=CometConfig(impl="levels", n_pf=2))
    assert ex.path == "fused-levels" and "merge epilogue" in ex.path_reason
    # streamed campaigns defer every flush to the cross-shard merge
    ex = TileExecutor(cfg=CometConfig(impl="levels", encoding="bitplane"),
                      deferred=True)
    assert ex.path == "streamed-fused-levels"
    assert ex.path3 == "streamed-fused-levels-ring"
    # binary fast path: levels == 1 swaps the plane-dot kernels for the
    # popcount bit-GEMM at every decision site (same conditions otherwise)
    ex = TileExecutor(cfg=CometConfig(impl="levels", levels=1,
                                      encoding="bitplane"))
    assert ex.path == "fused-popcount"
    assert ex.path3 == "fused-popcount-ring"
    ex = TileExecutor(cfg=CometConfig(impl="levels", levels=1,
                                      encoding="none"))
    assert ex.path3 == "fused-popcount"
    ex = TileExecutor(cfg=CometConfig(impl="levels", levels=1, n_pf=2))
    assert ex.path == "fused-popcount" and "merge epilogue" in ex.path_reason
    ex = TileExecutor(cfg=CometConfig(impl="levels", levels=1,
                                      encoding="bitplane"), deferred=True)
    assert ex.path == "streamed-fused-popcount"
    assert ex.path3 == "streamed-fused-popcount-ring"
    # levels_xla keeps the unfused plane contraction even for binary data
    ex = TileExecutor(cfg=CometConfig(impl="levels_xla", levels=1,
                                      encoding="bitplane"))
    assert ex.path == "unfused" and ex.path3 == "unfused"


# -- the result meta schema gate ---------------------------------------------


def _parse_meta_schema():
    """Parse the "## Result `meta` schema" bullets into
    ``{block: (required, optional)}`` key sets."""
    with open(os.path.join(REPO, "docs", "ARCHITECTURE.md")) as f:
        arch = f.read()
    assert "## Result `meta` schema" in arch, \
        "ARCHITECTURE.md lost the meta schema section"
    sec = arch.split("## Result `meta` schema", 1)[1].split("\n## ", 1)[0]
    blocks = {}
    for m in re.finditer(
        r"- `(\w+)` \([^)]*\): required\s+([^;.]*)(?:;\s*optional\s+([^.]*))?\.",
        sec, flags=re.S,
    ):
        name, req, opt = m.group(1), m.group(2), m.group(3) or ""
        blocks[name] = (set(re.findall(r"`(\w+)`", req)),
                        set(re.findall(r"`(\w+)`", opt)))
    return blocks


def _assert_meta_documented(meta, blocks, where):
    undocumented = set(meta) - set(blocks)
    assert not undocumented, f"{where}: undocumented meta blocks {undocumented}"
    for key, block in meta.items():
        required, optional = blocks[key]
        got = set(block)
        missing = required - got
        assert not missing, f"{where}: meta[{key!r}] missing required {missing}"
        extra = got - required - optional
        assert not extra, f"{where}: meta[{key!r}] emits undocumented {extra}"


def test_meta_schema_matches_emitted(tmp_path):
    """The documented schema IS what real campaigns emit: every block a
    campaign attaches is documented, required keys are always present,
    and no campaign emits a key the docs don't list — checked across the
    in-memory, streamed, delta, batched, and traced forms."""
    from repro.api import InputSpec, SimilarityEngine, SimilarityRequest
    from repro.core.synthetic import random_integer_vectors
    from repro.obs import trace
    from repro.store import append_dataset, write_dataset

    blocks = _parse_meta_schema()
    assert set(blocks) == {"obs", "dataset", "stream", "delta", "batch"}

    engine = SimilarityEngine()
    V = random_integer_vectors(32, 10, max_value=2, seed=1)
    path = os.path.join(str(tmp_path), "ds")
    write_dataset(path, V, levels=2, n_shards=2)
    sreq = SimilarityRequest(
        way=2, metric="czekanowski", impl="levels", levels=2,
        streaming="on", max_host_bytes=400,
        input=InputSpec(source="planes", path=path),
    )

    plain = engine.run(SimilarityRequest(way=2, metric="czekanowski"), V)
    assert set(plain.meta) == {"obs"}
    _assert_meta_documented(plain.meta, blocks, "in-memory")

    streamed = engine.run(sreq)
    assert {"obs", "dataset", "stream"} <= set(streamed.meta)
    _assert_meta_documented(streamed.meta, blocks, "streamed")

    append_dataset(path, random_integer_vectors(32, 4, max_value=2, seed=2))
    delta = engine.run_delta(sreq, streamed)
    assert "delta" in delta.meta
    _assert_meta_documented(delta.meta, blocks, "delta")

    trace.enable()
    try:
        batched = engine.run(SimilarityRequest(
            way=2, metric="czekanowski", metrics=("sorenson",),
            impl="levels", levels=2, encoding="bitplane"), V)
    finally:
        trace.disable()
    assert "batch" in batched.meta
    # the traced run exercises the OPTIONAL obs keys (phases, bound, ...)
    assert "phases" in batched.meta["obs"]
    _assert_meta_documented(batched.meta, blocks, "batched+traced")
    for mname, sname, res in batched.campaigns:
        _assert_meta_documented(res.meta, blocks, f"campaign {mname}/{sname}")


def test_observability_docs_name_real_code():
    """docs/OBSERVABILITY.md exists, is linked from README, and the API +
    CLI flags it documents are real."""
    from repro.obs import metrics as obs_metrics
    from repro.obs import trace as obs_trace

    for name in ("enable", "disable", "enabled", "span", "fence",
                 "roofline_event", "format_phase_table",
                 "validate_chrome_trace", "CANONICAL_PHASES", "Tracer"):
        assert hasattr(obs_trace, name), name
    for name in ("Counter", "Gauge", "Histogram", "MetricsRegistry",
                 "default_registry"):
        assert hasattr(obs_metrics, name), name
    from repro.serve.engine import SimilarityService
    for attr in ("stats", "metrics"):
        assert hasattr(SimilarityService, attr), attr

    with open(os.path.join(REPO, "README.md")) as f:
        readme = f.read()
    assert "docs/OBSERVABILITY.md" in readme, "README does not link the doc"
    assert "--trace" in readme
    with open(os.path.join(REPO, "docs", "OBSERVABILITY.md")) as f:
        doc = f.read()
    for name in ("--trace", "--metrics-json", "prefetch-stage", "ring-step",
                 "validate_chrome_trace", "bound_seconds", "utilization",
                 "stall_seconds", "MetricsRegistry"):
        assert name in doc, f"OBSERVABILITY.md lost its {name!r} mention"
    # the CLI flags the doc quotes exist in the launchers' parsers
    with open(os.path.join(REPO, "src", "repro", "launch",
                           "similarity.py")) as f:
        assert "--trace" in f.read()
    with open(os.path.join(REPO, "src", "repro", "launch", "serve.py")) as f:
        assert "--metrics-json" in f.read()
