from .ops import czek3_step, threeway_batch, threeway_step  # noqa: F401
from .ref import czek3_step_ref  # noqa: F401
