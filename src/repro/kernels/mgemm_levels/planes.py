"""Bit-plane encoding for the level-decomposition path.

For integer data quantized to levels {0, 1, ..., L} the indicator planes
``plane_t = 1[V >= t]`` (t = 1..L) fully describe V: each plane is one bit
per element and ``V = sum_t plane_t``.  This module packs the planes along
the *field* (contraction) axis, 8 plane-bits per byte, LSB-first — byte r
of a plane covers fields ``8r .. 8r+7`` with bit j holding field ``8r+j``.

Why pack: the packed representation is what the distributed engines
ring-carry and what the fused MXU kernels consume.  For SNP {0,1,2} data
(L=2) the packed planes are ``2 * n_f/8`` bytes per vector vs ``4 * n_f``
for the fp32 ring payload — 16x less ICI wire traffic and HBM read volume —
and encoding happens ONCE per campaign instead of ``(V >= t)`` being
recomputed from fp32 data at every ring step.

All zero-padding is inert: a zero field has bit 0 in every plane, so it
contributes nothing to any plane GEMM, exactly like the engines' existing
zero-padding of V.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = [
    "encode_bitplanes",
    "encode_bitplanes_np",
    "decode_bitplanes",
    "values_from_planes",
    "planes_nbytes",
]


def encode_bitplanes_np(V, levels: int, *, field_align: int = 1) -> np.ndarray:
    """Host-side packer: (k, n) leveled values -> (levels, kb, n) uint8.

    ``field_align``: pad the field count to a multiple of ``8 * field_align``
    so the *byte* axis splits evenly over ``field_align`` ranks (the "pf"
    sharding of the packed ring payload).
    """
    V = np.asarray(V)
    k, n = V.shape
    kp = (-k) % (8 * max(1, field_align))
    if kp:
        V = np.pad(V, ((0, kp), (0, 0)))
    thresholds = np.arange(1, levels + 1).reshape(-1, 1, 1).astype(V.dtype)
    planes = V[None, :, :] >= thresholds  # (levels, K, n) bool
    return np.packbits(planes, axis=1, bitorder="little")


def encode_bitplanes(V, levels: int):
    """jnp packer (jit-composable): (k, n) -> (levels, ceil(k/8), n) uint8."""
    V = jnp.asarray(V)
    k, n = V.shape
    kp = (-k) % 8
    if kp:
        V = jnp.pad(V, ((0, kp), (0, 0)))
    K = k + kp
    thresholds = jnp.arange(1, levels + 1, dtype=jnp.int32).astype(V.dtype)
    planes = (V[None] >= thresholds[:, None, None]).astype(jnp.int32)
    shifts = jnp.arange(8, dtype=jnp.int32).reshape(1, 1, 8, 1)
    packed = (planes.reshape(levels, K // 8, 8, n) << shifts).sum(axis=2)
    return packed.astype(jnp.uint8)


def decode_bitplanes(P):
    """(levels, kb, n) uint8 -> (levels, 8*kb, n) int32 {0, 1} planes."""
    P = jnp.asarray(P)
    levels, kb, n = P.shape
    shifts = jnp.arange(8, dtype=jnp.int32).reshape(1, 1, 8, 1)
    bits = (P.astype(jnp.int32)[:, :, None, :] >> shifts) & 1
    return bits.reshape(levels, kb * 8, n)


def values_from_planes(P, dtype=jnp.float32):
    """Exact value reconstruction V = sum_t plane_t for leveled data.

    Returns (8*kb, n); rows past the true field count are the zero padding.
    """
    return decode_bitplanes(P).sum(axis=0).astype(dtype)


def planes_nbytes(n_f: int, n_v: int, levels: int) -> int:
    """Packed payload size — the ring-traffic accounting used in docs/bench."""
    return levels * (-(-n_f // 8)) * n_v
