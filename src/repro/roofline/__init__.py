from repro.roofline.analysis import HW_V5E, analyze_compiled  # noqa: F401
