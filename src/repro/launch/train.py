"""Training launcher.

    python -m repro.launch.train --arch qwen1.5-0.5b --smoke --steps 50

``--smoke`` selects the reduced config (CPU-runnable); without it the exact
assigned config is used (pod-scale — pair with a real TPU mesh).  Supports
restart (picks up the latest checkpoint), elastic mesh reshape, and the
straggler watchdog.
"""
import argparse
import json
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs.registry import get_config, get_smoke_config
    from repro.models.common import param_count
    from repro.optim.adamw import AdamWConfig
    from repro.optim.schedule import warmup_cosine
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tcfg = TrainerConfig(
        steps=args.steps, ckpt_every=args.ckpt_every, log_every=args.log_every,
        ckpt_dir=args.ckpt_dir, seed=args.seed, batch=args.batch,
        seq_len=args.seq_len,
    )
    opt = AdamWConfig(lr=args.lr, schedule=warmup_cosine(args.steps // 10, args.steps))
    trainer = Trainer(cfg, tcfg, opt)
    state = trainer.resume_or_init()
    n = param_count(state.params)
    print(f"arch={cfg.name} params={n / 1e6:.1f}M resume_step={state.step}")
    state = trainer.train(state)
    for h in trainer.history:
        print(json.dumps(h))
    print(f"done @ step {state.step}; stragglers={len(trainer.watchdog.events)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
