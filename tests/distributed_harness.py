"""Multi-device validation harness (run as a subprocess with 8 CPU devices).

Reproduces the paper's §5 validation: identical synthetic input, many
parallel decompositions (n_pf, n_pv, n_pr, n_st), and asserts

  1. every decomposition computes exactly the unique result set,
  2. values are BIT-FOR-BIT identical across decompositions (exact integer
     inputs => exact numerators => identical IEEE divisions),
  3. values match the O(n^2)/O(n^3) numpy oracles.

Invoked by tests/test_distributed.py; standalone: python distributed_harness.py
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)

import numpy as np  # noqa: E402

from repro.core.metrics import czek2_metric_np, czek3_metric_np  # noqa: E402
from repro.core.synthetic import random_integer_vectors  # noqa: E402
from repro.core.threeway import czek3_distributed  # noqa: E402
from repro.core.twoway import CometConfig, czek2_distributed  # noqa: E402
from repro.core import checksum as ck  # noqa: E402
from repro.parallel.mesh import make_comet_mesh  # noqa: E402

N_F, N_V = 24, 24


def check_2way(V, ref_dense):
    ref_checksum = None
    configs = [
        (1, 1, 1),
        (1, 2, 1),
        (1, 4, 1),
        (1, 8, 1),
        (2, 2, 1),
        (1, 2, 2),
        (2, 2, 2),
        (1, 4, 2),
        (4, 2, 1),
    ]
    for n_pf, n_pv, n_pr in configs:
        cfg = CometConfig(n_pf=n_pf, n_pv=n_pv, n_pr=n_pr)
        mesh = make_comet_mesh(n_pf, n_pv, n_pr)
        out = czek2_distributed(V, mesh, cfg)
        assert out.num_pairs() == N_V * (N_V - 1) // 2, (
            f"2way {cfg}: {out.num_pairs()} pairs"
        )
        d = out.dense()
        iu = np.triu_indices(N_V, 1)
        np.testing.assert_allclose(d[iu], ref_dense[iu], rtol=1e-6,
                                   err_msg=f"2way {cfg} vs oracle")
        c = out.checksum()
        if ref_checksum is None:
            ref_checksum = c
        assert c == ref_checksum, f"2way checksum mismatch for {cfg}"
        print(f"  2way pf={n_pf} pv={n_pv} pr={n_pr}: OK ({hex(c)[:14]})")
    # pallas fused-epilogue path inside the distributed engine (interpret
    # mode): in-kernel assembly + triangular diagonal-block schedule must be
    # bit-identical to the XLA out-of-kernel path
    for n_pf, n_pv, n_pr in [(1, 2, 1), (1, 4, 1), (1, 2, 2)]:
        cfg = CometConfig(n_pf=n_pf, n_pv=n_pv, n_pr=n_pr, impl="pallas")
        out = czek2_distributed(V, make_comet_mesh(n_pf, n_pv, n_pr), cfg)
        assert out.checksum() == ref_checksum, (
            f"pallas impl changed results ({n_pf},{n_pv},{n_pr})"
        )
        print(f"  2way pallas impl pv={n_pv} pr={n_pr}: OK")
    # packed upper-triangular storage: same entries, same checksum
    packed = out.pack()
    assert packed.storage == "packed"
    assert packed.checksum() == ref_checksum, "packing changed results"
    print("  2way packed storage: OK")
    # levels impl is exact for small-integer data
    cfg = CometConfig(n_pf=1, n_pv=2, n_pr=1, impl="levels_xla", levels=15)
    out = czek2_distributed(V, make_comet_mesh(1, 2, 1), cfg)
    assert out.checksum() == ref_checksum, "levels impl not bit-exact"
    print("  2way levels impl: OK")
    # fused-levels campaign path: packed bit-planes encoded once, ring-
    # carried, MXU plane kernels with in-kernel epilogue + triangular
    # diagonal schedule; n_pf=2 keeps the fused MXU kernels but emits raw
    # psummed partials assembled by the out-of-kernel merge epilogue.
    # All bit-identical to the xla reference.
    for n_pf, n_pv, n_pr in [(1, 2, 1), (1, 4, 1), (1, 2, 2), (2, 2, 1)]:
        cfg = CometConfig(n_pf=n_pf, n_pv=n_pv, n_pr=n_pr, impl="levels",
                          levels=15)
        out = czek2_distributed(V, make_comet_mesh(n_pf, n_pv, n_pr), cfg)
        assert out.checksum() == ref_checksum, (
            f"fused-levels changed results ({n_pf},{n_pv},{n_pr})"
        )
        print(f"  2way fused-levels pf={n_pf} pv={n_pv} pr={n_pr}: OK")


def check_3way(V, ref_dense):
    ref_checksum = None
    configs = [  # (n_pf, n_pv, n_pr, n_st)
        (1, 1, 1, 1),
        (1, 2, 1, 1),
        (1, 4, 1, 1),
        (2, 2, 1, 1),
        (1, 2, 2, 1),
        (1, 2, 4, 1),
        (2, 2, 2, 1),
    ]
    n_unique = N_V * (N_V - 1) * (N_V - 2) // 6
    for n_pf, n_pv, n_pr, n_st in configs:
        cfg = CometConfig(n_pf=n_pf, n_pv=n_pv, n_pr=n_pr, n_st=n_st)
        mesh = make_comet_mesh(n_pf, n_pv, n_pr)
        out = czek3_distributed(V, mesh, cfg, stage=0)
        assert out.num_triples() == n_unique, (
            f"3way {cfg}: {out.num_triples()} != {n_unique}"
        )
        d = out.dense()
        errs = []
        for i in range(N_V):
            for j in range(i + 1, N_V):
                for k in range(j + 1, N_V):
                    errs.append(abs(d[i, j, k] - ref_dense[i, j, k]))
        assert max(errs) < 1e-6, f"3way {cfg}: max err {max(errs)}"
        c = out.checksum()
        if ref_checksum is None:
            ref_checksum = c
        assert c == ref_checksum, f"3way checksum mismatch for {cfg}"
        print(f"  3way pf={n_pf} pv={n_pv} pr={n_pr}: OK ({hex(c)[:14]})")

    # pallas path: fused X_j pipeline-step kernels, bit-identical numerators
    cfg = CometConfig(n_pf=1, n_pv=2, n_pr=1, impl="pallas")
    out = czek3_distributed(V, make_comet_mesh(1, 2, 1), cfg, stage=0)
    assert out.checksum() == ref_checksum, "3way pallas impl changed results"
    print("  3way pallas impl: OK")

    # packed bit-plane ring (path3 == "fused-levels-ring"): planes encoded
    # once before shard_map, ring-carried through Phases B/C, pipeline
    # slices fed to the level-decomposed kernels as byte-range views.
    # n_pf=2 shards the BYTE axis over "pf"; all bit-identical to xla.
    for n_pf, n_pv, n_pr in [(1, 2, 1), (2, 2, 1), (1, 2, 2), (1, 4, 1)]:
        cfg = CometConfig(n_pf=n_pf, n_pv=n_pv, n_pr=n_pr, impl="levels",
                          levels=15)
        out = czek3_distributed(V, make_comet_mesh(n_pf, n_pv, n_pr), cfg,
                                stage=0)
        assert out.checksum() == ref_checksum, (
            f"3way plane ring changed results ({n_pf},{n_pv},{n_pr})"
        )
        print(f"  3way fused-levels-ring pf={n_pf} pv={n_pv} pr={n_pr}: OK")

    # plane ring with the UNFUSED slice contraction (impl=levels_xla):
    # the ring still carries packed planes, X_j is a packed AND
    cfg = CometConfig(n_pf=2, n_pv=2, n_pr=1, impl="levels_xla", levels=15)
    out = czek3_distributed(V, make_comet_mesh(2, 2, 1), cfg, stage=0)
    assert out.checksum() == ref_checksum, "3way levels_xla plane ring"
    print("  3way plane ring unfused (levels_xla) pf=2 pv=2: OK")

    # encoding="none" opt-out keeps the value ring + per-slice encode
    cfg = CometConfig(n_pf=1, n_pv=2, n_pr=1, impl="levels", levels=15,
                      encoding="none")
    out = czek3_distributed(V, make_comet_mesh(1, 2, 1), cfg, stage=0)
    assert out.checksum() == ref_checksum, "3way value-ring fallback"
    print("  3way fused-levels value ring (encoding=none): OK")

    # staging: union over stages == the full result set, bit-identical
    cfg = CometConfig(n_pf=1, n_pv=2, n_pr=1, n_st=2)
    mesh = make_comet_mesh(1, 2, 1)
    parts = []
    total = 0
    for stage in range(2):
        out = czek3_distributed(V, mesh, cfg, stage=stage)
        total += out.num_triples()
        parts.extend(ck.raw_triples(I, J, K, W) for I, J, K, W in out.entries())
    assert total == n_unique, f"staged union {total} != {n_unique}"
    assert ck.combine(parts) == ref_checksum, "staged checksum mismatch"
    print("  3way staging n_st=2: OK")


def check_engine_parity(V):
    """The unified SimilarityEngine must reproduce the exact per-campaign
    checksums of the direct czek2/czek3 paths for several decompositions
    (the api_redesign acceptance contract), and the registry's CCC metric
    must be decomposition-invariant and match its numpy oracle."""
    from repro.api import SimilarityEngine, SimilarityRequest, get_metric

    engine = SimilarityEngine()
    for n_pf, n_pv, n_pr in [(1, 1, 1), (1, 4, 1), (2, 2, 2), (1, 2, 2)]:
        cfg = CometConfig(n_pf=n_pf, n_pv=n_pv, n_pr=n_pr)
        mesh = make_comet_mesh(n_pf, n_pv, n_pr)
        want2 = czek2_distributed(V, mesh, cfg).checksum()
        got2 = engine.run(
            SimilarityRequest(way=2, n_pf=n_pf, n_pv=n_pv, n_pr=n_pr), V
        ).checksum()
        assert got2 == want2, f"engine 2way checksum != direct ({n_pf},{n_pv},{n_pr})"
        want3 = czek3_distributed(V, mesh, cfg, stage=0).checksum()
        got3 = engine.run(
            SimilarityRequest(way=3, n_pf=n_pf, n_pv=n_pv, n_pr=n_pr), V
        ).checksum()
        assert got3 == want3, f"engine 3way checksum != direct ({n_pf},{n_pv},{n_pr})"
        print(f"  engine parity pf={n_pf} pv={n_pv} pr={n_pr}: OK")

    # CCC: decomposition-invariant checksum + oracle match (fp32 tolerance)
    ccc_ref = None
    oracle = get_metric("ccc").oracle2(V).astype(np.float32)
    iu = np.triu_indices(V.shape[1], 1)
    for n_pf, n_pv, n_pr in [(1, 1, 1), (1, 4, 1), (2, 2, 2)]:
        out = engine.run(
            SimilarityRequest(metric="ccc", way=2,
                              n_pf=n_pf, n_pv=n_pv, n_pr=n_pr), V
        )
        d = out.dense()
        np.testing.assert_allclose(d[iu], oracle[iu], rtol=1e-5,
                                   err_msg=f"ccc ({n_pf},{n_pv},{n_pr})")
        c = out.checksum()
        if ccc_ref is None:
            ccc_ref = c
        assert c == ccc_ref, "ccc checksum varies with decomposition"
        print(f"  ccc pf={n_pf} pv={n_pv} pr={n_pr}: OK ({hex(c)[:14]})")

    # the generated fused kernel serves CCC too (metric-generic epilogue):
    # integer data -> exact numerators -> bit-identical to the XLA path
    out = engine.run(
        SimilarityRequest(metric="ccc", way=2, n_pv=2, impl="pallas"), V
    )
    assert out.checksum() == ccc_ref, "ccc pallas fused path changed results"
    print("  ccc pallas fused epilogue: OK")


def check_plane_store(V):
    """Campaigns loaded from a repro.store dataset (pre-encoded packed
    planes, mmap -> ring) must be bit-identical to the in-memory matrix on
    BOTH engines across decompositions — including byte-axis "pf" sharding
    of the on-disk field shards — and must never run the host encoder."""
    import tempfile

    import repro.kernels.mgemm_levels as mgemm_levels
    from repro.api import InputSpec, SimilarityEngine, SimilarityRequest
    from repro.store import DatasetReader, write_dataset

    with tempfile.TemporaryDirectory() as tmp:
        write_dataset(tmp, V, levels=15, n_shards=2)
        DatasetReader(tmp).validate()
        engine = SimilarityEngine()
        spec = InputSpec(source="planes", path=tmp)

        calls = {"n": 0}
        orig = mgemm_levels.encode_bitplanes_np

        def counted(*args, **kwargs):
            calls["n"] += 1
            return orig(*args, **kwargs)

        mgemm_levels.encode_bitplanes_np = counted
        try:
            for way in (2, 3):
                ref = None
                for n_pf, n_pv, n_pr in [(1, 2, 1), (2, 2, 1), (1, 4, 1)]:
                    base = SimilarityRequest(
                        way=way, impl="levels", levels=15,
                        n_pf=n_pf, n_pv=n_pv, n_pr=n_pr,
                    )
                    before = calls["n"]
                    want = engine.run(base, V).checksum()
                    assert calls["n"] > before, "in-memory path should encode"
                    before = calls["n"]
                    got = engine.run(
                        SimilarityRequest(
                            way=way, impl="levels", levels=15,
                            n_pf=n_pf, n_pv=n_pv, n_pr=n_pr, input=spec,
                        )
                    ).checksum()
                    assert calls["n"] == before, (
                        f"{way}-way plane-store campaign ran the host encoder"
                    )
                    assert got == want, (
                        f"{way}-way store checksum != in-memory "
                        f"({n_pf},{n_pv},{n_pr})"
                    )
                    if ref is None:
                        ref = got
                    assert got == ref, f"{way}-way store checksum varies"
                    print(f"  {way}-way store pf={n_pf} pv={n_pv} pr={n_pr}: "
                          f"OK (zero-encode)")
        finally:
            mgemm_levels.encode_bitplanes_np = orig


def check_streamed(V):
    """Streamed campaigns (repro.stream) under multi-device meshes: the
    chunked deferred-flush pipeline + cross-shard merge epilogue must be
    bit-identical to the in-memory engines for 2-way AND 3-way, including
    byte-axis "pf" sharding of the chunks and a budget that forces >1
    chunk per shard."""
    import tempfile

    from repro.store import DatasetReader, write_dataset
    from repro.stream import stream_twoway, stream_threeway

    want2 = czek2_distributed(
        V, make_comet_mesh(1, 1, 1), CometConfig()).checksum()
    want3 = czek3_distributed(
        V, make_comet_mesh(1, 1, 1), CometConfig(), stage=0).checksum()
    with tempfile.TemporaryDirectory() as tmp:
        write_dataset(tmp, V, levels=15, n_shards=2)
        sh = DatasetReader(tmp).sharded()
        for n_pf, n_pv, n_pr, budget in [
            (1, 2, 1, 0),          # shard-per-chunk default
            (2, 2, 1, 0),          # byte axis split over "pf" per chunk
            # tight budget -> 1-byte chunks (2 * levels * n_v * 1 = 720
            # bytes double-buffered fits; a whole shard would not)
            (1, 2, 2, 800),
        ]:
            cfg = CometConfig(n_pf=n_pf, n_pv=n_pv, n_pr=n_pr,
                              impl="levels", levels=15, streaming="on",
                              max_host_bytes=budget)
            mesh = make_comet_mesh(n_pf, n_pv, n_pr)
            out2, info2 = stream_twoway(sh, mesh, cfg)
            assert out2.checksum() == want2, (
                f"streamed 2way != in-memory ({n_pf},{n_pv},{n_pr})"
            )
            out3, info3 = stream_threeway(sh, mesh, cfg, stage=0)
            assert out3.checksum() == want3, (
                f"streamed 3way != in-memory ({n_pf},{n_pv},{n_pr})"
            )
            if budget:
                assert info2["peak_host_bytes"] <= budget, info2
                assert info2["chunks"] > sh.n_shards, info2
            print(f"  streamed pf={n_pf} pv={n_pv} pr={n_pr} "
                  f"chunks={info2['chunks']}: OK")


def check_binary_popcount(Vb):
    """Binary ({0,1}) campaigns: levels=1 resolves to the popcount bit-GEMM
    (path == "fused-popcount") on BOTH engines, in-memory / store-backed /
    streamed, with checksums bit-identical to impl="xla" across
    decompositions — and the sorenson metric rides the same machinery."""
    import tempfile

    from repro.api import InputSpec, SimilarityEngine, SimilarityRequest
    from repro.core.metric_spec import CZEKANOWSKI
    from repro.core.tile_executor import TileExecutor
    from repro.core.twoway import resolve_config
    from repro.store import DatasetReader, write_dataset
    from repro.stream import stream_twoway, stream_threeway

    want2 = czek2_distributed(
        Vb, make_comet_mesh(1, 1, 1), CometConfig(impl="xla", levels=1)
    ).checksum()
    want3 = czek3_distributed(
        Vb, make_comet_mesh(1, 1, 1), CometConfig(impl="xla", levels=1),
        stage=0,
    ).checksum()

    # in-memory, >= 3 decompositions incl. the n_pf=2 merge epilogue
    for n_pf, n_pv, n_pr in [(1, 2, 1), (1, 2, 2), (2, 2, 1), (1, 4, 1)]:
        cfg = CometConfig(n_pf=n_pf, n_pv=n_pv, n_pr=n_pr, impl="levels",
                          levels=1)
        rcfg = resolve_config(cfg, Vb, CZEKANOWSKI)
        ex = TileExecutor(cfg=rcfg, metric=CZEKANOWSKI, axis=None)
        assert ex.path == "fused-popcount", (n_pf, ex.path)
        assert ex.path3 == "fused-popcount-ring", (n_pf, ex.path3)
        mesh = make_comet_mesh(n_pf, n_pv, n_pr)
        out2 = czek2_distributed(Vb, mesh, cfg)
        assert out2.checksum() == want2, (
            f"popcount 2way != xla ({n_pf},{n_pv},{n_pr})"
        )
        out3 = czek3_distributed(Vb, mesh, cfg, stage=0)
        assert out3.checksum() == want3, (
            f"popcount 3way != xla ({n_pf},{n_pv},{n_pr})"
        )
        print(f"  binary popcount pf={n_pf} pv={n_pv} pr={n_pr}: OK "
              f"(2way+3way)")

    # sorenson: same arithmetic on binary data -> same checksums, every impl
    engine = SimilarityEngine()
    for impl, levels in [("xla", 1), ("pallas", 1), ("levels", 1),
                         ("levels_xla", 1)]:
        got = engine.run(
            SimilarityRequest(metric="sorenson", way=2, n_pv=2, impl=impl,
                              levels=levels), Vb,
        ).checksum()
        assert got == want2, f"sorenson {impl} != xla reference"
    print("  sorenson parity (xla/pallas/popcount/levels_xla): OK")

    # store-backed + streamed binary campaigns stay on popcount partials
    with tempfile.TemporaryDirectory() as tmp:
        write_dataset(tmp, Vb, levels=1, n_shards=2)
        got = engine.run(
            SimilarityRequest(
                way=2, n_pv=2, impl="levels", levels=1,
                input=InputSpec(source="planes", path=tmp),
            )
        ).checksum()
        assert got == want2, "binary store campaign != xla"
        print("  binary store-backed campaign: OK")
        sh = DatasetReader(tmp).sharded()
        cfg = CometConfig(n_pv=2, impl="levels", levels=1, streaming="on")
        dex = TileExecutor(cfg=CometConfig(impl="levels", levels=1,
                                           encoding="bitplane"),
                           deferred=True)
        assert dex.path == "streamed-fused-popcount", dex.path
        assert dex.path3 == "streamed-fused-popcount-ring", dex.path3
        mesh = make_comet_mesh(1, 2, 1)
        out2, info2 = stream_twoway(sh, mesh, cfg)
        assert out2.checksum() == want2, "streamed binary 2way != xla"
        out3, info3 = stream_threeway(sh, mesh, cfg, stage=0)
        assert out3.checksum() == want3, "streamed binary 3way != xla"
        print(f"  binary streamed chunks={info2['chunks']}: OK (2way+3way)")


def check_delta(V):
    """Border-block delta campaigns under multi-device meshes: for a split
    n_old | n_new of V's columns, compute the prior on [0, n_old), run the
    delta program (new-vs-all rectangle + new-vs-new triangle, NO ring)
    across decompositions — including the n_pf=2 merge-epilogue case and a
    streamed run — merge into the packed prior, and require checksums
    BIT-IDENTICAL to the full recompute.  Accounting must report
    border-proportional compute with zero ring payload bytes."""
    import tempfile

    from repro.core.delta import merge_delta, twoway_delta
    from repro.store import DatasetReader, append_dataset, write_dataset
    from repro.stream import stream_twoway_delta

    n_old = 15
    m = N_V - n_old
    for impl, levels in [("xla", 15), ("levels", 15)]:
        base = CometConfig(impl=impl, levels=levels)
        want = czek2_distributed(V, make_comet_mesh(1, 1, 1), base).checksum()
        prior = czek2_distributed(
            V[:, :n_old], make_comet_mesh(1, 1, 1), base
        ).pack()
        for n_pf, n_pv, n_pr in [(1, 1, 1), (1, 2, 2), (2, 2, 1), (1, 4, 2),
                                 (2, 2, 2)]:
            cfg = CometConfig(n_pf=n_pf, n_pv=n_pv, n_pr=n_pr, impl=impl,
                              levels=levels)
            mesh = make_comet_mesh(n_pf, n_pv, n_pr)
            rect, tri, rcfg, info = twoway_delta(V, n_old, mesh, cfg)
            merged = merge_delta(prior, rect, tri, n_old, m, rcfg.out_dtype)
            assert merged.checksum() == want, (
                f"delta {impl} != full ({n_pf},{n_pv},{n_pr})"
            )
            assert info["ring_payload_bytes"] == 0, info
            assert info["computed_entries"] < info["full_entries"], info
            print(f"  delta {impl} pf={n_pf} pv={n_pv} pr={n_pr}: OK "
                  f"({info['computed_entries']}/{info['full_entries']} "
                  f"entries)")

    # streamed delta over an APPENDED store dataset (byte-column append),
    # multi-device + a budget forcing >1 chunk per shard, incl. the n_pf=2
    # merge-epilogue case
    base = CometConfig(impl="levels", levels=15)
    want = czek2_distributed(V, make_comet_mesh(1, 1, 1), base).checksum()
    prior = czek2_distributed(
        V[:, :n_old], make_comet_mesh(1, 1, 1), base
    ).pack()
    with tempfile.TemporaryDirectory() as tmp:
        write_dataset(tmp, V[:, :n_old], levels=15, n_shards=2)
        append_dataset(tmp, V[:, n_old:])
        sh = DatasetReader(tmp).sharded()
        for n_pf, n_pv, n_pr, budget in [(1, 2, 1, 0), (2, 2, 1, 0),
                                         (1, 2, 2, 800)]:
            cfg = CometConfig(n_pf=n_pf, n_pv=n_pv, n_pr=n_pr, impl="levels",
                              levels=15, streaming="on",
                              max_host_bytes=budget)
            mesh = make_comet_mesh(n_pf, n_pv, n_pr)
            rect, tri, rcfg, dinfo, sinfo = stream_twoway_delta(
                sh, n_old, mesh, cfg
            )
            merged = merge_delta(prior, rect, tri, n_old, m, rcfg.out_dtype)
            assert merged.checksum() == want, (
                f"streamed delta != full ({n_pf},{n_pv},{n_pr})"
            )
            assert dinfo["streamed"] and dinfo["ring_payload_bytes"] == 0
            if budget:
                assert sinfo["peak_host_bytes"] <= budget, sinfo
                assert sinfo["chunks"] > sh.n_shards, sinfo
            print(f"  streamed delta pf={n_pf} pv={n_pv} pr={n_pr} "
                  f"chunks={sinfo['chunks']}: OK")


def main():
    V = random_integer_vectors(N_F, N_V, max_value=15, seed=42)
    print("2-way decomposition invariance:")
    check_2way(V, czek2_metric_np(V).astype(np.float32))
    print("3-way decomposition invariance:")
    check_3way(V, czek3_metric_np(V).astype(np.float32))
    print("unified engine parity (api redesign contract):")
    check_engine_parity(V)
    print("plane-store zero-encode campaigns (repro.store):")
    check_plane_store(V)
    print("streamed campaigns (repro.stream):")
    check_streamed(V)
    print("binary popcount campaigns (kernels/popgemm):")
    check_binary_popcount(random_integer_vectors(N_F, N_V, max_value=1,
                                                 seed=43))
    print("border-block delta campaigns (repro.core.delta):")
    check_delta(V)
    print("ALL DISTRIBUTED CHECKS PASSED")


if __name__ == "__main__":
    main()
