import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh ((16,16) single-pod / (2,16,16) multi-pod),
  2. builds the step function + ShapeDtypeStruct inputs with shardings,
  3. jit(...).lower(...).compile()  — no allocation, proves the sharding
     config is coherent and fits,
  4. prints memory_analysis()/cost_analysis() and derives the roofline terms,
  5. appends the result to a JSON cache (incremental across invocations).

Usage:
  python -m repro.launch.dryrun --list
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]

results/dryrun is regenerable scratch (not committed); comet cells worth
versioning are copied to results/comet — see results/README.md.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.launch import specs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.parallel.sharding import use_mesh  # noqa: E402
from repro.roofline.analysis import analyze_compiled, model_flops  # noqa: E402

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "../../../results/dryrun")


def _cell_path(out_dir, arch, shape, multi_pod):
    mesh_tag = "multipod_2x16x16" if multi_pod else "pod_16x16"
    return os.path.join(out_dir, f"{arch}__{shape}__{mesh_tag}.json")


def _param_stats(arch):
    """(total_params, active_fraction) for MODEL_FLOPS."""
    from repro.configs.registry import get_config
    from repro.models import api

    cfg = get_config(arch)
    struct = jax.eval_shape(
        lambda k: api.init_model(cfg, k), jax.random.PRNGKey(0)
    )
    leaves_with_path = jax.tree_util.tree_flatten_with_path(struct)[0]
    total = sum(int(np.prod(l.shape)) for _, l in leaves_with_path)
    expert = sum(
        int(np.prod(l.shape))
        for p, l in leaves_with_path
        if any("moe" in str(k) for k in p) and not any("router" in str(k) for k in p)
    )
    if cfg.family == "moe" and expert:
        active = total - expert + expert * cfg.experts_per_token / cfg.n_experts
        return total, active / total
    return total, 1.0


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             force: bool = False, tag: str = "", overrides=None) -> dict:
    path = _cell_path(out_dir, arch, shape, multi_pod)
    if tag:
        path = path.replace(".json", f"__{tag}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    os.makedirs(out_dir, exist_ok=True)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    t0 = time.time()
    if arch.startswith("comet"):
        fn, args, meta = specs.build_comet_cell(arch, mesh, multi_pod, overrides)
        vpu_fraction = 0.0 if "mxu" in arch or (
            overrides or {}).get("impl", "").startswith("levels") else 1.0
    else:
        fn, args, meta = specs.build_cell(arch, shape, mesh, overrides)
        vpu_fraction = 0.0
    if overrides:
        meta = dict(meta, overrides={k: str(v) for k, v in overrides.items()})
    from contextlib import nullcontext

    # trace under the mesh context so with_sharding_constraint() inside the
    # model code binds to the production mesh; comet cells shard_map over
    # their own (pf, pv, pr) reinterpretation and need no ambient mesh.
    ctx = nullcontext() if arch.startswith("comet") else use_mesh(mesh)
    with ctx:
        lowered = jax.jit(fn).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    print(f"== {arch} x {shape} ({'2x16x16' if multi_pod else '16x16'}) ==")
    print(compiled.memory_analysis())
    from repro.parallel.compat import cost_analysis_dict

    ca = cost_analysis_dict(compiled)
    print({k: ca[k] for k in ("flops", "bytes accessed") if k in ca})

    terms = analyze_compiled(compiled, n_dev, vpu_fraction=vpu_fraction)
    if "work_fraction" in meta:
        # comet engines: rescale static cond-branch counts to the per-rank
        # round-robin share (see build_comet_cell)
        wf = meta["work_fraction"]
        terms["t_compute_static"] = terms["t_compute"]
        terms["t_memory_static"] = terms["t_memory"]
        terms["t_compute"] *= wf
        terms["t_memory"] *= wf
        terms["bottleneck"] = max(
            ("compute", terms["t_compute"]),
            ("memory", terms["t_memory"]),
            ("collective", terms["t_collective"]),
            key=lambda kv: kv[1],
        )[0]
        tb = max(terms["t_compute"], terms["t_memory"], terms["t_collective"])
        terms["roofline_fraction"] = terms["t_compute"] / tb if tb else 0.0
    result = dict(meta)
    result.update(
        multi_pod=multi_pod,
        mesh="2x16x16" if multi_pod else "16x16",
        lower_s=t_lower,
        compile_s=t_compile,
        roofline=terms,
    )
    if not arch.startswith("comet"):
        n_params, active_frac = _param_stats(arch)
        tokens = meta["batch"] * (meta["seq"] if meta["kind"] != "decode" else 1)
        mf = model_flops(n_params, tokens, meta["kind"], active_frac)
        hlo_total = terms["flops_per_device"] * n_dev
        result.update(
            n_params=n_params,
            active_fraction=active_frac,
            model_flops=mf,
            useful_flops_ratio=(mf / hlo_total) if hlo_total else 0.0,
        )
    else:
        # comparisons for the paper's metric: unique pairs/triples * n_f
        n_v = meta["n_v"]
        if meta["kind"] == "comet2way":
            comps = n_v * (n_v - 1) / 2 * meta["n_f"]
        else:
            comps = n_v * (n_v - 1) * (n_v - 2) / 6 * meta["n_f"] / meta["n_st"]
        result["elementwise_comparisons"] = comps
    with open(path, "w") as f:
        json.dump(result, f, indent=2, default=str)
    print(json.dumps({k: result[k] for k in ("arch", "shape", "mesh", "compile_s")},
                     default=str))
    print(f"  terms: compute={terms['t_compute']:.4e}s memory={terms['t_memory']:.4e}s"
          f" collective={terms['t_collective']:.4e}s -> {terms['bottleneck']}")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", default="paper")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=os.path.normpath(DEFAULT_OUT))
    ap.add_argument("--tag", default="", help="suffix for experiment variants")
    ap.add_argument("--override", action="append", default=[],
                    help="config override key=value (repeatable)")
    args = ap.parse_args(argv)

    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        if v in ("True", "False"):
            v = v == "True"
        else:
            try:
                v = int(v)
            except ValueError:
                try:
                    v = float(v)
                except ValueError:
                    pass
        overrides[k] = v

    if args.list:
        for arch, shape in specs.cells():
            print(f"{arch:28s} {shape}")
        return 0

    todo = []
    if args.all:
        for arch, shape in specs.cells():
            meshes = [False, True] if args.both_meshes else [args.multi_pod]
            for mp in meshes:
                todo.append((arch, shape, mp))
    else:
        assert args.arch, "--arch required (or --all)"
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        for mp in meshes:
            todo.append((args.arch, args.shape, mp))

    failures = []
    for arch, shape, mp in todo:
        try:
            run_cell(arch, shape, mp, args.out, force=args.force, tag=args.tag,
                     overrides=overrides or None)
        except Exception:
            traceback.print_exc()
            failures.append((arch, shape, mp))
    if failures:
        print("FAILED CELLS:", failures)
        return 1
    print(f"all {len(todo)} cells OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
