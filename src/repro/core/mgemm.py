"""min-product GEMM ("mGEMM") — the paper's core kernel (§3.1).

``mgemm(A, B)[i, j] = sum_q min(A[i, q], B[q, j])`` for A (m, k), B (k, n).

The paper realizes this by patching MAGMA's GEMM stencil (FMA -> fmin+add) on
NVIDIA GPUs.  On TPU the systolic MXU cannot evaluate ``min``, so the faithful
path is a VPU (vector-unit) Pallas kernel; see ``repro/kernels/mgemm``.  This
module provides the implementation registry and the XLA fallback used for CPU
execution and as a jit-friendly building block inside the distributed engines.

Implementations
---------------
``xla``     chunked jnp.minimum broadcast + reduce (runs everywhere; what the
            distributed engines use on the CPU container).
``pallas``  Pallas VPU kernel (TPU target; ``interpret=True`` on CPU tests).
``levels``  beyond-paper MXU path: exact for L-level integer data via
            level decomposition (see ``repro/kernels/mgemm_levels``).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["mgemm", "mgemm_xla", "register_impl", "get_impl", "available_impls"]

_IMPLS: dict[str, Callable] = {}


def register_impl(name: str, fn: Callable) -> None:
    _IMPLS[name] = fn


def get_impl(name: str) -> Callable:
    if name not in _IMPLS:
        # late import so kernels register themselves without import cycles
        import repro.kernels.mgemm.ops  # noqa: F401
        import repro.kernels.mgemm_levels.ops  # noqa: F401
    return _IMPLS[name]


def available_impls() -> list[str]:
    get_impl("xla")
    return sorted(_IMPLS)


@functools.partial(jax.jit, static_argnames=("chunk", "out_dtype"))
def mgemm_xla(A, B, *, chunk: int = 128, out_dtype=jnp.float32):
    """Chunked XLA min-plus GEMM.

    Memory is bounded by chunking the contraction axis: each step materializes
    an (m, chunk, n) broadcast-minimum and reduces it.  Accumulation is fp32
    (or fp64 under x64) regardless of input dtype, like the Pallas kernel.
    """
    A = jnp.asarray(A)
    B = jnp.asarray(B)
    m, k = A.shape
    k2, n = B.shape
    assert k == k2, f"contraction mismatch {A.shape} x {B.shape}"
    acc_dt = jnp.promote_types(out_dtype, jnp.float32)

    # pad k to a multiple of chunk with +inf-neutral values?  min() with pad
    # values must not contribute: pad with 0 and subtract nothing — instead we
    # pad both operands with 0 so min(0, 0) = 0 contributes 0.  (All genomics
    # inputs are >= 0; for generality pad with the dtype minimum contribution
    # 0 via masking.)
    pad = (-k) % chunk
    if pad:
        A = jnp.pad(A, ((0, 0), (0, pad)))
        B = jnp.pad(B, ((0, pad), (0, 0)))
        k = k + pad
    nc = k // chunk
    A3 = A.reshape(m, nc, chunk).transpose(1, 0, 2)  # (nc, m, chunk)
    B3 = B.reshape(nc, chunk, n)  # (nc, chunk, n)

    def body(acc, ab):
        a, b = ab  # (m, chunk), (chunk, n)
        part = jnp.minimum(a[:, :, None], b[None, :, :]).astype(acc_dt).sum(axis=1)
        return acc + part, None

    acc0 = jnp.zeros((m, n), acc_dt)
    acc, _ = jax.lax.scan(body, acc0, (A3, B3))
    return acc.astype(out_dtype)


register_impl("xla", mgemm_xla)


def mgemm(A, B, *, impl: str = "xla", **kw):
    """Dispatching entry point. ``impl`` in {'xla', 'pallas', 'levels', ...}."""
    return get_impl(impl)(A, B, **kw)


def mgemm_vt_v(V, *, impl: str = "xla", **kw):
    """The paper's M = V^T ∘min V for V of shape (n_f, n_v)."""
    return mgemm(V.T, V, impl=impl, **kw)
