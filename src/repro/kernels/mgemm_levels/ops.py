"""jit'd wrappers + impl registration for the MXU level-decomposition path."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.mgemm import register_impl

from .kernel import mgemm_levels_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def mgemm_levels(A, B, *, levels: int = 2, **kw):
    kw.setdefault("interpret", not _on_tpu())
    return mgemm_levels_pallas(A, B, levels=levels, **kw)


def mgemm_levels_xla(A, B, *, levels: int = 2, out_dtype=jnp.float32):
    """XLA (non-Pallas) realization — what the distributed engines call on
    CPU, and what the dry-run lowers on the v5e mesh (plain dots partition
    cleanly under GSPMD)."""
    acc = jnp.zeros((A.shape[0], B.shape[1]), jnp.float32)
    for t in range(1, levels + 1):
        at = (A >= t).astype(jnp.bfloat16)
        bt = (B >= t).astype(jnp.bfloat16)
        acc += jnp.dot(at, bt, preferred_element_type=jnp.float32)
    return acc.astype(out_dtype)


register_impl("levels", mgemm_levels)
register_impl("levels_xla", mgemm_levels_xla)
