"""Batched campaign results: many (metric, subset) campaigns, one payload.

A batched ``SimilarityRequest`` (``metrics=[...]`` and/or ``subsets=[...]``)
runs every campaign against ONE ring traversal of the shared plane payload
(``repro.core.twoway.twoway_batched`` / ``threeway.threeway_batched``).  The
engine wraps the per-campaign outputs in a ``BatchedSimilarityResult``: an
ordered collection of ordinary ``SimilarityResult`` objects — each one
bit-identical (checksum) to the sequential single-campaign run it replaces —
plus the shared ``meta["batch"]`` ring accounting proving the payload bytes
moved are independent of the campaign count.

Named-subset campaigns never re-encode: the engine restricts the payload to
the sorted union of all subset indices (a byte-level vector-axis view of the
packed planes — slicing commutes with encoding, see docs/BITPLANE_FORMAT.md),
runs the batched engines over the union, and ``extract_twoway`` /
``extract_threeway`` below carve each named subset's result out of the union
output.  Extraction is a host-side re-index into the smallest single-rank
plan — values are copied untouched, so bit-exactness survives.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.plan2 import TwoWayPlan
from repro.core.plan3 import ThreeWayPlan
from repro.core.threeway import ThreeWayOutput
from repro.core.twoway import TwoWayOutput

__all__ = ["BatchedSimilarityResult", "extract_twoway", "extract_threeway"]


@dataclass
class BatchedSimilarityResult:
    """Ordered (metric, subset_name, SimilarityResult) campaigns.

    ``subset_name`` is ``""`` for full-set campaigns.  Iterating yields the
    ``(metric, subset_name, result)`` triples in request order (metrics
    outer, subsets inner); ``get`` looks one campaign up by name.
    """

    campaigns: list  # [(metric, subset_name, SimilarityResult), ...]
    meta: dict = field(default_factory=dict)
    seconds: float = 0.0

    def __iter__(self):
        return iter(self.campaigns)

    def __len__(self) -> int:
        return len(self.campaigns)

    def get(self, metric: str, subset: str = ""):
        for m, s, r in self.campaigns:
            if m == metric and s == subset:
                return r
        raise KeyError(f"no campaign (metric={metric!r}, subset={subset!r})")

    def checksums(self) -> dict:
        """{(metric, subset_name): checksum} over every campaign."""
        return {(m, s): r.checksum() for m, s, r in self.campaigns}


def _position_lut(n_union: int, pos: np.ndarray) -> np.ndarray:
    """union position -> subset position (or -1), preserving subset order."""
    pos = np.asarray(pos, dtype=np.int64)
    lut = np.full((n_union,), -1, np.int64)
    lut[pos] = np.arange(len(pos))
    return lut


def extract_twoway(full: TwoWayOutput, pos) -> TwoWayOutput:
    """Carve a subset's 2-way result out of the union-payload output.

    ``pos``: the subset's vector positions within the union payload, in
    subset order (subset index t lives at union column pos[t]).  Returns a
    single-rank ``TwoWayOutput`` (plan (1, 1): one diagonal block, strict
    upper triangle) whose entries/checksum equal a sequential run over the
    subset columns alone — values are copied, never recomputed.
    """
    pos = np.asarray(pos, dtype=np.int64)
    m = len(pos)
    lut = _position_lut(full.n_v, pos)
    sub = np.zeros((m, m), full.blocks.dtype)
    for I, J, V in full.entries():
        a, b = lut[I], lut[J]
        keep = (a >= 0) & (b >= 0)
        a, b, v = a[keep], b[keep], V[keep]
        lo, hi = np.minimum(a, b), np.maximum(a, b)
        sub[lo, hi] = v
    return TwoWayOutput(
        blocks=sub[None, None, None], plan=TwoWayPlan(1, 1), n_v=m, n_vp=m,
    )


def extract_threeway(stage_outs, pos) -> ThreeWayOutput:
    """Carve a subset's 3-way result out of union-payload stage outputs.

    ``stage_outs`` must cover every computed triple of the union run (all
    stages of the request — the engine validates completeness before
    batching).  Returns a single-rank single-stage ``ThreeWayOutput``
    (plan (1, 1, 1)): the subset block size is padded to a multiple of 6
    and each canonical triple a < b < c lands in DIAG slot ``b // L`` at
    pipeline offset ``b - slot * L`` (L = padded_m / 6) — exactly where the
    sequential single-rank schedule computes it.
    """
    pos = np.asarray(pos, dtype=np.int64)
    m = len(pos)
    mp = m + (-m) % 6
    L = mp // 6
    lut = _position_lut(stage_outs[0].n_v, pos)
    blocks = np.zeros((1, 1, 6, L, mp, mp), stage_outs[0].blocks.dtype)
    for out in stage_outs:
        for I, J, K, V in out.entries():
            t = np.stack([lut[I], lut[J], lut[K]])
            keep = (t >= 0).all(axis=0)
            a, b, c = np.sort(t[:, keep], axis=0)
            s = b // L
            blocks[0, 0, s, b - s * L, a, c] = V[keep]
    return ThreeWayOutput(
        blocks=blocks, plan=ThreeWayPlan(1, 1, 1), n_v=m, n_vp=mp, stage=0,
    )
