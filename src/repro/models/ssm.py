"""Mamba2 (SSD — state-space duality) block, chunked matmul formulation.

Faithful to "Transformers are SSDs" (arXiv:2405.21060): the sequence is
split into chunks of length Q; intra-chunk terms are dense matmuls (MXU
work), inter-chunk state is a short ``lax.scan`` recurrence over chunk
summaries — O(S) time, O(S·N·P/Q) state traffic, matmul-dominated.

Block layout (Mamba2):
  in_proj -> [z | xBC | dt];  causal depthwise conv over xBC;  split x, B, C;
  y = SSD(x, dt, A, B, C) + D*x;  y = RMSNorm(y * silu(z));  out_proj.

Decode keeps O(1) state: conv tail (k-1 inputs) + SSM state (H, P, N).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init
from repro.models.norms import rms_norm
from repro.parallel.sharding import DATA_AXES, shard


def init_mamba(cfg: ModelConfig, key):
    di = cfg.d_inner
    H = cfg.ssm_heads
    N = cfg.ssm_state
    conv_dim = di + 2 * N  # x + B + C (single group)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(k1, (cfg.d_model, 2 * di + 2 * N + H), cfg.pdt),
        "conv_w": dense_init(k2, (cfg.ssm_conv, conv_dim), cfg.pdt, scale=0.5),
        "conv_b": jnp.zeros((conv_dim,), cfg.pdt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 1e-2))).astype(jnp.float32),
        "norm_w": jnp.ones((di,), cfg.pdt),
        "out_proj": dense_init(k3, (di, cfg.d_model), cfg.pdt),
    }


def _causal_conv(xbc, w, b, tail=None):
    """Depthwise causal conv. xbc (B,S,C), w (k,C). tail (B,k-1,C) or None."""
    k = w.shape[0]
    if tail is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = tail.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)  # (B, S+k-1, C)
    out = sum(xp[:, i : i + xbc.shape[1]] * w[i] for i in range(k))
    new_tail = xp[:, -(k - 1) :] if k > 1 else None
    return out + b, new_tail


def _ssd_chunked(x, dt, A, Bm, Cm, Q: int):
    """SSD scan. x (B,S,H,P), dt (B,S,H), A (H,), Bm/Cm (B,S,N).

    Returns y (B,S,H,P) and final state (B,H,P,N)."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % Q == 0, (S, Q)
    nc = S // Q
    f32 = jnp.float32
    xc = x.reshape(Bsz, nc, Q, H, P).astype(f32)
    dtc = dt.reshape(Bsz, nc, Q, H).astype(f32)
    Bc = Bm.reshape(Bsz, nc, Q, N).astype(f32)
    Cc = Cm.reshape(Bsz, nc, Q, N).astype(f32)

    dA = dtc * A  # (B,nc,Q,H), A < 0
    dA_cs = jnp.cumsum(dA, axis=2)
    # intra-chunk: L[i,j] = exp(dA_cs[i] - dA_cs[j]) for i >= j
    seg = dA_cs[:, :, :, None, :] - dA_cs[:, :, None, :, :]  # (B,nc,Q_i,Q_j,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)  # (B,nc,Qi,Qj)
    xdt = xc * dtc[..., None]  # (B,nc,Q,H,P)
    y_diag = jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, L, xdt)

    # chunk state summaries: S_c = sum_j exp(dA_cs[last]-dA_cs[j]) B_j (x dt)_j
    decay_to_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # (B,nc,Q,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", Bc, decay_to_end, xdt)
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])  # (B,nc,H)

    def inter(carry, inp):
        st, dec = inp  # (B,H,N,P), (B,H)
        new = st + dec[:, :, None, None] * carry
        return new, carry  # emit state *before* this chunk

    init = jnp.zeros((Bsz, H, N, P), f32)
    final, prev_states = jax.lax.scan(
        inter, init, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B,nc,H,N,P)

    decay_in = jnp.exp(dA_cs)  # (B,nc,Q,H) decay from chunk start to i
    y_off = jnp.einsum("bcin,bcih,bchnp->bcihp", Cc, decay_in, prev_states)
    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y, jnp.moveaxis(final, 2, 3)  # state (B,H,P,N)


def mamba_block(cfg: ModelConfig, p, x, *, cache=None):
    """x (B,S,D) -> (y (B,S,D), new_cache).

    cache = {"conv": (B,k-1,conv_dim), "ssm": (B,H,P,N)} for decode (S==1)."""
    cdt = cfg.cdt
    di, H, N, P = cfg.d_inner, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    B_, S, _ = x.shape
    zxbcdt = x @ p["in_proj"].astype(cdt)
    z, xBC, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * N], axis=-1)
    z = shard(z, DATA_AXES, None, "model")
    xBC = shard(xBC, DATA_AXES, None, "model")

    if cache is None:
        xBC, _ = _causal_conv(xBC, p["conv_w"].astype(cdt), p["conv_b"].astype(cdt))
        xBC = jax.nn.silu(xBC)
        xs, Bm, Cm = jnp.split(xBC, [di, di + N], axis=-1)
        dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
        A = -jnp.exp(p["A_log"])
        y, _ = _ssd_chunked(
            xs.reshape(B_, S, H, P), dtv, A, Bm, Cm, min(cfg.ssm_chunk, S)
        )
        new_cache = None
    elif S == 1:
        # single-token recurrence
        xBC, new_tail = _causal_conv(
            xBC, p["conv_w"].astype(cdt), p["conv_b"].astype(cdt), tail=cache["conv"]
        )
        xBC = jax.nn.silu(xBC)
        xs, Bm, Cm = jnp.split(xBC, [di, di + N], axis=-1)
        dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,1,H)
        A = -jnp.exp(p["A_log"])
        dA = jnp.exp(dtv[:, 0, :] * A)  # (B,H)
        xh = xs.reshape(B_, H, P).astype(jnp.float32)
        st = cache["ssm"]  # (B,H,P,N)
        st = dA[:, :, None, None] * st + jnp.einsum(
            "bhp,bn,bh->bhpn", xh, Bm[:, 0].astype(jnp.float32), dtv[:, 0]
        )
        y = jnp.einsum("bhpn,bn->bhp", st, Cm[:, 0].astype(jnp.float32))
        y = y.reshape(B_, 1, H, P)
        new_cache = {"conv": new_tail, "ssm": st}
        xs = xs.reshape(B_, S, di)
    else:
        # chunked prefill: seed from cache, emit final state (assumes fresh
        # cache, i.e. prior state zero — the serve engine's prefill contract)
        xBC, new_tail = _causal_conv(
            xBC, p["conv_w"].astype(cdt), p["conv_b"].astype(cdt), tail=cache["conv"]
        )
        xBC = jax.nn.silu(xBC)
        xs, Bm, Cm = jnp.split(xBC, [di, di + N], axis=-1)
        dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
        A = -jnp.exp(p["A_log"])
        y, final_state = _ssd_chunked(
            xs.reshape(B_, S, H, P), dtv, A, Bm, Cm, min(cfg.ssm_chunk, S)
        )
        new_cache = {"conv": new_tail, "ssm": final_state}

    y = y + p["D"][None, None, :, None] * xs.reshape(B_, S, H, P).astype(jnp.float32)
    y = y.reshape(B_, S, di).astype(cdt)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(cdt)
    return shard(out, DATA_AXES, None, None), new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, n_layers: int, dtype):
    di, H, N, P = cfg.d_inner, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    conv_dim = di + 2 * N
    return {
        "conv": jnp.zeros((n_layers, batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((n_layers, batch, H, P, N), jnp.float32),
    }
