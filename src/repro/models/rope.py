"""Rotary position embeddings: standard RoPE + M-RoPE (Qwen2-VL).

M-RoPE splits the rotary dims into (temporal, height, width) sections with
independent position ids — for pure text all three ids coincide and M-RoPE
degenerates to standard RoPE (which is how the smoke tests exercise it; the
vision frontend is a stub per the assignment).
"""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def rope_angles(positions, head_dim: int, theta: float, sections=()):
    """positions (..., S) or (3, ..., S) for M-RoPE -> cos/sin (..., S, hd/2)."""
    inv = rope_freqs(head_dim, theta)  # (hd/2,)
    if sections:
        assert positions.ndim >= 2 and positions.shape[0] == 3, "M-RoPE wants (3,...,S)"
        ang = positions[..., None].astype(jnp.float32) * inv  # (3, ..., S, hd/2)
        # select section: first sections[0] freqs use temporal ids, next use
        # height, rest width (Qwen2-VL interleaved layout simplified to
        # contiguous sections).
        sec = jnp.concatenate(
            [jnp.full((n,), i, jnp.int32) for i, n in enumerate(sections)]
        )[: inv.shape[0]]
        ang = jnp.take_along_axis(
            ang, sec[(None,) * (ang.ndim - 2) + (slice(None),)][None].astype(jnp.int32),
            axis=0,
        )[0]
    else:
        ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, hd/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (..., S, H, hd); cos/sin (..., S, hd/2) broadcast over heads."""
    dt = x.dtype
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(dt)
