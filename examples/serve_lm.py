"""Batched serving example: prefill + decode with KV cache.

    PYTHONPATH=src python examples/serve_lm.py --arch llama3-8b
(uses the reduced smoke config so it runs on CPU; drop --smoke on a pod)
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.models import api
from repro.serve.engine import ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.7)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = api.init_model(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(
        cfg, params,
        ServeConfig(max_new_tokens=args.tokens, temperature=args.temperature),
    )
    prompts = np.random.default_rng(0).integers(
        3, cfg.vocab_size, (args.batch, 8)
    ).astype(np.int32)
    t0 = time.time()
    out = eng.generate(prompts)
    dt = time.time() - t0
    print(f"{cfg.name}: {out.size} tokens in {dt:.2f}s ({out.size / dt:.1f} tok/s)")
    for r, row in enumerate(out):
        print(f"  seq{r}: {row.tolist()}")


if __name__ == "__main__":
    main()
