"""repro.obs — tracing + metrics for the whole similarity stack.

Two halves, both zero-overhead when unused:

* ``repro.obs.trace`` — a thread-aware span tracer.  Disabled (the
  default) every ``span()`` call returns one shared no-op singleton: no
  allocation, no lock, no clock read on the hot path.  Enabled, spans
  record B/E event pairs (wall time, thread id, byte/counter attributes)
  that export as Chrome/Perfetto trace-event JSON and aggregate into the
  per-phase table the CLI prints after a ``--trace`` run.

* ``repro.obs.metrics`` — a process-wide metrics registry (counters,
  gauges, latency histograms) whose ``snapshot()`` is taken under one
  lock, so concurrent readers always see an internally consistent view
  (``SimilarityService.metrics()`` is built on it).

See docs/OBSERVABILITY.md for the full walkthrough.
"""
from repro.obs.metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_registry,
)
from repro.obs.trace import (  # noqa: F401
    Tracer,
    aggregate_phases,
    current_path,
    disable,
    enable,
    enabled,
    fence,
    format_phase_table,
    get_tracer,
    roofline_event,
    span,
    validate_chrome_trace,
)
