"""Pallas TPU kernel: popcount bit-GEMM for binary (levels=1) planes.

For binary data the single bit-plane IS the data and, for a, b in {0, 1},

    min(a, b) = a AND b

so the min-plus numerator collapses to pure bit arithmetic over the
*packed* bytes (paper §2.3 — the same trick second-generation PLINK uses
for biobank-scale binary genotype arithmetic):

    N[i, j] = sum_q popcount(Pa[q, i] AND Pb[q, j])

Where the levels path inflates each byte tile 8x into bf16 indicators
before contracting, these kernels AND the byte tiles directly, group 4
consecutive bytes into one int32 word per lane, and accumulate
``lax.population_count`` of the AND outer product — no unpack shuffle and
1/8 the VMEM indicator footprint on the hottest binary-workload loop.

Operand layout is unchanged: ``(1, kb, w)`` uint8 packed planes in the
documented wire format (docs/BITPLANE_FORMAT.md) — ring payloads, store
shards, and pipeline byte-range views feed in unmodified.  Zero pad bytes
AND to zero and contribute zero popcount, so padding is inert exactly as
the format promises for the dot formulation.

Exactness: every numerator is an integer <= n_f, exactly representable in
fp32, so campaign checksums stay bit-identical to ``impl="xla"`` across
every decomposition, chunking, and path — popcount partials also ADD
exactly, which is what keeps the streamed/merge paths on this kernel.

Mosaic note: ``lax.population_count`` is exercised interpret-mode in CI;
its real-TPU Mosaic lowering still needs a v5e check (ROADMAP "Real-TPU
validation") — the SWAR shift/mask/add formulation is the drop-in
fallback if the op is unsupported there.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.mgemm.kernel import _tri_decode, tri_tile_coords
from repro.kernels.mgemm_levels.kernel import _pad_planes, _pad_stat

DEFAULT_BM = 256
DEFAULT_BN = 256
# byte tile of the contraction axis; wrappers round it up so every K-tile
# packs into whole (4-byte) words and whole popcount chunks
DEFAULT_BKB = 64
# int32 words (= 256 fields) popcounted per fori_loop step — bounds the
# (k_chunk, bm, bn) AND/popcount intermediate like czek3's K_CHUNK; 8
# words is 2 MiB of int32 intermediate at the default 256x256 tile
# (VMEM-safe) and measurably ahead of 4 on the loop-overhead side
K_CHUNK = 8
DEFAULT_BM3 = 128
DEFAULT_BN3 = 128


def _pack_words(tile):
    """(bkb, w) packed uint8 -> (bkb//4, w) int32 words, little-endian.

    AND distributes over the 4-byte grouping, so popcount(AND of words) ==
    popcount(AND of bytes); callers align ``bkb`` to whole words.  The
    int32 may wrap negative when byte 3 has its top bit set — the bit
    pattern (what ``population_count`` sees) is still exact."""
    kb, w = tile.shape
    b = tile.astype(jnp.int32).reshape(kb // 4, 4, w)
    return b[:, 0] | (b[:, 1] << 8) | (b[:, 2] << 16) | (b[:, 3] << 24)


def _pop_contract(pa, pb, k_chunk: int):
    """out[i, j] = sum_q popcount(pa[q, i] & pb[q, j]) for one K-tile.

    pa (bkb, bm), pb (bkb, bn) packed uint8 -> (bm, bn) fp32.  The AND
    outer product is popcounted ``k_chunk`` words at a time to bound the
    (k_chunk, bm, bn) intermediate."""
    wa = _pack_words(pa)
    wb = _pack_words(pb)
    nw, bm = wa.shape
    bn = wb.shape[1]

    def body(t, acc):
        a_sub = jax.lax.dynamic_slice(wa, (t * k_chunk, 0), (k_chunk, bm))
        b_sub = jax.lax.dynamic_slice(wb, (t * k_chunk, 0), (k_chunk, bn))
        pc = jax.lax.population_count(a_sub[:, :, None] & b_sub[:, None, :])
        return acc + pc.sum(axis=0).astype(jnp.float32)

    return jax.lax.fori_loop(
        0, nw // k_chunk, body, jnp.zeros((bm, bn), jnp.float32)
    )


def _word_align(bkb: int, k_chunk: int) -> int:
    """Round a byte-tile size up to whole popcount chunks of int32 words."""
    unit = 4 * k_chunk
    return -(-bkb // unit) * unit


def _pop_fused_kernel(
    pa_ref, pb_ref, sa_ref, sb_ref, o_ref, acc_ref,
    *, n_k_steps: int, k_chunk: int, epilogue,
):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _pop_contract(pa_ref[0], pb_ref[0], k_chunk)

    @pl.when(pl.program_id(2) == n_k_steps - 1)
    def _flush():
        acc = acc_ref[...]
        vals = acc if epilogue is None else epilogue(
            acc, sa_ref[...], sb_ref[...]
        )
        o_ref[...] = vals.astype(o_ref.dtype)


def _pop_fused_tri_kernel(
    idx_ref, pa_ref, pb_ref, sa_ref, sb_ref, o_ref, acc_ref,
    *, n_k_steps: int, k_chunk: int, epilogue,
):
    """Triangular-schedule popcount kernel for diagonal blocks (paper §5):
    grid axis 0 walks only the ``tj >= ti`` tiles; on-diagonal tiles are
    masked to the strict upper triangle at flush."""
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _pop_contract(pa_ref[0], pb_ref[0], k_chunk)

    @pl.when(pl.program_id(1) == n_k_steps - 1)
    def _flush():
        acc = acc_ref[...]
        vals = acc if epilogue is None else epilogue(
            acc, sa_ref[...], sb_ref[...]
        )
        on_diag = idx_ref[0, 0] == idx_ref[0, 1]
        li = jax.lax.broadcasted_iota(jnp.int32, vals.shape, 0)
        lj = jax.lax.broadcasted_iota(jnp.int32, vals.shape, 1)
        keep = jnp.logical_or(jnp.logical_not(on_diag), li < lj)
        o_ref[0] = jnp.where(keep, vals, 0.0).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "epilogue", "bm", "bn", "bkb", "k_chunk", "interpret", "out_dtype"
    ),
)
def metric2_pop_pallas(
    Pa,
    Pb,
    sa,
    sb,
    *,
    epilogue,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bkb: int = DEFAULT_BKB,
    k_chunk: int = K_CHUNK,
    interpret: bool = False,
    out_dtype=jnp.float32,
):
    """Fused 2-way metric kernel on a binary packed plane (rectangular grid).

    Pa (1, kb, m) / Pb (1, kb, n) single-plane payloads; sa (m,) / sb (n,)
    per-vector stats (= the plane popcounts for binary data).  Returns
    ``epilogue(popcount(Pa AND Pb), sa, sb)``; ``epilogue=None`` returns
    the raw fp32 numerator (the deferred-flush form for ``n_pf > 1`` psums
    and streamed chunk programs).
    """
    levels, kb, m = Pa.shape
    n = Pb.shape[2]
    assert levels == 1 and Pb.shape[:2] == (1, kb), (Pa.shape, Pb.shape)
    bkb = _word_align(bkb, k_chunk)
    mp, np_, kbp = (-m) % bm, (-n) % bn, (-kb) % bkb
    Pa = _pad_planes(Pa, mp, kbp)
    Pb = _pad_planes(Pb, np_, kbp)
    sa = _pad_stat(sa, mp)[:, None]
    sb = _pad_stat(sb, np_)[None, :]
    M, N, KB = m + mp, n + np_, kb + kbp
    n_k_steps = KB // bkb
    grid = (M // bm, N // bn, n_k_steps)
    out = pl.pallas_call(
        functools.partial(
            _pop_fused_kernel, n_k_steps=n_k_steps, k_chunk=k_chunk,
            epilogue=epilogue,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bkb, bm), lambda i, j, t: (0, t, i)),
            pl.BlockSpec((1, bkb, bn), lambda i, j, t: (0, t, j)),
            pl.BlockSpec((bm, 1), lambda i, j, t: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, t: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(Pa, Pb, sa, sb)
    return out[:m, :n]


@functools.partial(
    jax.jit,
    static_argnames=(
        "epilogue", "bt", "bkb", "k_chunk", "interpret", "out_dtype"
    ),
)
def metric2_pop_tri_pallas(
    P,
    s,
    *,
    epilogue,
    bt: int = DEFAULT_BM,
    bkb: int = DEFAULT_BKB,
    k_chunk: int = K_CHUNK,
    interpret: bool = False,
    out_dtype=jnp.float32,
):
    """Fused diagonal-block popcount kernel on the triangular tile schedule.

    P (1, kb, m) is the packed plane of ONE vector block (both operand
    orientations read the same array); only the T(T+1)/2 tiles with
    ``tj >= ti`` are enumerated.  Returns the packed tile list (nP, bt, bt)
    in ``tri_tile_coords`` order, like ``metric2_levels_tri_pallas``."""
    levels, kb, m = P.shape
    assert levels == 1, P.shape
    bkb = _word_align(bkb, k_chunk)
    mp, kbp = (-m) % bt, (-kb) % bkb
    P = _pad_planes(P, mp, kbp)
    sp = _pad_stat(s, mp)
    sa, sb = sp[:, None], sp[None, :]
    M, KB = m + mp, kb + kbp
    T = M // bt
    nP = T * (T + 1) // 2
    n_k_steps = KB // bkb
    ti, tj = tri_tile_coords(T)
    idx = jnp.asarray(np.stack([ti, tj], axis=1))  # (nP, 2) static schedule

    def a_map(p, t):
        return (0, t, _tri_decode(p, T)[0])

    def b_map(p, t):
        return (0, t, _tri_decode(p, T)[1])

    def sa_map(p, t):
        return (_tri_decode(p, T)[0], 0)

    def sb_map(p, t):
        return (0, _tri_decode(p, T)[1])

    out = pl.pallas_call(
        functools.partial(
            _pop_fused_tri_kernel, n_k_steps=n_k_steps, k_chunk=k_chunk,
            epilogue=epilogue,
        ),
        grid=(nP, n_k_steps),
        in_specs=[
            pl.BlockSpec((1, 2), lambda p, t: (p, 0)),
            pl.BlockSpec((1, bkb, bt), a_map),
            pl.BlockSpec((1, bkb, bt), b_map),
            pl.BlockSpec((bt, 1), sa_map),
            pl.BlockSpec((1, bt), sb_map),
        ],
        out_specs=pl.BlockSpec((1, bt, bt), lambda p, t: (p, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nP, bt, bt), out_dtype),
        scratch_shapes=[pltpu.VMEM((bt, bt), jnp.float32)],
        interpret=interpret,
    )(idx, P, P, sa, sb)
    return out


# -- 3-way pipeline-slice variant --------------------------------------------
#
# min(a, x, b) = a AND x AND b on binary planes: the X_j = min(own, x)
# tile is a bitwise AND of packed bytes that STAYS packed — the whole
# slice contraction never unpacks a byte.  The 3-way analogue of
# ``czek3.threeway_batch_levels_pallas`` with the popcount contraction in
# place of the plane dot_generals.


def _threeway_pop_kernel(
    own_ref, x_ref, right_ref, o_ref, acc_ref, *, n_k_steps, k_chunk
):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # packed AND == plane of min(own, x); x (1, bkb, 1) broadcasts
    xo = own_ref[0] & x_ref[0]
    acc_ref[...] += _pop_contract(xo, right_ref[0], k_chunk)

    @pl.when(pl.program_id(3) == n_k_steps - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bkb", "k_chunk", "interpret", "out_dtype"),
)
def threeway_batch_pop_pallas(
    Pown,
    PX,
    Pright,
    *,
    bm: int = DEFAULT_BM3,
    bn: int = DEFAULT_BN3,
    bkb: int = DEFAULT_BKB,
    k_chunk: int = K_CHUNK,
    interpret: bool = False,
    out_dtype=jnp.float32,
):
    """B[t, i, k] = sum_q popcount-min(own[q, i], X[q, t], right[q, k]) on
    binary packed planes.

    Pown (1, kb, m), PX (1, kb, L) pipeline columns, Pright (1, kb, n) ->
    (L, m, n); operands use the documented wire layout — on the plane-ring
    campaign path they are byte-range views of the ring payload, fed in
    unmodified.  One launch for the whole pipeline slice like
    ``threeway_batch_levels_pallas``."""
    levels, kb, m = Pown.shape
    assert levels == 1, Pown.shape
    L = PX.shape[2]
    n = Pright.shape[2]
    bkb = _word_align(bkb, k_chunk)
    mp, np_, kbp = (-m) % bm, (-n) % bn, (-kb) % bkb
    if mp or kbp:
        Pown = jnp.pad(Pown, ((0, 0), (0, kbp), (0, mp)))
    if kbp:
        PX = jnp.pad(PX, ((0, 0), (0, kbp), (0, 0)))
    if np_ or kbp:
        Pright = jnp.pad(Pright, ((0, 0), (0, kbp), (0, np_)))
    M, N, KB = m + mp, n + np_, kb + kbp
    n_k_steps = KB // bkb
    grid = (L, M // bm, N // bn, n_k_steps)
    out = pl.pallas_call(
        functools.partial(
            _threeway_pop_kernel, n_k_steps=n_k_steps, k_chunk=k_chunk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bkb, bm), lambda l, i, j, t: (0, t, i)),
            pl.BlockSpec((1, bkb, 1), lambda l, i, j, t: (0, t, l)),
            pl.BlockSpec((1, bkb, bn), lambda l, i, j, t: (0, t, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda l, i, j, t: (l, i, j)),
        out_shape=jax.ShapeDtypeStruct((L, M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(Pown, PX, Pright)
    return out[:, :m, :n]
