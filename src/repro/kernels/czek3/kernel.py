"""Pallas TPU kernel: fused 3-way inner step (paper §3.2, Algorithm 3).

One pipeline step of the 3-way computation:

    B_j[i, k] = sum_q combine(own[q, i], x[q], right[q, k])

where ``x = pipe[:, j]`` is the current pipeline column and ``combine`` is
the metric's elementwise pairing op (``min`` for Czekanowski, ``*`` for the
correlation family).  The paper materializes X_j = combine(V, v_j) and then
runs a 2-way mGEMM; this kernel fuses the X_j construction into the
contraction so X_j never touches HBM — eliminating one full (n_f x n_vp)
HBM write + read per pipeline step.

These kernels are NOT stand-alone demonstrations: the ``TileExecutor``
routes every 3-way pipeline slice of the distributed engine through them —
``threeway_batch_pallas`` under ``impl="pallas"`` (``path3 ==
"fused-vpu"``), ``threeway_batch_levels_pallas`` under ``impl="levels"``
(``path3 == "fused-levels"`` / ``"fused-levels-ring"``).  On the plane
ring the packed operands arrive exactly as ring-carried, with no per-slice
re-encode.

Plane-layout invariant: the packed-plane variant consumes the
(levels, kb, w) uint8 LSB-first layout specified in
docs/BITPLANE_FORMAT.md.  Its unpack helper and MXU accumulation
(``_plane_matmuls``) are imported from ``mgemm_levels.kernel`` — shared
with the 2-way plane kernels precisely so the bit layout and dot shapes
can never drift between the engines.

Value operands arrive field-major ((n_f, m) blocks), matching how the
distributed engine stores vector blocks, so the kernels contract over the
*leading* axis; plane operands put the same fields at 8-per-byte along
their middle (byte) axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# shared with the 2-way plane kernels so the bit layout and the MXU
# accumulation (dot shape, preferred_element_type) can never drift
from repro.kernels.mgemm_levels.kernel import DEFAULT_BKB, _plane_matmuls

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512
K_CHUNK = 8


def _threeway_kernel(
    own_ref, x_ref, right_ref, o_ref, acc_ref, *, n_k_steps, k_chunk, combine
):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    own = own_ref[...]  # (bk, bm)  field-major
    x = x_ref[...]  # (bk, 1)
    right = right_ref[...]  # (bk, bn)
    bk, bm = own.shape
    bn = right.shape[1]
    xo = combine(own, x)  # fused X_j tile — never written to HBM

    def body(t, acc):
        a_sub = jax.lax.dynamic_slice(xo, (t * k_chunk, 0), (k_chunk, bm))
        b_sub = jax.lax.dynamic_slice(right, (t * k_chunk, 0), (k_chunk, bn))
        m = combine(a_sub[:, :, None], b_sub[:, None, :]).astype(jnp.float32)
        return acc + m.sum(axis=0)

    acc_ref[...] += jax.lax.fori_loop(
        0, bk // k_chunk, body, jnp.zeros((bm, bn), jnp.float32)
    )

    @pl.when(pl.program_id(2) == n_k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("combine", "bm", "bn", "bk", "k_chunk", "interpret",
                     "out_dtype"),
)
def threeway_step_pallas(
    own,
    x,
    right,
    *,
    combine,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    k_chunk: int = K_CHUNK,
    interpret: bool = False,
    out_dtype=jnp.float32,
):
    """B[i, k] = sum_q combine(own[q, i], x[q], right[q, k]).

    own (n_f, m), x (n_f,) or (n_f, 1), right (n_f, n).  Valid for any
    metric whose 3-way term chains its elementwise ``combine`` (min-plus and
    product metrics both do — ``MetricSpec.combine_sum_contract``)."""
    if x.ndim == 1:
        x = x[:, None]
    k, m = own.shape
    n = right.shape[1]
    mp, np_, kp = (-m) % bm, (-n) % bn, (-k) % bk
    if mp or kp:
        own = jnp.pad(own, ((0, kp), (0, mp)))
    if kp:
        x = jnp.pad(x, ((0, kp), (0, 0)))
    if np_ or kp:
        right = jnp.pad(right, ((0, kp), (0, np_)))
    K, M = own.shape
    N = right.shape[1]
    n_k_steps = K // bk
    grid = (M // bm, N // bn, n_k_steps)
    out = pl.pallas_call(
        functools.partial(
            _threeway_kernel, n_k_steps=n_k_steps, k_chunk=k_chunk,
            combine=combine,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bm), lambda i, j, t: (t, i)),
            pl.BlockSpec((bk, 1), lambda i, j, t: (t, 0)),
            pl.BlockSpec((bk, bn), lambda i, j, t: (t, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(own, x, right)
    return out[:m, :n]


def _threeway_batch_kernel(
    own_ref, x_ref, right_ref, o_ref, acc_ref, *, n_k_steps, k_chunk, combine
):
    """Batched variant: grid axis 0 walks the pipeline columns, so a whole
    (n_fp, L) slice runs as ONE kernel launch (the accumulator still lives
    across the innermost K axis only)."""
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    own = own_ref[...]  # (bk, bm)
    x = x_ref[...]  # (bk, 1) — this grid step's pipeline column
    right = right_ref[...]  # (bk, bn)
    bk, bm = own.shape
    bn = right.shape[1]
    xo = combine(own, x)  # fused X_j tile — never written to HBM

    def body(t, acc):
        a_sub = jax.lax.dynamic_slice(xo, (t * k_chunk, 0), (k_chunk, bm))
        b_sub = jax.lax.dynamic_slice(right, (t * k_chunk, 0), (k_chunk, bn))
        m = combine(a_sub[:, :, None], b_sub[:, None, :]).astype(jnp.float32)
        return acc + m.sum(axis=0)

    acc_ref[...] += jax.lax.fori_loop(
        0, bk // k_chunk, body, jnp.zeros((bm, bn), jnp.float32)
    )

    @pl.when(pl.program_id(3) == n_k_steps - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("combine", "bm", "bn", "bk", "k_chunk", "interpret",
                     "out_dtype"),
)
def threeway_batch_pallas(
    own,
    X,
    right,
    *,
    combine,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    k_chunk: int = K_CHUNK,
    interpret: bool = False,
    out_dtype=jnp.float32,
):
    """B[t, i, k] = sum_q combine(own[q, i], X[q, t], right[q, k]).

    own (n_f, m), X (n_f, L) pipeline columns, right (n_f, n) -> (L, m, n).
    One launch for the whole pipeline slice: the grid is (L, m/bm, n/bn,
    K/bk), so trace/compile cost is O(1) in L instead of L separate
    pallas_calls."""
    k, m = own.shape
    L = X.shape[1]
    n = right.shape[1]
    mp, np_, kp = (-m) % bm, (-n) % bn, (-k) % bk
    if mp or kp:
        own = jnp.pad(own, ((0, kp), (0, mp)))
    if kp:
        X = jnp.pad(X, ((0, kp), (0, 0)))
    if np_ or kp:
        right = jnp.pad(right, ((0, kp), (0, np_)))
    K, M = own.shape
    N = right.shape[1]
    n_k_steps = K // bk
    grid = (L, M // bm, N // bn, n_k_steps)
    out = pl.pallas_call(
        functools.partial(
            _threeway_batch_kernel, n_k_steps=n_k_steps, k_chunk=k_chunk,
            combine=combine,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bm), lambda l, i, j, t: (t, i)),
            pl.BlockSpec((bk, 1), lambda l, i, j, t: (t, l)),
            pl.BlockSpec((bk, bn), lambda l, i, j, t: (t, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda l, i, j, t: (l, i, j)),
        out_shape=jax.ShapeDtypeStruct((L, M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(own, X, right)
    return out[:, :m, :n]


# -- packed bit-plane variant (level-decomposed min on the MXU) --------------
#
# For leveled integer data, min(a, x, b) = sum_t 1[a>=t] 1[x>=t] 1[b>=t]:
# the X_j = min(own, x) tile is a bitwise AND of *packed* plane bytes (one
# VPU op per 8 fields, still never written to HBM), and the contraction is
# ``levels`` MXU dot_generals per K-tile — the 3-way analogue of
# ``mgemm_levels.metric2_levels_pallas``, sharing its unpack helper
# (imported at top) so the plane kernels can never disagree on bit layout.


def _threeway_levels_kernel(
    own_ref, x_ref, right_ref, o_ref, acc_ref, *, n_k_steps, levels
):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # packed AND == plane of min(own, x); x (levels, bkb, 1) broadcasts
    xo = own_ref[...] & x_ref[...]
    acc_ref[...] += _plane_matmuls(xo, right_ref[...], levels)

    @pl.when(pl.program_id(3) == n_k_steps - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bkb", "interpret", "out_dtype"),
)
def threeway_batch_levels_pallas(
    Pown,
    PX,
    Pright,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bkb: int = DEFAULT_BKB,
    interpret: bool = False,
    out_dtype=jnp.float32,
):
    """B[t, i, k] = sum_q min(own[q, i], X[q, t], right[q, k]) on packed
    bit-planes.

    Pown (levels, kb, m), PX (levels, kb, L) pipeline columns, Pright
    (levels, kb, n) -> (L, m, n); operands use the documented wire layout
    (docs/BITPLANE_FORMAT.md) — on the plane-ring campaign path they are
    byte-range views of the ring payload, fed in unmodified.  Exact for
    leveled integer data; one launch for the whole pipeline slice like
    ``threeway_batch_pallas``."""
    levels, kb, m = Pown.shape
    L = PX.shape[2]
    n = Pright.shape[2]
    mp, np_, kbp = (-m) % bm, (-n) % bn, (-kb) % bkb
    if mp or kbp:
        Pown = jnp.pad(Pown, ((0, 0), (0, kbp), (0, mp)))
    if kbp:
        PX = jnp.pad(PX, ((0, 0), (0, kbp), (0, 0)))
    if np_ or kbp:
        Pright = jnp.pad(Pright, ((0, 0), (0, kbp), (0, np_)))
    M, N, KB = m + mp, n + np_, kb + kbp
    n_k_steps = KB // bkb
    grid = (L, M // bm, N // bn, n_k_steps)
    out = pl.pallas_call(
        functools.partial(
            _threeway_levels_kernel, n_k_steps=n_k_steps, levels=levels,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((levels, bkb, bm), lambda l, i, j, t: (0, t, i)),
            pl.BlockSpec((levels, bkb, 1), lambda l, i, j, t: (0, t, l)),
            pl.BlockSpec((levels, bkb, bn), lambda l, i, j, t: (0, t, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda l, i, j, t: (l, i, j)),
        out_shape=jax.ShapeDtypeStruct((L, M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(Pown, PX, Pright)
    return out[:, :m, :n]
