"""Quickstart: all-pairs + all-triples Proportional Similarity in 30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.synthetic import random_integer_vectors
from repro.core.twoway import CometConfig, czek2_distributed
from repro.core.threeway import czek3_distributed
from repro.parallel.mesh import make_comet_mesh


def main():
    # 200 vectors of 128 fields — think "SNP profiles" or "metabolite peaks"
    V = random_integer_vectors(n_f=128, n_v=198, max_value=15, seed=7)
    mesh = make_comet_mesh(1, 1, 1)  # single device; scales via (pf, pv, pr)
    cfg = CometConfig(out_dtype="float32")

    out2 = czek2_distributed(V, mesh, cfg)
    print(f"2-way: {out2.num_pairs()} unique pairs, checksum {hex(out2.checksum())[:18]}")
    pairs = [(i, j, w) for I, J, W in out2.entries() for i, j, w in zip(I, J, W)]
    for i, j, w in sorted(pairs, key=lambda t: -t[2])[:5]:
        print(f"  most similar: v{i} ~ v{j}  c2={w:.4f}")

    # 3-way on a subset (O(n^3) results!)
    out3 = czek3_distributed(V[:, :48], mesh, cfg, stage=0)
    print(f"3-way: {out3.num_triples()} unique triples, "
          f"checksum {hex(out3.checksum())[:18]}")
    triples = [
        (i, j, k, w)
        for I, J, K, W in out3.entries()
        for i, j, k, w in zip(I, J, K, W)
    ]
    for i, j, k, w in sorted(triples, key=lambda t: -t[3])[:5]:
        print(f"  most similar: (v{i}, v{j}, v{k})  c3={w:.4f}")


if __name__ == "__main__":
    main()
