"""Batched serving engine: prefill + greedy/temperature decode with KV (or
SSM-state) caches, per-sequence stopping, and a request queue.

The decode loop is a single jit'd step over the full batch (static shapes);
finished sequences keep decoding into a scratch slot but their outputs are
frozen — the standard static-batch serving pattern.  Continuous batching at
pod scale would swap finished rows for queued requests at step granularity;
the cache layout (batch-major leaves) supports that, and `swap_row` is the
hook (used by tests).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.models.common import ModelConfig
from repro.parallel.sharding import use_mesh


@dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 -> greedy
    eos_id: int = 2
    seed: int = 0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig | None = None,
                 mesh=None):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg or ServeConfig()
        self.mesh = mesh
        self._decode = jax.jit(
            lambda p, c, t, i: api.decode_step(cfg, p, c, t, i)
        )

    def _prefill(self, tokens):
        """Feed the prompt one block at a time through decode steps.

        For attention archs this fills the KV cache; a production prefill
        would batch the whole prompt (see launch/dryrun.py's prefill_step —
        the serving engine here favors simplicity on CPU)."""
        B, P = tokens.shape
        cache = api.init_cache(
            self.cfg, self.params, B, P + self.scfg.max_new_tokens
        )
        logits = None
        for i in range(P):
            logits, cache = self._decode(
                self.params, cache, tokens[:, i : i + 1], i
            )
        return logits, cache, P

    def generate(self, prompts: np.ndarray) -> np.ndarray:
        """prompts (B, P) int32 -> (B, max_new_tokens) int32."""
        scfg = self.scfg
        with use_mesh(self.mesh):
            logits, cache, pos = self._prefill(jnp.asarray(prompts))
            B = prompts.shape[0]
            out = np.zeros((B, scfg.max_new_tokens), np.int32)
            done = np.zeros((B,), bool)
            key = jax.random.PRNGKey(scfg.seed)
            tok = self._sample(logits, key)
            for t in range(scfg.max_new_tokens):
                out[:, t] = np.where(done, 0, np.asarray(tok[:, 0]))
                done |= np.asarray(tok[:, 0]) == scfg.eos_id
                if done.all():
                    break
                logits, cache = self._decode(self.params, cache, tok, pos + t)
                key, sub = jax.random.split(key)
                tok = self._sample(logits, sub)
        return out

    def _sample(self, logits, key):
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        scaled = logits[:, -1, :] / self.scfg.temperature
        return jax.random.categorical(key, scaled)[:, None].astype(jnp.int32)
