"""repro.stream: out-of-core streamed campaigns.

Pins the streaming acceptance contract (ISSUE / docs/BITPLANE_FORMAT.md
"Cross-shard merge"):

* streamed 2-way AND 3-way campaigns are BIT-IDENTICAL (checksum) to
  in-memory runs — across shard counts (1, 2), chunk/shard-mismatched
  budgets (chunks crossing disk shard boundaries), and non-multiple-of-8
  field counts (hypothesis);
* ``StreamPlan`` geometry: chunk_kb is a positive n_pf multiple, chunks
  tile the payload byte axis exactly, spans reassemble the global payload,
  ``peak_host_bytes`` respects ``max_host_bytes`` and an impossible budget
  raises (naming the minimum) instead of overshooting;
* streamed campaigns never run the host encoder (counter monkeypatch) and
  never stage more than the budget (``meta["stream"]`` accounting);
* ``ShardPrefetcher`` propagates worker errors to the consumer and never
  leaks its thread — error, early-exit, and normal paths all join;
* the n_pf > 1 fused-levels merge path (raw kernel numerator + merge
  epilogue) is bit-identical to the in-kernel epilogue and to the unfused
  XLA assembly.

Multi-device decompositions (n_pf=2 chunks, streamed meshes) are covered
in tests/distributed_harness.py.
"""
import os
import threading

import numpy as np
import pytest

import repro.kernels.mgemm_levels as mgemm_levels
from repro.api import InputSpec, SimilarityEngine, SimilarityRequest
from repro.core.synthetic import random_integer_vectors
from repro.core.threeway import czek3_distributed
from repro.core.twoway import CometConfig, czek2_distributed, resolve_config
from repro.parallel.mesh import make_comet_mesh
from repro.store import write_dataset
from repro.stream import (
    ShardPrefetcher,
    StreamPlan,
    fill_chunk,
    stream_threeway,
    stream_twoway,
)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

LEVELS = 3


def _matrix(n_f, n_v, seed=0):
    return random_integer_vectors(n_f, n_v, max_value=LEVELS, seed=seed)


def _write(tmp_path, V, n_shards, name="ds"):
    path = os.path.join(str(tmp_path), name)
    write_dataset(path, V, levels=LEVELS, n_shards=n_shards)
    return path


# -- StreamPlan geometry -----------------------------------------------------


def test_stream_plan_default_is_shard_per_chunk():
    p = StreamPlan.plan(levels=3, kb=8, kbs=4, n_shards=2, n_v=16,
                        n_v_data=10)
    assert p.chunk_kb == 4 and p.n_chunks == 2
    assert p.chunk_shape == (3, 4, 16)
    assert p.n_buffers == 2
    assert p.peak_host_bytes == 2 * 3 * 4 * 16


def test_stream_plan_single_chunk_single_buffer():
    p = StreamPlan.plan(levels=3, kb=4, kbs=4, n_shards=1, n_v=8,
                        n_v_data=8)
    assert p.n_chunks == 1 and p.n_buffers == 1
    assert p.peak_host_bytes == p.chunk_nbytes


@pytest.mark.parametrize("n_pf", [1, 2, 4])
def test_stream_plan_budget_math(n_pf):
    levels, n_v, kb = 3, 16, 32
    budget = 2 * levels * n_v * (3 * n_pf) + 5  # fits 3*n_pf bytes/chunk
    p = StreamPlan.plan(levels=levels, kb=kb, kbs=kb, n_shards=1, n_v=n_v,
                        n_v_data=n_v, n_pf=n_pf, max_host_bytes=budget)
    assert p.chunk_kb % n_pf == 0 and p.chunk_kb >= n_pf
    assert p.peak_host_bytes <= budget
    # largest feasible chunk: one byte more per chunk would overshoot
    assert 2 * levels * n_v * (p.chunk_kb + n_pf) > budget


def test_stream_plan_budget_too_small_raises():
    with pytest.raises(ValueError, match="cannot stage two"):
        StreamPlan.plan(levels=3, kb=8, kbs=8, n_shards=1, n_v=16,
                        n_v_data=16, n_pf=2, max_host_bytes=100)


def test_stream_plan_chunks_tile_payload_across_shards():
    # chunk_kb=3 vs kbs=2: chunks cross disk shard boundaries
    p = StreamPlan(levels=2, kb=8, kbs=2, n_shards=4, n_v=8, n_v_data=8,
                   n_pf=1, chunk_kb=3)
    chunks = p.chunks()
    assert [c.start for c in chunks] == [0, 3, 6]
    assert chunks[-1].stop == 8
    for c in chunks:
        off = 0
        g = c.start
        for rank, lo, hi, buf_off in c.spans:
            assert buf_off == off and 0 <= lo < hi <= p.kbs
            assert rank * p.kbs + lo == g  # spans are globally contiguous
            off += hi - lo
            g += hi - lo
        assert g == c.stop
    assert chunks[0].spans[0][0] == 0 and len(chunks[0].spans) == 2


def test_stream_plan_rejects_misaligned_chunk():
    with pytest.raises(ValueError, match="multiple of"):
        StreamPlan(levels=2, kb=8, kbs=8, n_shards=1, n_v=8, n_v_data=8,
                   n_pf=2, chunk_kb=3)


def test_fill_chunk_reassembles_payload():
    rng = np.random.default_rng(0)
    levels, kb, kbs, n_v = 2, 10, 5, 6
    payload = rng.integers(0, 256, (levels, kb, n_v)).astype(np.uint8)
    shards = [payload[:, r * kbs:(r + 1) * kbs, :] for r in range(2)]
    p = StreamPlan(levels=levels, kb=kb, kbs=kbs, n_shards=2, n_v=n_v + 2,
                   n_v_data=n_v, n_pf=1, chunk_kb=4)
    buf = np.full(p.chunk_shape, 0xFF, np.uint8)
    got = np.zeros((levels, p.n_chunks * p.chunk_kb, n_v + 2), np.uint8)
    for c in p.chunks():
        buf[:, :, :n_v] = 0xFF  # staging buffers are REUSED; fill must win
        fill_chunk(buf, c, lambda r: shards[r], n_v)
        got[:, c.start:c.start + p.chunk_kb] = buf
    np.testing.assert_array_equal(got[:, :kb, :n_v], payload)
    assert not got[:, kb:, :].any()  # tail chunk zero-padded (all columns)
    # padding columns in valid rows are never written by fill (the pipeline
    # zeroes them once at allocation) — the sentinel survives
    assert (got[:, :kb, n_v:] == 0xFF).all()


# -- streamed == in-memory (bit-identical checksums) -------------------------


@pytest.mark.parametrize("n_shards,budget", [
    (1, 0),      # single shard, streamed explicitly
    (2, 0),      # shard-per-chunk default
    # tight budget: chunk_kb=3 vs kbs=4 — chunks cross shard boundaries
    # (budget_kb = 250 // (2 * 3 * 12) = 3)
    (2, 250),
])
def test_streamed_matches_inmemory(tmp_path, n_shards, budget):
    n_f, n_v = 64, 12  # kb=8: divides both shard counts; n_v % 6 == 0
    V = _matrix(n_f, n_v)
    path = _write(tmp_path, V, n_shards, f"ds{n_shards}_{budget}")
    mesh = make_comet_mesh(1, 1, 1)
    cfg = CometConfig(impl="levels", levels=LEVELS, streaming="on",
                      max_host_bytes=budget)
    ref2 = czek2_distributed(V, mesh, CometConfig()).checksum()
    ref3 = czek3_distributed(V, mesh, CometConfig(), stage=0).checksum()

    out2, info2 = stream_twoway(path, mesh, cfg)
    assert out2.checksum() == ref2
    out3, info3 = stream_threeway(path, mesh, cfg, stage=0)
    assert out3.checksum() == ref3

    for info in (info2, info3):
        assert info["n_shards"] == n_shards
        if budget:
            assert info["peak_host_bytes"] <= budget
            assert info["staged_bytes"] <= budget
            assert info["chunks"] > n_shards  # budget forced sub-shard chunks


if HAVE_HYPOTHESIS:

    @settings(max_examples=5, deadline=None)
    @given(n_f=st.integers(9, 40).filter(lambda n: n % 8),
           seed=st.integers(0, 2**16))
    def test_streamed_nonmultiple_of_8_fields(tmp_path_factory, n_f, seed):
        """Partial trailing bytes in the packed planes stay inert when the
        byte axis is chunked (zero bits encode zero fields)."""
        n_v = 6
        V = _matrix(n_f, n_v, seed=seed)
        tmp = tmp_path_factory.mktemp("stream_hyp")
        path = _write(tmp, V, 1, f"ds{n_f}_{seed}")
        mesh = make_comet_mesh(1, 1, 1)
        # 2-byte chunks: levels * n_v * 2 bytes double-buffered
        cfg = CometConfig(impl="levels", levels=LEVELS, streaming="on",
                          max_host_bytes=2 * LEVELS * n_v * 2)
        out, info = stream_twoway(path, mesh, cfg)
        ref = czek2_distributed(V, mesh, CometConfig()).checksum()
        assert out.checksum() == ref, f"n_f={n_f} chunks={info['chunks']}"


# -- engine dispatch: auto streaming, zero-encode, accounting ----------------


def test_engine_streams_and_never_encodes(tmp_path, monkeypatch):
    V = _matrix(64, 12)
    path = _write(tmp_path, V, 2)
    engine = SimilarityEngine()
    spec = InputSpec(source="planes", path=path)

    calls = {"n": 0}
    orig = mgemm_levels.encode_bitplanes_np

    def counted(*args, **kwargs):
        calls["n"] += 1
        return orig(*args, **kwargs)

    monkeypatch.setattr(mgemm_levels, "encode_bitplanes_np", counted)
    for way in (2, 3):
        want = engine.run(
            SimilarityRequest(way=way, impl="levels", levels=LEVELS), V
        ).checksum()
        assert calls["n"] > 0  # the in-memory run DID encode
        calls["n"] = 0
        res = engine.run(SimilarityRequest(
            way=way, impl="levels", levels=LEVELS, input=spec,
            max_host_bytes=400,
        ))
        assert calls["n"] == 0, "streamed campaign ran the host encoder"
        assert res.checksum() == want
        # multi-shard source="planes" resolves streaming="auto" -> on
        stream = res.meta["stream"]
        assert stream["chunks"] >= 2 and stream["n_shards"] == 2
        assert stream["staged_bytes"] <= 400
        assert stream["peak_host_bytes"] <= 400


def test_engine_streaming_off_matches_streamed(tmp_path):
    V = _matrix(64, 12)
    path = _write(tmp_path, V, 2)
    engine = SimilarityEngine()
    spec = InputSpec(source="planes", path=path)
    base = dict(way=2, impl="levels", levels=LEVELS, input=spec)
    on = engine.run(SimilarityRequest(streaming="on", **base))
    off = engine.run(SimilarityRequest(streaming="off", **base))
    assert "stream" in on.meta and "stream" not in off.meta
    assert on.checksum() == off.checksum()


def test_streaming_request_validation(tmp_path):
    with pytest.raises(ValueError, match="streaming"):
        SimilarityRequest(streaming="sometimes").validate()
    with pytest.raises(ValueError, match="max_host_bytes"):
        SimilarityRequest(max_host_bytes=-1).validate()
    with pytest.raises(ValueError, match="store-backed"):
        SimilarityRequest(
            streaming="on",
            input=InputSpec(source="synthetic", n_f=8, n_v=8),
        ).validate()
    # resolve_config: a resident value matrix cannot stream
    with pytest.raises(ValueError, match="store-backed"):
        from repro.core.metric_spec import CZEKANOWSKI
        resolve_config(CometConfig(streaming="on"), _matrix(8, 8),
                       CZEKANOWSKI)


# -- prefetcher lifecycle ----------------------------------------------------


def _prefetch_threads():
    return [t for t in threading.enumerate()
            if t.name == "repro-stream-prefetch" and t.is_alive()]


def test_prefetcher_propagates_fill_error_and_joins():
    buffers = [np.zeros(4, np.uint8) for _ in range(2)]

    def fill(idx, buf):
        if idx == 1:
            raise RuntimeError("disk on fire")
        buf[:] = idx

    seen = []
    with pytest.raises(RuntimeError, match="disk on fire"):
        with ShardPrefetcher(fill, 4, buffers) as pf:
            for idx, buf in pf:
                seen.append(idx)
                pf.release(buf)
    assert seen == [0]
    assert not _prefetch_threads(), "worker thread leaked after fill error"


def test_prefetcher_consumer_abort_joins():
    buffers = [np.zeros(4, np.uint8) for _ in range(2)]

    def fill(idx, buf):
        buf[:] = idx

    with ShardPrefetcher(fill, 100, buffers) as pf:
        for idx, buf in pf:
            break  # consumer bails without draining or releasing
    assert not _prefetch_threads(), "worker thread leaked after early exit"


def test_prefetcher_orders_items_and_bounds_lookahead():
    buffers = [np.zeros(1, np.uint8) for _ in range(2)]
    in_flight = {"now": 0, "max": 0}
    lock = threading.Lock()

    def fill(idx, buf):
        with lock:
            in_flight["now"] += 1
            in_flight["max"] = max(in_flight["max"], in_flight["now"])
        buf[0] = idx

    got = []
    with ShardPrefetcher(fill, 8, buffers) as pf:
        for idx, buf in pf:
            assert buf[0] == idx
            got.append(idx)
            with lock:
                in_flight["now"] -= 1
            pf.release(buf)
    assert got == list(range(8))
    # two buffers => never more than two chunks staged at once
    assert in_flight["max"] <= 2


# -- n_pf > 1 merge epilogue == in-kernel epilogue (executor level) ----------


def test_merge_pair_bitwise_matches_fused_and_unfused():
    from repro.core.tile_executor import TileExecutor

    V = _matrix(40, 16)  # non-multiple-of-8 fields
    A, B = V[:, :8], V[:, 8:]
    sa, sb = A.sum(axis=0), B.sum(axis=0)
    mk = lambda **kw: TileExecutor(
        cfg=CometConfig(impl="levels", levels=LEVELS, **kw), axis=None
    )
    fused = mk()
    merged = mk(n_pf=2)  # psum over "pf" is the identity at axis=None
    unfused = TileExecutor(cfg=CometConfig(impl="xla"), axis=None)
    assert fused.path == "fused-levels" and fused.path_reason == ""
    assert merged.path == "fused-levels"
    assert "merge epilogue" in merged.path_reason

    for diag, (Vb, s2) in {False: (B, sb), True: (A, sa)}.items():
        want = np.asarray(fused.pair_block(A, sa, Vb, s2, diagonal=diag))
        got = np.asarray(merged.pair_block(A, sa, Vb, s2, diagonal=diag))
        xla = np.asarray(unfused.pair_block(A, sa, Vb, s2, diagonal=diag))
        np.testing.assert_array_equal(got, want)
        np.testing.assert_array_equal(got, xla)
        # and merge_pair applied to the raw partial is the same assembly
        n2 = merged.pair_partial(A, Vb)
        manual = np.asarray(merged.merge_pair(n2, sa, s2, diagonal=diag))
        np.testing.assert_array_equal(manual, want)
