"""Checksum contract tests (paper §5 validation machinery)."""
import numpy as np

from repro.core import checksum as ck


def _pairs(n=50, seed=0):
    rng = np.random.default_rng(seed)
    i, j = np.triu_indices(12, k=1)
    v = rng.random(len(i)).astype(np.float32)
    return i, j, v


def test_order_invariance():
    i, j, v = _pairs()
    a = ck.checksum_pairs(i, j, v)
    perm = np.random.default_rng(1).permutation(len(i))
    b = ck.checksum_pairs(i[perm], j[perm], v[perm])
    assert a == b


def test_index_canonicalization():
    i, j, v = _pairs()
    assert ck.checksum_pairs(i, j, v) == ck.checksum_pairs(j, i, v)


def test_single_ulp_sensitivity():
    i, j, v = _pairs()
    a = ck.checksum_pairs(i, j, v)
    v2 = v.copy()
    v2[3] = np.nextafter(v2[3], np.float32(np.inf))
    assert a != ck.checksum_pairs(i, j, v2)


def test_missing_and_duplicate_sensitivity():
    i, j, v = _pairs()
    a = ck.checksum_pairs(i, j, v)
    assert a != ck.checksum_pairs(i[:-1], j[:-1], v[:-1])
    i2 = np.concatenate([i, i[:1]])
    j2 = np.concatenate([j, j[:1]])
    v2 = np.concatenate([v, v[:1]])
    assert a != ck.checksum_pairs(i2, j2, v2)


def test_combine_matches_monolithic():
    i, j, v = _pairs()
    whole = ck.checksum_pairs(i, j, v)
    parts = [ck.raw_pairs(i[:20], j[:20], v[:20]), ck.raw_pairs(i[20:], j[20:], v[20:])]
    assert ck.combine(parts) == whole


def test_triples_order_and_canonicalization():
    rng = np.random.default_rng(2)
    idx = np.array([(a, b, c) for a in range(6) for b in range(a + 1, 6) for c in range(b + 1, 6)])
    v = rng.random(len(idx)).astype(np.float64)
    a = ck.checksum_triples(idx[:, 0], idx[:, 1], idx[:, 2], v)
    # permute entry order and scramble index order within each triple
    b = ck.checksum_triples(idx[:, 2], idx[:, 0], idx[:, 1], v)
    assert a == b
    parts = [
        ck.raw_triples(idx[:7, 0], idx[:7, 1], idx[:7, 2], v[:7]),
        ck.raw_triples(idx[7:, 0], idx[7:, 1], idx[7:, 2], v[7:]),
    ]
    assert ck.combine(parts) == a
