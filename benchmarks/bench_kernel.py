"""Paper Table 1: mGEMM kernel vs standard GEMM (single device).

The paper compares modified-MAGMA mGEMM against cuBLAS GEMM on a K20X
(mGEMM within ~2.5x of GEMM-achievable).  Here: XLA min-plus contraction vs
jnp.dot at the same (scaled) shape on CPU, plus the beyond-paper level-
decomposition path which turns the min-plus contraction back into GEMMs —
the v5e projection (MXU vs VPU pricing) is derived in EXPERIMENTS.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import row, time_fn
from repro.core.mgemm import mgemm_xla
from repro.kernels.mgemm_levels.ops import mgemm_levels_xla

# paper shape n_v=10240, n_f=12288 scaled /8 to stay CPU-friendly
M = N = 1280
K = 1536


def main():
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.integers(0, 3, (M, K)).astype(np.float32))
    B = jnp.asarray(rng.integers(0, 3, (K, N)).astype(np.float32))

    t_gemm = time_fn(jax.jit(lambda a, b: a @ b), A, B)
    t_mgemm = time_fn(lambda a, b: mgemm_xla(a, b), A, B)
    t_levels = time_fn(lambda a, b: mgemm_levels_xla(a, b, levels=2), A, B)

    ops = 2 * M * K * N
    rows = [
        row("table1/gemm", t_gemm, f"{ops / t_gemm / 1e9:.2f}_GOps"),
        row("table1/mgemm_minplus", t_mgemm,
            f"{ops / t_mgemm / 1e9:.2f}_GOps_ratio={t_mgemm / t_gemm:.2f}x"),
        row("table1/mgemm_levels_L2", t_levels,
            f"{ops / t_levels / 1e9:.2f}_GOps_ratio={t_levels / t_gemm:.2f}x"),
    ]
    return rows


if __name__ == "__main__":
    from benchmarks.util import print_rows

    print_rows(main())
