"""seamless-m4t-large-v2 [audio] — arXiv:2308.11596 (hf-verified).

24L d_model=1024 16H (kv=16) d_ff=8192 vocab=256206, enc-dec multimodal.
The backbone is encoder(24L, speech-frame embeddings from the STUB frontend)
+ causal text decoder(24L) with cross-attention.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,  # decoder layers
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    head_dim=64,
)

SMOKE = CONFIG.replace(
    name="seamless-m4t-large-v2-smoke",
    n_layers=2,
    n_enc_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
)
