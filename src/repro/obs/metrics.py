"""Process-wide metrics registry: counters, gauges, latency histograms.

The consistency contract (pinned by the ``test_serve.py`` concurrency
battery): every metric belonging to one registry mutates under the
registry's single re-entrant lock, and ``snapshot()`` reads them all
under that same lock — so a snapshot taken mid-flight is internally
consistent (e.g. ``hits + misses + in_flight == submitted`` holds in
EVERY snapshot, never just at quiescence).  Multi-metric updates that
must be atomic as a group run inside ``with registry.locked():``.

Histograms keep raw observations (bounded ring of the most recent
``max_samples``) so percentiles are exact over the retained window —
right for serving latencies at campaign granularity, not for per-element
hot loops.
"""
from __future__ import annotations

import math
import threading

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "default_registry",
]


class Counter:
    """Monotonically increasing count."""

    def __init__(self, lock):
        self._lock = lock
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._value

    def snapshot(self):
        return self._value  # caller holds the registry lock


class Gauge:
    """Point-in-time level (queue depth, in-flight campaigns)."""

    def __init__(self, lock):
        self._lock = lock
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = v

    def inc(self, n: float = 1) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: float = 1) -> None:
        with self._lock:
            self._value -= n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def snapshot(self):
        return self._value


class Histogram:
    """Latency histogram with exact percentiles over a bounded window."""

    def __init__(self, lock, max_samples: int = 4096):
        self._lock = lock
        self._max = max_samples
        self._samples = []
        self._next = 0  # ring-buffer write head once the window is full
        self._count = 0
        self._sum = 0.0

    def observe(self, v: float) -> None:
        with self._lock:
            self._count += 1
            self._sum += v
            if len(self._samples) < self._max:
                self._samples.append(v)
            else:
                self._samples[self._next] = v
                self._next = (self._next + 1) % self._max

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile (p in [0, 100]) over the window; 0.0
        when empty."""
        with self._lock:
            return self._percentile_locked(p)

    def _percentile_locked(self, p: float) -> float:
        if not self._samples:
            return 0.0
        s = sorted(self._samples)
        rank = max(1, math.ceil(p / 100.0 * len(s)))  # nearest-rank
        return s[min(rank, len(s)) - 1]

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def snapshot(self):
        return {
            "count": self._count,
            "sum": self._sum,
            "mean": (self._sum / self._count) if self._count else 0.0,
            "p50": self._percentile_locked(50),
            "p90": self._percentile_locked(90),
            "p99": self._percentile_locked(99),
            "max": max(self._samples) if self._samples else 0.0,
        }


class MetricsRegistry:
    """Named metrics sharing ONE lock; ``snapshot()`` is consistent."""

    def __init__(self):
        self._lock = threading.RLock()
        self._metrics = {}

    def _get(self, name, cls, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(self._lock, **kw)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} is {type(m).__name__}, not {cls.__name__}"
                )
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, max_samples: int = 4096) -> Histogram:
        return self._get(name, Histogram, max_samples=max_samples)

    def locked(self):
        """Context manager: hold the registry lock across a multi-metric
        update so no snapshot can observe it half-applied."""
        return self._lock

    def snapshot(self) -> dict:
        """One consistent view of every registered metric."""
        with self._lock:
            return {name: m.snapshot() for name, m in self._metrics.items()}


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide registry (components may also own private ones —
    ``SimilarityService`` does, so tests and services never share state)."""
    return _DEFAULT
