"""Synthetic vector datasets — paper §5.

Two generator families, mirroring the paper's test design:

1. ``random_integer_vectors`` — every entry a random small integer.  Integer
   values make fp sums *exact* (order-independent) as long as
   ``n_f * max_value`` stays below the mantissa limit, which is what lets the
   paper (and us) demand **bit-for-bit identical results across parallel
   decompositions** and verify with an exact checksum.

2. ``analytic_window_vectors`` — "randomized placement of entries specifically
   chosen so that the correctness of every result value can be verified
   analytically".  Our construction: vector i is the indicator of a circular
   window of width w starting at offset ``perm[i] * stride`` in [0, n_f).
   Then  n2(i, j)   = circular overlap of two windows  (closed form)
         n3'(i,j,k) = circular overlap of three windows (closed form)
   so every metric value is known without an O(n^2)/O(n^3) reference run.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = [
    "random_integer_vectors",
    "AnalyticWindows",
    "analytic_window_vectors",
]


def random_integer_vectors(
    n_f: int, n_v: int, *, max_value: int = 15, seed: int = 0, dtype=np.float32
) -> np.ndarray:
    """(n_f, n_v) matrix of integers in [0, max_value], fp-exact summable."""
    # mantissa guard: exact integer accumulation requires n_f * max_value to be
    # representable exactly: 2^24 for f32, 2^53 for f64.
    limit = 2 ** (24 if dtype == np.float32 else 53)
    assert n_f * max_value < limit, "sums would lose exactness"
    rng = np.random.default_rng(seed)
    return rng.integers(0, max_value + 1, size=(n_f, n_v)).astype(dtype)


def _circ_overlap(starts: np.ndarray, w: int, n_f: int) -> np.ndarray:
    """Overlap size of circular windows [s, s+w) for every pair of starts.

    starts: (..., 2) int array -> (...) overlap counts.  Requires 2*w <= n_f
    so each pair of windows overlaps in at most one circular run.
    """
    a = starts[..., 0]
    b = starts[..., 1]
    d = np.abs(a - b)
    d = np.minimum(d, n_f - d)  # circular distance
    return np.maximum(0, w - d)


@dataclass(frozen=True)
class AnalyticWindows:
    """Parameters of the analytic dataset + closed-form metric values."""

    n_f: int
    n_v: int
    width: int
    starts: np.ndarray  # (n_v,) window start offsets
    value: float  # constant entry value inside the window

    def n2(self, i, j) -> np.ndarray:
        s = np.stack([self.starts[np.asarray(i)], self.starts[np.asarray(j)]], -1)
        return self.value * _circ_overlap(s, self.width, self.n_f)

    def nprime3(self, i, j, k) -> np.ndarray:
        """Triple overlap: windows are intervals; use pairwise min overlap.

        For circular windows of equal width with 2*w <= n_f, the triple
        intersection is the min over the three pairwise intersections if the
        three windows share a common point, else 0.  With equal widths the
        common-point condition is implied when all three pairwise overlaps are
        positive and the windows are "aligned"; we compute it exactly from
        interval arithmetic on the unrolled circle instead of guessing.
        """
        i, j, k = (np.asarray(x) for x in (i, j, k))
        si, sj, sk = self.starts[i], self.starts[j], self.starts[k]
        w, n = self.width, self.n_f
        # unroll: a circular window [s, s+w) intersected with others — try all
        # shifts of +-n for j and k relative to i.
        best = np.zeros(np.broadcast_shapes(si.shape, sj.shape, sk.shape), np.int64)
        for dj in (-n, 0, n):
            for dk in (-n, 0, n):
                lo = np.maximum(np.maximum(si, sj + dj), sk + dk)
                hi = np.minimum(np.minimum(si, sj + dj), sk + dk) + w
                best = np.maximum(best, np.maximum(0, hi - lo))
        return self.value * best

    def sums(self) -> np.ndarray:
        return np.full(self.n_v, self.value * self.width)

    def c2(self, i, j) -> np.ndarray:
        return 2.0 * self.n2(i, j) / (self.value * 2 * self.width)

    def c3(self, i, j, k) -> np.ndarray:
        n3 = self.n2(i, j) + self.n2(i, k) + self.n2(j, k) - self.nprime3(i, j, k)
        return 1.5 * n3 / (self.value * 3 * self.width)


def analytic_window_vectors(
    n_f: int,
    n_v: int,
    *,
    width: int | None = None,
    value: float = 1.0,
    seed: int = 0,
    dtype=np.float32,
) -> tuple[np.ndarray, AnalyticWindows]:
    """Build the analytic dataset. Returns (V, AnalyticWindows)."""
    width = width if width is not None else max(1, n_f // 4)
    assert 2 * width <= n_f, "need 2*w <= n_f for single-run circular overlap"
    rng = np.random.default_rng(seed)
    starts = rng.integers(0, n_f, size=n_v)
    V = np.zeros((n_f, n_v), dtype=dtype)
    idx = (starts[None, :] + np.arange(width)[:, None]) % n_f  # (w, n_v)
    V[idx, np.arange(n_v)[None, :]] = value
    return V, AnalyticWindows(n_f=n_f, n_v=n_v, width=width, starts=starts, value=value)
