"""Property-based tests (hypothesis) on the system's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import checksum as ck  # noqa: E402
from repro.core.mgemm import mgemm_xla  # noqa: E402
from repro.core.metrics import czek2_metric_np  # noqa: E402
from repro.core.plan2 import TwoWayPlan, global_pairs_of_block  # noqa: E402
from repro.core.plan3 import ThreeWayPlan  # noqa: E402
from repro.core.synthetic import analytic_window_vectors  # noqa: E402
from repro.kernels.mgemm_levels.ref import mgemm_levels_ref  # noqa: E402
from repro.optim.compression import dequantize, quantize  # noqa: E402

DIMS = st.integers(2, 12)


def _ref_minplus(A, B):
    return np.minimum(A[:, :, None], B[None, :, :]).sum(axis=1)


@settings(max_examples=25, deadline=None)
@given(
    m=DIMS, k=DIMS, n=DIMS,
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(0.1, 100.0),
)
def test_mgemm_matches_reference_on_floats(m, k, n, seed, scale):
    rng = np.random.default_rng(seed)
    A = (rng.random((m, k)) * scale).astype(np.float32)
    B = (rng.random((k, n)) * scale).astype(np.float32)
    got = np.asarray(mgemm_xla(jnp.asarray(A), jnp.asarray(B), chunk=4))
    np.testing.assert_allclose(got, _ref_minplus(A, B), rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_mgemm_transpose_identity(m, k, n, seed):
    """min-plus GEMM: (A ∘ B)^T == (B^T ∘ A^T)."""
    rng = np.random.default_rng(seed)
    A = rng.integers(0, 9, (m, k)).astype(np.float32)
    B = rng.integers(0, 9, (k, n)).astype(np.float32)
    ab = np.asarray(mgemm_xla(jnp.asarray(A), jnp.asarray(B)))
    ba = np.asarray(mgemm_xla(jnp.asarray(B.T), jnp.asarray(A.T)))
    assert (ab.T == ba).all()


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_mgemm_monotonicity(m, k, n, seed):
    """Increasing any input entry never decreases any output entry."""
    rng = np.random.default_rng(seed)
    A = rng.integers(0, 9, (m, k)).astype(np.float32)
    B = rng.integers(0, 9, (k, n)).astype(np.float32)
    base = np.asarray(mgemm_xla(jnp.asarray(A), jnp.asarray(B)))
    i, j = rng.integers(0, m), rng.integers(0, k)
    A2 = A.copy()
    A2[i, j] += 3
    up = np.asarray(mgemm_xla(jnp.asarray(A2), jnp.asarray(B)))
    assert (up >= base - 1e-6).all()


@settings(max_examples=20, deadline=None)
@given(levels=st.integers(1, 9), m=DIMS, k=DIMS, n=DIMS,
       seed=st.integers(0, 2**31 - 1))
def test_levels_decomposition_exact(levels, m, k, n, seed):
    rng = np.random.default_rng(seed)
    A = rng.integers(0, levels + 1, (m, k)).astype(np.float32)
    B = rng.integers(0, levels + 1, (k, n)).astype(np.float32)
    got = np.asarray(mgemm_levels_ref(jnp.asarray(A), jnp.asarray(B), levels=levels))
    assert (got == _ref_minplus(A, B)).all()


@settings(max_examples=20, deadline=None)
@given(n_v=st.integers(2, 10), n_f=st.integers(2, 30),
       seed=st.integers(0, 2**31 - 1), alpha=st.floats(0.1, 10.0))
def test_czek2_scale_invariance_and_range(n_v, n_f, seed, alpha):
    rng = np.random.default_rng(seed)
    V = rng.integers(0, 9, (n_f, n_v)).astype(np.float64) + 0.5
    c = czek2_metric_np(V)
    c2 = czek2_metric_np(V * alpha)
    np.testing.assert_allclose(c, c2, rtol=1e-9)  # scale invariant
    assert (c >= 0).all() and (c <= 1 + 1e-12).all()
    np.testing.assert_allclose(np.diag(c), 1.0)


@settings(max_examples=15, deadline=None)
@given(n_pv=st.integers(1, 10), n_vp=st.integers(1, 6),
       n_pr=st.integers(1, 4))
def test_plan2_exact_cover_property(n_pv, n_vp, n_pr):
    plan = TwoWayPlan(n_pv, n_pr)
    n_v = n_pv * n_vp
    seen = set()
    for p_v, d, col in plan.all_computed_blocks():
        I, J, mask = global_pairs_of_block(p_v, col, n_vp)
        for i, j in zip(I[mask], J[mask]):
            key = (min(i, j), max(i, j))
            assert key not in seen
            seen.add(key)
    assert len(seen) == n_v * (n_v - 1) // 2


@settings(max_examples=8, deadline=None)
@given(n_pv=st.integers(1, 4), n_st=st.sampled_from([1, 2]),
       mult=st.integers(1, 2))
def test_plan3_exact_cover_property(n_pv, n_st, mult):
    n_vp = 6 * n_st * mult
    plan = ThreeWayPlan(n_pv, 1, n_st)
    n_v = n_pv * n_vp
    seen = set()
    for p_v in range(n_pv):
        for it in plan.items_of(p_v, 0):
            for stg in range(n_st):
                gi, gj, gk = plan.item_cells(p_v, it, n_vp, stg)
                for t in zip(gi, gj, gk):
                    key = tuple(sorted(t))
                    assert key not in seen
                    seen.add(key)
    assert len(seen) == n_v * (n_v - 1) * (n_v - 2) // 6


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 40))
def test_checksum_multiset_invariance(seed, n):
    rng = np.random.default_rng(seed)
    i = rng.integers(0, 100, n)
    j = rng.integers(101, 200, n)
    v = rng.random(n).astype(np.float32)
    perm = rng.permutation(n)
    assert ck.checksum_pairs(i, j, v) == ck.checksum_pairs(i[perm], j[perm], v[perm])


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_quantization_error_bound(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray((rng.random(64) - 0.5).astype(np.float32) * 10)
    q, s = quantize(x)
    err = np.abs(np.asarray(dequantize(q, s) - x))
    assert (err <= float(s) / 2 + 1e-7).all()


@settings(max_examples=10, deadline=None)
@given(n_f=st.integers(8, 60), n_v=st.integers(2, 12),
       seed=st.integers(0, 2**31 - 1))
def test_analytic_windows_closed_form(n_f, n_v, seed):
    width = max(1, n_f // 4)
    V, aw = analytic_window_vectors(n_f, n_v, width=width, seed=seed)
    n2 = np.minimum(V[:, :, None], V[:, None, :]).sum(axis=0)
    I, J = np.meshgrid(np.arange(n_v), np.arange(n_v), indexing="ij")
    np.testing.assert_allclose(aw.n2(I, J), n2)
