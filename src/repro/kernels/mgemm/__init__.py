from .ops import czek2_metric, mgemm  # noqa: F401
from .ref import czek2_metric_ref, mgemm_ref  # noqa: F401
