"""Pallas TPU kernels: blocked min-plus GEMM (the paper's mGEMM, §3.1) and
the generated fused-epilogue metric kernels behind the ``TileExecutor``.

TPU adaptation of the paper's modified-MAGMA GEMM.  The MXU cannot evaluate
``min`` inside its systolic array, so the contraction runs on the VPU:
HBM -> VMEM tiles via BlockSpec, fp32 accumulation in a VMEM scratch
accumulator, K-chunked broadcast-combine + reduce inside the block.

Grid: (M/bm, N/bn, K/bk), K innermost so the accumulator tile stays resident
in VMEM across the contraction (standard Pallas matmul pattern).

Default tile (bm, bn, bk) = (128, 128, 512):
  VMEM working set = A tile 128*512*4 B + B tile 512*128*4 B + acc 128*128*4 B
                   = 256 KiB + 256 KiB + 64 KiB ≈ 0.6 MiB  « 16 MiB VMEM,
leaving room for double buffering of the input streams.  The inner k-chunk
(8) bounds the broadcast intermediate to 128*8*128*4 = 512 KiB of VREG/VMEM
traffic, aligned to the (8, 128) VPU vector register shape.

Fused metric kernels (paper §3.1 epilogue fusion + §5 symmetry)
---------------------------------------------------------------
``metric2_pallas`` generates, for ANY metric spec with a Pallas-composable
``assemble_tile`` epilogue, the fused kernel: the contraction accumulates
``sum_q combine(a, b)`` in VMEM and the flush divides the tile in place —
the dense numerator matrix never exists in HBM.

``metric2_tri_pallas`` is the diagonal-block (Va == Vb) variant realizing
the paper's §5 block-triangle scheme IN the grid: the schedule enumerates
only the T(T+1)/2 tiles with ``tj >= ti`` (a 1-D grid whose index maps
decode the packed triangular index arithmetically), so the redundant lower
triangle is never computed rather than computed-then-masked.  Output is the
packed tile list (P, bt, bt); ``unpack_tri_tiles`` scatters it to a dense
strictly-upper block when a caller needs one.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.metric_spec import czek_assemble_tile

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512
K_CHUNK = 8

__all__ = [
    "mgemm_pallas",
    "czek2_metric_pallas",
    "metric2_pallas",
    "metric2_tri_pallas",
    "tri_tile_coords",
    "unpack_tri_tiles",
]


def _accumulate(a, b, combine, k_chunk):
    """One (bm, bk) x (bk, bn) combine-sum contraction in fp32."""
    bm, bk = a.shape
    bn = b.shape[1]

    def body(t, acc):
        a_sub = jax.lax.dynamic_slice(a, (0, t * k_chunk), (bm, k_chunk))
        b_sub = jax.lax.dynamic_slice(b, (t * k_chunk, 0), (k_chunk, bn))
        m = combine(a_sub[:, :, None], b_sub[None, :, :]).astype(jnp.float32)
        return acc + m.sum(axis=1)

    return jax.lax.fori_loop(
        0, bk // k_chunk, body, jnp.zeros((bm, bn), jnp.float32)
    )


def _mgemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k_steps: int, k_chunk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _accumulate(a_ref[...], b_ref[...], jnp.minimum, k_chunk)

    @pl.when(pl.program_id(2) == n_k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _fused2_kernel(
    a_ref, b_ref, sa_ref, sb_ref, o_ref, acc_ref,
    *, n_k_steps, k_chunk, combine, epilogue,
):
    """Generated fused metric kernel: contraction + in-VMEM epilogue.

    The flush applies the metric's ``assemble_tile`` to the accumulator
    tile, so the numerator block is divided in VMEM and only metric values
    reach HBM (the §3.1 epilogue-fusion bandwidth win)."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _accumulate(a_ref[...], b_ref[...], combine, k_chunk)

    @pl.when(pl.program_id(2) == n_k_steps - 1)
    def _flush():
        o_ref[...] = epilogue(acc_ref[...], sa_ref[...], sb_ref[...]).astype(
            o_ref.dtype
        )


def _fused2_tri_kernel(
    idx_ref, a_ref, b_ref, sa_ref, sb_ref, o_ref, acc_ref,
    *, n_k_steps, k_chunk, combine, epilogue,
):
    """Triangular-schedule fused kernel for diagonal blocks (paper §5).

    Grid axis 0 walks the packed tile list (only ``tj >= ti``); ``idx_ref``
    carries this tile's (ti, tj) so the flush can zero the redundant
    lower-and-diagonal entries of on-diagonal tiles in place."""
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _accumulate(a_ref[...], b_ref[...], combine, k_chunk)

    @pl.when(pl.program_id(1) == n_k_steps - 1)
    def _flush():
        vals = epilogue(acc_ref[...], sa_ref[...], sb_ref[...])
        on_diag = idx_ref[0, 0] == idx_ref[0, 1]
        li = jax.lax.broadcasted_iota(jnp.int32, vals.shape, 0)
        lj = jax.lax.broadcasted_iota(jnp.int32, vals.shape, 1)
        keep = jnp.logical_or(jnp.logical_not(on_diag), li < lj)
        o_ref[0] = jnp.where(keep, vals, 0.0).astype(o_ref.dtype)


def _tri_decode(p, T: int):
    """Packed triangular index -> (ti, tj), tj >= ti, row-major.

    Pure scalar arithmetic (no captured constants) so it is legal inside a
    BlockSpec index map.  The float sqrt estimate is corrected branchlessly,
    keeping the decode exact for any practical tile count."""
    q = T * (T + 1) // 2 - 1 - p
    qf = jnp.asarray(q).astype(jnp.float32)
    r = ((jnp.sqrt(8.0 * qf + 1.0) - 1.0) / 2.0).astype(jnp.int32)
    r = jnp.where((r + 1) * (r + 2) // 2 <= q, r + 1, r)
    r = jnp.where(r * (r + 1) // 2 > q, r - 1, r)
    o = q - r * (r + 1) // 2
    return T - 1 - r, T - 1 - o


def tri_tile_coords(T: int) -> tuple[np.ndarray, np.ndarray]:
    """Host-side (ti, tj) arrays of the packed triangular schedule."""
    ti = np.array([i for i in range(T) for _ in range(i, T)], np.int32)
    tj = np.array([j for i in range(T) for j in range(i, T)], np.int32)
    return ti, tj


def unpack_tri_tiles(packed, m: int, bt: int):
    """Scatter packed (P, bt, bt) tiles to a dense (m, m) strictly-upper block.

    The lower triangle was never computed; it reads back as zeros, matching
    the compute-both-then-mask layout bit for bit."""
    T = -(-m // bt)
    ti, tj = tri_tile_coords(T)
    dense = jnp.zeros((T, T, bt, bt), packed.dtype).at[ti, tj].set(packed)
    dense = dense.transpose(0, 2, 1, 3).reshape(T * bt, T * bt)
    return dense[:m, :m]


def _pad_operands(A, B, sa, sb, bm, bn, bk):
    """Block-pad operands; stats pad with ZERO so the epilogue's
    ``safe_denom`` guard covers pad columns exactly like all-zero real
    columns (0/eps = 0), instead of a bypassing pad constant."""
    m, k = A.shape
    n = B.shape[1]
    mp, np_, kp = (-m) % bm, (-n) % bn, (-k) % bk
    if mp or kp:
        A = jnp.pad(A, ((0, mp), (0, kp)))
    if np_ or kp:
        B = jnp.pad(B, ((0, kp), (0, np_)))
    sa = jnp.pad(jnp.asarray(sa, jnp.float32).reshape(-1), (0, mp))[:, None]
    sb = jnp.pad(jnp.asarray(sb, jnp.float32).reshape(-1), (0, np_))[None, :]
    return A, B, sa, sb


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "k_chunk", "interpret", "out_dtype"),
)
def mgemm_pallas(
    A,
    B,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    k_chunk: int = K_CHUNK,
    interpret: bool = False,
    out_dtype=jnp.float32,
):
    """out[i, j] = sum_k min(A[i, k], B[k, j]).  A (m, k), B (k, n)."""
    m, k = A.shape
    k2, n = B.shape
    assert k == k2
    # pad every dim to its block multiple; k pads with zeros on both operands
    # => min(0, 0) = 0 contributes nothing.
    mp, np_, kp = (-m) % bm, (-n) % bn, (-k) % bk
    if mp or kp:
        A = jnp.pad(A, ((0, mp), (0, kp)))
    if np_ or kp:
        B = jnp.pad(B, ((0, kp), (0, np_)))
    M, K = A.shape
    N = B.shape[1]
    n_k_steps = K // bk
    grid = (M // bm, N // bn, n_k_steps)
    out = pl.pallas_call(
        functools.partial(_mgemm_kernel, n_k_steps=n_k_steps, k_chunk=k_chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, t: (i, t)),
            pl.BlockSpec((bk, bn), lambda i, j, t: (t, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(A, B)
    return out[:m, :n]


@functools.partial(
    jax.jit,
    static_argnames=(
        "combine", "epilogue", "bm", "bn", "bk", "k_chunk", "interpret",
        "out_dtype",
    ),
)
def metric2_pallas(
    A,
    B,
    sa,
    sb,
    *,
    combine,
    epilogue,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    k_chunk: int = K_CHUNK,
    interpret: bool = False,
    out_dtype=jnp.float32,
):
    """Generated fused 2-way metric kernel (rectangular tile grid).

    out[i, j] = epilogue(sum_k combine(A[i, k], B[k, j]), sa_i, sb_j) for any
    registered metric whose contraction is the combine-sum reduction."""
    m, k = A.shape
    n = B.shape[1]
    A, B, sa, sb = _pad_operands(A, B, sa, sb, bm, bn, bk)
    M, K = A.shape
    N = B.shape[1]
    n_k_steps = K // bk
    grid = (M // bm, N // bn, n_k_steps)
    out = pl.pallas_call(
        functools.partial(
            _fused2_kernel, n_k_steps=n_k_steps, k_chunk=k_chunk,
            combine=combine, epilogue=epilogue,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, t: (i, t)),
            pl.BlockSpec((bk, bn), lambda i, j, t: (t, j)),
            pl.BlockSpec((bm, 1), lambda i, j, t: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, t: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(A, B, sa, sb)
    return out[:m, :n]


@functools.partial(
    jax.jit,
    static_argnames=(
        "combine", "epilogue", "bt", "bk", "k_chunk", "interpret", "out_dtype",
    ),
)
def metric2_tri_pallas(
    A,
    B,
    sa,
    sb,
    *,
    combine,
    epilogue,
    bt: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    k_chunk: int = K_CHUNK,
    interpret: bool = False,
    out_dtype=jnp.float32,
):
    """Fused diagonal-block metric kernel on the triangular tile schedule.

    A (m, k) and B (k, m) are the two orientations of the SAME vector block;
    only the T(T+1)/2 tiles with ``tj >= ti`` are enumerated (paper §5), and
    on-diagonal tiles are masked to the strict upper triangle at flush.
    Returns the packed tile list (P, bt, bt) in ``tri_tile_coords`` order —
    the packed upper-triangular storage form."""
    m, k = A.shape
    assert B.shape == (k, m), "triangular schedule needs a square block"
    A, B, sa, sb = _pad_operands(A, B, sa, sb, bt, bt, bk)
    M, K = A.shape
    T = M // bt
    P = T * (T + 1) // 2
    n_k_steps = K // bk
    ti, tj = tri_tile_coords(T)
    idx = jnp.asarray(np.stack([ti, tj], axis=1))  # (P, 2) static schedule

    def a_map(p, t):
        return (_tri_decode(p, T)[0], t)

    def b_map(p, t):
        return (t, _tri_decode(p, T)[1])

    def sa_map(p, t):
        return (_tri_decode(p, T)[0], 0)

    def sb_map(p, t):
        return (0, _tri_decode(p, T)[1])

    out = pl.pallas_call(
        functools.partial(
            _fused2_tri_kernel, n_k_steps=n_k_steps, k_chunk=k_chunk,
            combine=combine, epilogue=epilogue,
        ),
        grid=(P, n_k_steps),
        in_specs=[
            pl.BlockSpec((1, 2), lambda p, t: (p, 0)),
            pl.BlockSpec((bt, bk), a_map),
            pl.BlockSpec((bk, bt), b_map),
            pl.BlockSpec((bt, 1), sa_map),
            pl.BlockSpec((1, bt), sb_map),
        ],
        out_specs=pl.BlockSpec((1, bt, bt), lambda p, t: (p, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((P, bt, bt), out_dtype),
        scratch_shapes=[pltpu.VMEM((bt, bt), jnp.float32)],
        interpret=interpret,
    )(idx, A, B, sa, sb)
    return out


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "k_chunk", "interpret", "out_dtype"),
)
def czek2_metric_pallas(
    A,
    B,
    sa,
    sb,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    k_chunk: int = K_CHUNK,
    interpret: bool = False,
    out_dtype=jnp.float32,
):
    """Fused 2-way Czekanowski: out[i,j] = 2*Σ min / safe_denom(sa_i + sb_j).

    One instantiation of the generated ``metric2_pallas`` kernel.  The
    denominator runs through the unified ``safe_denom`` guard (stats pad
    with zero), so all-zero real columns yield 0 exactly like the XLA path
    instead of hitting 0/0."""
    return metric2_pallas(
        A, B, sa, sb,
        combine=jnp.minimum, epilogue=czek_assemble_tile,
        bm=bm, bn=bn, bk=bk, k_chunk=k_chunk, interpret=interpret,
        out_dtype=out_dtype,
    )
