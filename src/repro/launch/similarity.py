"""Similarity-campaign launcher: the paper's workload as a CLI over the
unified ``repro.api`` engine.

    python -m repro.launch.similarity --way 2 --n-f 1000 --n-v 512 \
        --n-pv 4 --n-pr 2 --devices 8 --metric czekanowski --out /tmp/metrics

Builds a ``SimilarityRequest`` (any registered metric; 2-way or staged
3-way), runs it through ``SimilarityEngine``, writes the result's block
manifest with the exact checksum (paper §5), and prints throughput in
elementwise comparisons/second (the paper's headline metric).
"""
import argparse
import os
import sys


def _parse_metrics(metrics: str, metric: str) -> list:
    """'czekanowski,sorenson' -> campaign metric names (primary first);
    an empty --metrics falls back to the single --metric."""
    if not metrics:
        return [metric]
    names = [m.strip() for m in metrics.split(",") if m.strip()]
    if not names:
        raise ValueError("--metrics given but no metric names parsed")
    return names


def _parse_subsets(subsets: str) -> tuple:
    """';'-separated 'name=lo:hi[:step]' or 'name=i,j,k' -> request tuples."""
    if not subsets:
        return ()
    out = []
    for part in subsets.split(";"):
        part = part.strip()
        if not part:
            continue
        name, eq, spec = part.partition("=")
        if not eq or not name.strip() or not spec.strip():
            raise ValueError(
                f"--subsets entry {part!r} is not 'name=lo:hi[:step]' "
                f"or 'name=i,j,k'"
            )
        name, spec = name.strip(), spec.strip()
        try:
            if ":" in spec:
                fields = [int(x) for x in spec.split(":")]
                if len(fields) not in (2, 3):
                    raise ValueError
                idx = tuple(range(*fields))
            else:
                idx = tuple(int(x) for x in spec.split(","))
        except ValueError:
            raise ValueError(
                f"--subsets entry {part!r}: bad index spec {spec!r}"
            ) from None
        out.append((name, idx))
    return tuple(out)


def _report_batched(batched, request, args) -> int:
    """Per-campaign result rows + the shared ring-traffic accounting."""
    b = batched.meta["batch"]
    print(f"batched campaigns={b['campaigns']} "
          f"metrics={','.join(request.campaign_metrics())} "
          f"subsets={','.join(b['subsets']) or '(full)'} "
          f"families={b['families']} way={b['way']}")
    print(f"ring payload_bytes_per_rank={b['payload_bytes_per_rank']} "
          f"ring_steps={b['ring_steps']} n_ranks={b['n_ranks']} "
          f"ring_payload_bytes={b['ring_payload_bytes']} "
          f"stat_ring_bytes={b['stat_ring_bytes']} "
          f"traversals={b['traversals']} encodes={b['encodes']}")
    for mname, sname, result in batched:
        n_results = result.num_results()
        print(f"campaign metric={mname} subset={sname or '(full)'} "
              f"n_v={result.n_v} results={n_results} "
              f"checksum={hex(result.checksum())}")
        if args.out:
            sub = mname + (f"__{sname}" if sname else "")
            result.save(os.path.join(args.out, sub))
    print(f"time={batched.seconds:.3f}s")
    return 0


def _report_trace(tracer, result, args) -> None:
    """--trace epilogue: write the Chrome trace file, print the per-phase
    table (every canonical phase, count 0 when it never ran) and the
    roofline-utilization line from ``meta["obs"]``."""
    if tracer is None:
        return
    from repro.obs import trace as obs_trace

    obs_trace.disable()
    tracer.write_chrome_trace(args.trace)
    print(obs_trace.format_phase_table(tracer.phase_stats()))
    ob = result.meta.get("obs") or {}
    line = (f"obs comparisons={ob.get('comparisons')} "
            f"rate={ob.get('comparisons_per_s', 0.0):.3e} comparisons/s")
    if "bound_seconds" in ob:
        line += (f" bound_seconds={ob['bound_seconds']:.6f}"
                 f" bottleneck={ob.get('bottleneck')}")
    if "utilization" in ob:
        line += f" utilization={ob['utilization']:.3e}"
    print(line)
    print(f"trace={args.trace} events={tracer.event_count()}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--metric", default="czekanowski",
                    help="registered metric name (see --list-metrics)")
    ap.add_argument("--metrics", default="",
                    help="comma-separated metric list for a BATCHED campaign "
                         "— every metric rides ONE ring traversal of the "
                         "shared payload (overrides --metric; first name is "
                         "the primary)")
    ap.add_argument("--subsets", default="",
                    help="named vector-index subsets for a batched campaign, "
                         "';'-separated 'name=SPEC' with SPEC either "
                         "'lo:hi[:step]' or 'i,j,k'; each subset runs as its "
                         "own campaign against a byte-slice view of the "
                         "shared plane payload (no re-encode)")
    ap.add_argument("--list-metrics", action="store_true",
                    help="print every registered metric (sorted) with its "
                         "one-line description and exit")
    ap.add_argument("--way", type=int, default=2, choices=(2, 3))
    ap.add_argument("--n-f", type=int, default=512)
    ap.add_argument("--n-v", type=int, default=240)
    ap.add_argument("--n-pf", type=int, default=1)
    ap.add_argument("--n-pv", type=int, default=1)
    ap.add_argument("--n-pr", type=int, default=1)
    ap.add_argument("--n-st", type=int, default=1)
    ap.add_argument("--stage", type=int, default=0,
                    help="3-way stage to run; -1 runs all n_st stages")
    ap.add_argument("--devices", type=int, default=0,
                    help="force host device count (set before jax init)")
    ap.add_argument("--impl", default=None,
                    help="mgemm implementation (default: xla, or levels "
                         "when --dataset is given)")
    ap.add_argument("--levels", type=int, default=None,
                    help="level count for impl='levels*' (default: 2, or "
                         "the dataset's encoded levels with --dataset)")
    ap.add_argument("--out-dtype", default="float32",
                    help="metric output dtype (e.g. float32, bfloat16)")
    ap.add_argument("--ring-dtype", default="auto",
                    help="ring payload dtype; 'auto' picks int8 for "
                         "small-integer data (4x less ICI traffic), "
                         "'float32' opts out")
    ap.add_argument("--encoding", default="auto",
                    choices=("auto", "bitplane", "none"),
                    help="bit-plane pre-encoding for the levels path: "
                         "encode V once into packed uint8 planes and "
                         "ring-carry those (up to 16x less wire for SNP "
                         "{0,1,2} data)")
    ap.add_argument("--streaming", default="auto",
                    choices=("auto", "on", "off"),
                    help="out-of-core streaming over a --dataset: 'auto' "
                         "streams multi-shard (or --max-host-bytes budgeted) "
                         "datasets chunk by chunk with double-buffered "
                         "prefetch, 'on' requires a dataset, 'off' always "
                         "materializes in memory; results are bit-identical "
                         "either way")
    ap.add_argument("--max-host-bytes", type=int, default=0,
                    help="staging-buffer budget in bytes for the streamed "
                         "pipeline (0 = one disk shard per chunk)")
    ap.add_argument("--dry-run", action="store_true",
                    help="print the resolved execution path (fused-popcount "
                         "/ fused-levels / streamed-fused-* / fused-vpu / "
                         "unfused + reason), encoding, ring dtype and the "
                         "streaming decision, then exit without running the "
                         "campaign")
    ap.add_argument("--chunk", type=int, default=128,
                    help="XLA mgemm contraction-chunk size")
    ap.add_argument("--input", default="", help=".npy (n_f, n_v) input")
    ap.add_argument("--dataset", default="",
                    help="packed bit-plane dataset directory (repro.store): "
                         "the campaign loads pre-encoded planes and never "
                         "runs the host encoder")
    ap.add_argument("--max-value", type=int, default=15)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--append", default="",
                    help=".npy (n_f, m) matrix appended to --dataset before "
                         "the campaign (byte-column append — the existing "
                         "payload is never re-encoded); grows the dataset "
                         "in place")
    ap.add_argument("--delta-from", default="",
                    help="saved prior result directory covering the "
                         "dataset's first vectors: run a border-block DELTA "
                         "campaign — only the new-vs-all rectangle and "
                         "new-vs-new triangle are computed and merged, "
                         "checksum bit-identical to a full recompute")
    ap.add_argument("--trace", default="", metavar="OUT.json",
                    help="record per-phase spans (repro.obs) during the "
                         "campaign, write Chrome/Perfetto trace-event JSON "
                         "to OUT.json, and print the phase table plus "
                         "roofline utilization after the run; checksums are "
                         "unchanged (tracing only adds timing fences)")
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", "")
        )
    from repro.api import (
        InputSpec,
        SimilarityEngine,
        SimilarityRequest,
        available_metrics,
    )

    if args.list_metrics:
        from repro.api import get_metric

        for name in sorted(available_metrics()):
            desc = get_metric(name).description.split("\n")[0].strip()
            print(f"{name:16s} {desc}" if desc else name)
        return 0

    try:
        names = _parse_metrics(args.metrics, args.metric)
        subsets = _parse_subsets(args.subsets)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    if args.dataset and args.input:
        print("error: --input and --dataset are mutually exclusive",
              file=sys.stderr)
        return 2
    if args.append:
        if not args.dataset:
            print("error: --append grows a --dataset store", file=sys.stderr)
            return 2
        import numpy as np

        from repro.core.validate import validate_matrix
        from repro.store import append_dataset

        try:
            V_new = validate_matrix(np.load(args.append), what=args.append,
                                    check_fp32_sums=True)
            manifest = append_dataset(args.dataset, V_new)
        except ValueError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
        print(f"appended {V_new.shape[1]} vector(s): {args.dataset} now "
              f"n_v={manifest['n_v']} (v{manifest['dataset_version']})")
    impl = args.impl or ("levels" if args.dataset else "xla")
    levels = args.levels
    if args.dataset:
        # pre-encoded campaign: the store's planes feed the engines directly
        from repro.store import read_manifest

        try:
            manifest = read_manifest(args.dataset)
        except ValueError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
        if levels is None:
            levels = manifest["levels"]
        input_spec = InputSpec(source="planes", path=args.dataset)
    elif args.input:
        input_spec = InputSpec(source="npy", path=args.input)
    else:
        input_spec = InputSpec(
            source="synthetic", n_f=args.n_f, n_v=args.n_v,
            max_value=args.max_value, seed=args.seed,
        )
    if levels is None:
        levels = 2
    stages = None if (args.way == 3 and args.stage < 0) else (
        (args.stage,) if args.way == 3 else None
    )
    request = SimilarityRequest(
        metric=names[0], metrics=tuple(names[1:]), subsets=subsets,
        way=args.way,
        n_pf=args.n_pf, n_pv=args.n_pv, n_pr=args.n_pr, n_st=args.n_st,
        stages=stages, impl=impl, levels=levels,
        out_dtype=args.out_dtype, ring_dtype=args.ring_dtype,
        encoding=args.encoding, chunk=args.chunk,
        streaming=args.streaming, max_host_bytes=args.max_host_bytes,
        input=input_spec, delta_from=args.delta_from,
    )
    from repro.api import UnknownMetricError

    if args.dry_run:
        # surface the executor's chosen path so silent fallbacks (e.g. a
        # fused request declined because n_pf > 1) become visible
        import jax.numpy as jnp

        from repro.api.registry import get_metric
        from repro.core.tile_executor import TileExecutor
        from repro.core.twoway import resolve_config

        try:
            spec = get_metric(request.metric)
            request.validate(metric_spec=spec)
            specs = [get_metric(n) for n in request.campaign_metrics()]
            if (request.input.source == "planes"
                    and request.streaming != "off"):
                # lazy handle: the streaming decision resolves without
                # reading a payload byte
                from repro.store import DatasetReader

                probe = DatasetReader(request.input.path).sharded()
            else:
                probe = request.input.materialize()
            # batched campaigns resolve the shared-payload knobs against
            # the lead (plane-native) metric — same rule as the engines
            from repro.api.registry import batch_lead

            cfg = resolve_config(
                request.to_comet_config(), probe,
                batch_lead(specs) if request.is_batched else spec,
            )
        except (UnknownMetricError, ValueError) as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
        # one row per campaign: the per-metric executor path over the
        # SHARED resolved payload (subsets never change the path — they
        # are byte-slice views of the same planes)
        for mspec in specs:
            ex = TileExecutor(cfg=cfg, metric=mspec,
                              out_dtype=jnp.dtype(args.out_dtype), axis=None,
                              deferred=(cfg.streaming == "on"))
            path, why = ((ex.path, ex.path_reason) if args.way == 2
                         else (ex.path3, ex.path3_reason))
            reason = f" ({why})" if why else ""
            for sname, _ in request.campaign_subsets():
                row = f"path={path}{reason}"
                if request.is_batched:
                    row = (f"campaign metric={mspec.name} "
                           f"subset={sname or '(full)'} " + row)
                print(row)
        # with encoding=bitplane BOTH engines pre-encode once and ring-carry
        # the packed planes (3-way: path3 == "fused-levels-ring"); with
        # streaming=on the streamed-* chunk paths + merge epilogue run
        print(f"encoding={cfg.encoding} ring_dtype={cfg.ring_dtype} "
              f"impl={cfg.impl} levels={cfg.levels}")
        print(f"streaming={cfg.streaming} "
              f"max_host_bytes={cfg.max_host_bytes}")
        return 0

    tracer = None
    if args.trace:
        from repro.obs import trace as obs_trace

        tracer = obs_trace.enable()
    try:
        result = SimilarityEngine().run(request)
    except (UnknownMetricError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    if request.is_batched:
        rc = _report_batched(result, request, args)
        _report_trace(tracer, result, args)
        return rc

    n_results = result.num_results()
    comparisons = n_results * result.n_f
    checksum = result.checksum()
    print(f"metric={result.metric} way={result.way} "
          f"n_f={result.n_f} n_v={result.n_v} "
          f"decomp=({args.n_pf},{args.n_pv},{args.n_pr}) "
          f"stages={list(result.stages)}")
    print(f"results={n_results} time={result.seconds:.3f}s "
          f"rate={comparisons / max(result.seconds, 1e-12):.3e} comparisons/s")
    stream = result.meta.get("stream")
    if stream:
        print(f"streamed chunks={stream['chunks']} "
              f"chunk_bytes={stream['chunk_bytes']} "
              f"peak_host_bytes={stream['peak_host_bytes']} "
              f"n_shards={stream['n_shards']}")
    delta = result.meta.get("delta")
    if delta:
        # border-proportional proof: computed_entries ~ m*n + m^2/2, not
        # the full n^2/2 — the CI smoke step greps this line
        print(f"delta n_old={delta['n_old']} n_new={delta['n_new']} "
              f"border_entries={delta['border_entries']} "
              f"computed_entries={delta['computed_entries']} "
              f"full_entries={delta['full_entries']} "
              f"ring_payload_bytes={delta['ring_payload_bytes']} "
              f"streamed={delta['streamed']}")
    print(f"checksum={hex(checksum)}")
    _report_trace(tracer, result, args)
    if args.out:
        result.save(args.out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
