"""jit'd wrappers for the binary popcount bit-GEMM path.

No ``register_impl`` here: popcount is not an ``impl`` name — it is the
``levels == 1`` specialization of ``impl="levels"``, selected by the
``TileExecutor`` (``path == "fused-popcount"``), so request knobs stay
unchanged and binary campaigns speed up without opting into anything.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import (
    metric2_pop_pallas,
    metric2_pop_tri_pallas,
    threeway_batch_pop_pallas,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def metric2_pop(Pa, Pb, sa, sb, *, epilogue, **kw):
    """Fused metric kernel on a binary packed plane (rectangular grid)."""
    kw.setdefault("interpret", not _on_tpu())
    return metric2_pop_pallas(Pa, Pb, sa, sb, epilogue=epilogue, **kw)


def metric2_pop_tri(P, s, *, epilogue, **kw):
    """Fused diagonal-block popcount kernel (triangular tile schedule)."""
    kw.setdefault("interpret", not _on_tpu())
    return metric2_pop_tri_pallas(P, s, epilogue=epilogue, **kw)


def pop_planes(Pa, Pb, **kw):
    """Popcount-contraction-only kernel: the raw-numerator form used when
    the reduction is split over ranks (``n_pf > 1``) or deferred across
    streamed chunks and the epilogue must wait for the psum/merge."""
    kw.setdefault("interpret", not _on_tpu())
    za = jnp.zeros((Pa.shape[2],), jnp.float32)
    zb = jnp.zeros((Pb.shape[2],), jnp.float32)
    return metric2_pop_pallas(Pa, Pb, za, zb, epilogue=None, **kw)


def threeway_batch_pop(Pown, PX, Pright, **kw):
    """3-way pipeline-slice popcount kernel (packed AND stays packed)."""
    kw.setdefault("interpret", not _on_tpu())
    return threeway_batch_pop_pallas(Pown, PX, Pright, **kw)
