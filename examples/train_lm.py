"""End-to-end training driver: ~100M-param LM, a few hundred steps on CPU.

    PYTHONPATH=src python examples/train_lm.py                 # ~100M, 200 steps
    PYTHONPATH=src python examples/train_lm.py --quick         # ~10M, 50 steps

Demonstrates the full substrate: deterministic data pipeline, AdamW +
warmup-cosine, async checkpointing (resume with the same command), straggler
watchdog, loss logging.
"""
import argparse
import json

from repro.models.common import ModelConfig, param_count
from repro.optim.adamw import AdamWConfig
from repro.optim.schedule import warmup_cosine
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.quick:
        cfg = ModelConfig(
            name="lm-10m", family="dense", n_layers=4, d_model=256,
            n_heads=4, n_kv_heads=2, d_ff=1024, vocab_size=8192, head_dim=64,
        )
        steps = args.steps or 50
        batch, seq = 4, 128
    else:
        # ~100M params: 12L x 512, 32k vocab
        cfg = ModelConfig(
            name="lm-100m", family="dense", n_layers=12, d_model=512,
            n_heads=8, n_kv_heads=4, d_ff=2048, vocab_size=32768, head_dim=64,
        )
        steps = args.steps or 200
        batch, seq = 4, 256

    tcfg = TrainerConfig(
        steps=steps, ckpt_every=max(steps // 4, 1), log_every=5,
        ckpt_dir=args.ckpt_dir, batch=batch, seq_len=seq,
    )
    opt = AdamWConfig(lr=6e-4, schedule=warmup_cosine(steps // 10, steps))
    trainer = Trainer(cfg, tcfg, opt)
    state = trainer.resume_or_init()
    print(f"{cfg.name}: {param_count(state.params) / 1e6:.1f}M params, "
          f"resuming at step {state.step}/{steps}")
    state = trainer.train(state)
    for h in trainer.history:
        print(json.dumps({k: round(v, 4) if isinstance(v, float) else v
                          for k, v in h.items()}))
    first = trainer.history[0]["loss"] if trainer.history else float("nan")
    last = trainer.history[-1]["loss"] if trainer.history else float("nan")
    print(f"loss {first:.3f} -> {last:.3f} over {state.step} steps")


if __name__ == "__main__":
    main()
