"""Paper Figs 6-10: strong + weak scaling of the distributed engines.

Strong (Fig 6): fixed problem, ranks 1..8 — report time vs ranks + parallel
efficiency.  Weak (Figs 7/8 2-way, 9/10 3-way): fixed per-rank work —
report comparisons/sec/rank (the paper's right-hand graphs; flat = ideal).

Runs in a subprocess with 8 virtual CPU devices (one jax startup for the
whole sweep); the measured efficiencies are structural (ring + round-robin
overheads), with CPU compute standing in for the GPU mGEMM.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.util import row

HERE = os.path.dirname(os.path.abspath(__file__))
CACHE = os.path.join(HERE, "..", "results", "scaling.json")


def run_harness():
    env = dict(os.environ)
    src = os.path.join(HERE, "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "scaling_harness.py")],
        capture_output=True, text=True, env=env, timeout=1800,
    )
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    data = json.loads(proc.stdout.splitlines()[-1])
    os.makedirs(os.path.dirname(CACHE), exist_ok=True)
    with open(CACHE, "w") as f:
        json.dump(data, f, indent=2)
    return data


def main():
    data = run_harness()
    rows = []
    for key in ("strong_2way", "strong_3way"):
        base = data[key][0]
        for r in data[key]:
            ranks = r["n_pv"] * r["n_pr"]
            eff = base["seconds"] / (r["seconds"] * ranks)
            rows.append(row(f"fig6/{key}/ranks{ranks}", r["seconds"],
                            f"efficiency={eff:.2f}"))
    for key in ("weak_2way", "weak_3way"):
        base = data[key][0]
        for r in data[key]:
            ranks = r["n_pv"] * r["n_pr"]
            rel = r["rate_per_rank"] / base["rate_per_rank"]
            rows.append(row(f"fig7_10/{key}/ranks{ranks}", r["seconds"],
                            f"rate_per_rank={r['rate_per_rank']:.3e}_rel={rel:.2f}"))
    return rows


if __name__ == "__main__":
    from benchmarks.util import print_rows

    print_rows(main())
