"""Near-duplicate detection over documents — the paper's technique applied
to the LM data pipeline (DESIGN.md §5).

Documents are summarized as token count-profile vectors (the Proportional
Similarity metric's native input: non-negative profiles); all-pairs 2-way
Czekanowski similarity via the distributed engine; pairs above a threshold
are near-duplicates.  c2(u, u) = 1 exactly, and c2 is robust to length
differences (it compares distributions, not raw counts).
"""
from __future__ import annotations

import numpy as np

from repro.core.twoway import CometConfig, czek2_distributed
from repro.parallel.mesh import make_comet_mesh

__all__ = ["count_profiles", "find_near_duplicates"]


def count_profiles(docs: list[np.ndarray], vocab_size: int, hash_dim: int = 1024
                   ) -> np.ndarray:
    """(hash_dim, n_docs) matrix of hashed token-count profiles."""
    V = np.zeros((hash_dim, len(docs)), np.float32)
    for j, toks in enumerate(docs):
        np.add.at(V[:, j], toks % hash_dim, 1.0)
    return V


def find_near_duplicates(
    docs: list[np.ndarray],
    vocab_size: int,
    threshold: float = 0.9,
    hash_dim: int = 1024,
    mesh=None,
    cfg: CometConfig | None = None,
) -> list[tuple[int, int, float]]:
    """All (i, j, sim) pairs with Czekanowski similarity >= threshold."""
    V = count_profiles(docs, vocab_size, hash_dim)
    mesh = mesh or make_comet_mesh(1, 1, 1)
    cfg = cfg or CometConfig(out_dtype="float32")
    out = czek2_distributed(V, mesh, cfg)
    hits = []
    for I, J, W in out.entries():
        sel = W >= threshold
        hits.extend(zip(I[sel].tolist(), J[sel].tolist(), W[sel].tolist()))
    return sorted(hits, key=lambda t: -t[2])
