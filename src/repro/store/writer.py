"""Dataset writer: streaming, field-sharded encode of a value matrix.

The writer never materializes the full plane array: fields are processed
one shard at a time — slice ``[8·r·kbs, 8·(r+1)·kbs)`` of V is encoded with
``encode_bitplanes_np`` and written as ``planes.shard<r>.npy`` — so peak
extra memory is one shard's payload.  Because shard boundaries are
byte-aligned (multiples of 8 fields), the per-shard encode is byte-identical
to the corresponding ``shard_planes_fields`` range of a whole-matrix encode
(property-tested in tests/test_store.py).

Input guard: the plane decomposition is exact ONLY for integer data in
``[0, levels]``, so the writer validates before encoding and fails naming
the offending stat — a ``levels=1`` store (binary / Sorenson data) therefore
admits exactly {0, 1} matrices, whose single plane's popcounts equal the
column sums.
"""
from __future__ import annotations

import hashlib
import os

import numpy as np

from repro.kernels.mgemm_levels import POPCOUNT, encode_bitplanes_np
from repro.store.format import (
    FORMAT_NAME,
    FORMAT_VERSION,
    STATS_NAME,
    shard_name,
    write_manifest,
)

__all__ = ["write_dataset", "append_dataset", "validate_leveled", "POPCOUNT"]


def validate_leveled(V: np.ndarray, levels: int, *, what: str = "input") -> None:
    """Raise ValueError naming the offending stat unless V is integer-valued
    in [0, levels] — the exactness domain of the plane decomposition (the
    shared ``repro.core.validate`` gate with the levels check layered on)."""
    if not (isinstance(levels, int) and levels >= 1):
        raise ValueError(f"levels must be a positive int, got {levels!r}")
    from repro.core.validate import validate_matrix

    validate_matrix(V, what=what, levels=levels)


def write_dataset(
    path: str,
    V: np.ndarray,
    *,
    levels: int,
    n_shards: int = 1,
    source: dict = None,
) -> dict:
    """Encode ``V`` (n_f, n_v) into a plane dataset at ``path``.

    ``n_shards`` splits the field (byte) axis into equal on-disk shards —
    each one the exact "pf" byte range a rank of an ``n_pf = n_shards``
    campaign ring-carries.  ``source`` is free-form provenance recorded in
    the manifest (kind/path/seed/...).  Returns the manifest dict.
    """
    V = np.asarray(V)
    validate_leveled(V, levels, what="write_dataset")
    if not (isinstance(n_shards, int) and n_shards >= 1):
        raise ValueError(f"n_shards must be a positive int, got {n_shards!r}")
    n_f, n_v = V.shape
    # total byte-axis length: ceil(n_f / 8) rounded up so shards are equal
    kbs = -(-n_f // (8 * n_shards))
    kb = kbs * n_shards
    os.makedirs(path, exist_ok=True)

    stats = np.zeros((levels, n_v), np.int64)
    h = hashlib.sha256()
    files = []
    for r in range(n_shards):
        f0, f1 = 8 * r * kbs, min(8 * (r + 1) * kbs, n_f)
        chunk = V[f0:f1] if f1 > f0 else V[:0]
        P = encode_bitplanes_np(chunk, levels)  # (levels, <=kbs, n_v)
        if P.shape[1] < kbs:  # tail shard: pad with inert zero bytes
            P = np.pad(P, ((0, 0), (0, kbs - P.shape[1]), (0, 0)))
        stats += POPCOUNT[P].sum(axis=1, dtype=np.int64)
        fname = shard_name(r)
        np.save(os.path.join(path, fname), P)
        h.update(np.ascontiguousarray(P).tobytes())
        files.append(fname)
    np.save(os.path.join(path, STATS_NAME), stats)

    manifest = {
        "format": FORMAT_NAME,
        "format_version": FORMAT_VERSION,
        "levels": int(levels),
        "n_f": int(n_f),
        "n_v": int(n_v),
        "kb": int(kb),
        "n_shards": int(n_shards),
        "shard_files": files,
        "stats_file": STATS_NAME,
        "checksum": "sha256:" + h.hexdigest(),
        "dataset_version": 1,
        "source": source or {"kind": "array"},
    }
    write_manifest(path, manifest)
    return manifest


def append_dataset(
    path: str,
    V_new: np.ndarray,
    *,
    out: str = None,
) -> dict:
    """Append ``V_new`` (n_f, m) as new vectors (byte-columns) to a dataset.

    The wire layout packs bits along the FIELD axis, so vector columns are
    independent: appending ``m`` vectors is, per shard, a concatenation of
    ``m`` freshly-encoded byte-columns onto the last axis — byte-identical
    to re-encoding ``concat([V_old, V_new], axis=1)`` from scratch with the
    same shard count (property-tested in tests/test_delta.py).  The stats
    sidecar grows by the new columns' popcounts; the manifest gets a fresh
    checksum, ``dataset_version = parent + 1`` and a ``parent`` lineage
    block naming the dataset it grew from (path / checksum / n_v) so delta
    campaigns can verify ancestry.

    ``out=None`` appends in place; ``out=<dir>`` writes the appended copy
    there and leaves the parent untouched.  Returns the new manifest.
    """
    from repro.store.reader import DatasetReader

    reader = DatasetReader(path)
    parent = reader.manifest
    V_new = np.asarray(V_new)
    validate_leveled(V_new, parent["levels"], what="append_dataset")
    if V_new.shape[0] != parent["n_f"]:
        raise ValueError(
            f"append_dataset: new vectors have n_f={V_new.shape[0]}, "
            f"dataset has n_f={parent['n_f']}"
        )
    m = V_new.shape[1]
    if m < 1:
        raise ValueError("append_dataset: no vectors to append")
    levels, n_shards = parent["levels"], parent["n_shards"]
    kbs = parent["kb"] // n_shards
    n_f, n_v = parent["n_f"], parent["n_v"] + m

    target = path if out is None else out
    os.makedirs(target, exist_ok=True)

    new_stats = np.zeros((levels, m), np.int64)
    h = hashlib.sha256()
    files = []
    for r in range(n_shards):
        f0, f1 = 8 * r * kbs, min(8 * (r + 1) * kbs, n_f)
        chunk = V_new[f0:f1] if f1 > f0 else V_new[:0]
        P = encode_bitplanes_np(chunk, levels)  # (levels, <=kbs, m)
        if P.shape[1] < kbs:  # tail shard: pad with inert zero bytes
            P = np.pad(P, ((0, 0), (0, kbs - P.shape[1]), (0, 0)))
        new_stats += POPCOUNT[P].sum(axis=1, dtype=np.int64)
        grown = np.concatenate([reader.shard(r), P], axis=2)
        fname = shard_name(r)
        np.save(os.path.join(target, fname), grown)
        h.update(np.ascontiguousarray(grown).tobytes())
        files.append(fname)
    stats = np.concatenate([reader.stats(), new_stats], axis=1)
    np.save(os.path.join(target, STATS_NAME), stats)

    manifest = dict(parent)
    manifest.update(
        n_v=int(n_v),
        shard_files=files,
        checksum="sha256:" + h.hexdigest(),
        dataset_version=int(parent.get("dataset_version", 1)) + 1,
        parent={
            "path": path,
            "checksum": parent["checksum"],
            "n_v": int(parent["n_v"]),
            "dataset_version": int(parent.get("dataset_version", 1)),
        },
    )
    write_manifest(target, manifest)
    return manifest
